"""Kernel microbenchmarks: us_per_call of the jnp reference path on CPU, and
allclose drift vs the Pallas kernel (interpret mode — TPU timings are the
dry-run's job; this guards correctness + tracks the oracle's CPU cost)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    rng = np.random.default_rng(0)

    # segment_agg on a power-law graph
    n, d = 4096, 128
    deg = np.minimum(np.random.default_rng(1).zipf(1.5, n), 64)
    indptr = np.concatenate([[0], np.cumsum(deg)])
    indices = rng.integers(0, n, indptr[-1])
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    src = jnp.asarray(indices)
    dst = jnp.asarray(np.repeat(np.arange(n), deg))
    ref_fn = jax.jit(lambda x_: ref.segment_agg_ref(x_, src, dst, n))
    us = _time(ref_fn, x)
    agg = ops.make_segment_agg(indptr, indices)
    err = float(jnp.abs(agg(x) - ref_fn(x)).max())
    emit("kernel", {"name": "segment_agg", "n": n, "d": d, "edges": int(indptr[-1]),
                    "us_per_call_ref_cpu": round(us, 1), "pallas_max_err": err})

    # flash attention
    b, hq, hkv, s, dh = 1, 8, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(b, hq, s, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, s, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, s, dh)).astype(np.float32))
    ref_fn = jax.jit(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=True))
    us = _time(ref_fn, q, k, v)
    err = float(jnp.abs(ops.flash_attention(q, k, v, causal=True)
                        - ref_fn(q, k, v)).max())
    emit("kernel", {"name": "flash_attention", "bhsd": f"{b}x{hq}x{s}x{dh}",
                    "us_per_call_ref_cpu": round(us, 1), "pallas_max_err": err})

    # rmsnorm
    x = jnp.asarray(rng.normal(size=(8192, 1024)).astype(np.float32))
    w = jnp.ones((1024,), jnp.float32)
    ref_fn = jax.jit(lambda x_: ref.rmsnorm_ref(x_, w))
    us = _time(ref_fn, x)
    err = float(jnp.abs(ops.rmsnorm(x, w) - ref_fn(x)).max())
    emit("kernel", {"name": "rmsnorm", "shape": "8192x1024",
                    "us_per_call_ref_cpu": round(us, 1), "pallas_max_err": err})


if __name__ == "__main__":
    main()
