"""Partitioned GNN serving benchmark — the latency trajectory for PR 7.

Builds the serving engine from an ``SPMDEngine`` export on `products-s`
(P=4, stacked, jnp segment-op aggregation), then drives a synthetic
request stream: every tick applies a few feature updates and answers a
batch of logit queries, with incremental dirty-set recomputation between
ticks.  Records:

  p50/p99 tick latency and sustained queries/s over the stream;
  incremental-vs-full: wall time of an incremental flush after a SMALL
      dirty set (a handful of feature updates) vs ``refresh_full()``
      (every owned row recomputed through the same machinery).

The acceptance gate: the incremental flush must be >= 2x faster than the
full recompute on small dirty sets — the whole point of dirty-set
propagation.  ``preds_match`` (served predictions == a fresh export after
the stream) is recorded, not gated; the bitwise oracle lives in
tests/test_serve_gnn.py.

Emits ``results/BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_serving.json")


def build(args):
    from repro.core import GPHyperParams, partition_graph
    from repro.engine import EngineConfig, SPMDEngine
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.serve import GNNServingEngine
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS[args.dataset])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels,
                        args.parts, method="ew", seed=args.seed)
    pg = build_partitioned_graph(g, r.parts, args.parts)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=64,
                      num_classes=g.num_classes)
    eng = SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3), pg,
                     GPHyperParams(),
                     EngineConfig(mode="stacked", use_pallas_agg=False))
    params = model.init(args.seed)
    srv = GNNServingEngine.from_engine(eng, pg, params)
    return g, pg, model, eng, params, srv


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products-s")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--updates-per-tick", type=int, default=4)
    ap.add_argument("--queries-per-tick", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g, pg, model, eng, params, srv = build(args)
    rng = np.random.default_rng(args.seed)

    def rand_updates(n):
        return {int(v): rng.normal(0, 1, g.feature_dim).astype(np.float32)
                for v in rng.choice(g.num_nodes, n, replace=False)}

    # warm the jitted recompute/gather kernels out of the timed region
    for gid, vec in rand_updates(args.updates_per_tick).items():
        srv.update_features(gid, vec)
    srv.submit(rng.choice(g.num_nodes, args.queries_per_tick, replace=False))
    srv.tick()

    # ---- request stream: p50/p99 tick latency + QPS --------------------
    lat = []
    t_wall = time.time()
    for _ in range(args.ticks):
        for gid, vec in rand_updates(args.updates_per_tick).items():
            srv.update_features(gid, vec)
        srv.submit(rng.choice(g.num_nodes, args.queries_per_tick,
                              replace=False))
        t0 = time.perf_counter()
        srv.tick()
        lat.append(time.perf_counter() - t0)
    wall = time.time() - t_wall
    qps = args.ticks * args.queries_per_tick / wall
    p50, p99 = np.percentile(lat, [50, 99])

    # ---- incremental vs full recompute on a small dirty set ------------
    # (best-of-3 each; full refresh re-runs every owned row through the
    # same flush machinery, so the ratio isolates dirty-set propagation)
    t_inc, t_full = [], []
    for _ in range(3):
        for gid, vec in rand_updates(args.updates_per_tick).items():
            srv.update_features(gid, vec)
        t0 = time.perf_counter()
        st_inc = srv.flush()
        t_inc.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        st_full = srv.refresh_full()
        t_full.append(time.perf_counter() - t0)
    speedup = min(t_full) / max(1e-9, min(t_inc))

    # served predictions vs a fresh export after the whole stream
    fresh = eng.export_serving_state(params)
    want = np.zeros(g.num_nodes, np.int64)
    for p in range(pg.num_parts):
        n = int(pg.n_own[p])
        want[np.asarray(pg.global_ids[p])[:n]] = \
            np.asarray(fresh["logits"][p])[:n].argmax(-1)
    # NOTE: the stream mutated features, so rebuild the engine's shards is
    # NOT what we compare against — export AFTER handing it the mutated
    # store is the serving engine's own state; instead check internal
    # consistency: query path == store path for a sample of nodes
    sample = rng.choice(g.num_nodes, 256, replace=False)
    preds_match = bool(
        (srv.predict(sample) == srv.export_logits()[sample].argmax(-1))
        .all())

    out = {"dataset": args.dataset, "parts": args.parts,
           "num_nodes": int(g.num_nodes), "ticks": args.ticks,
           "updates_per_tick": args.updates_per_tick,
           "queries_per_tick": args.queries_per_tick,
           "p50_tick_ms": round(float(p50) * 1e3, 2),
           "p99_tick_ms": round(float(p99) * 1e3, 2),
           "qps": round(float(qps), 1),
           "incremental_flush_s": round(min(t_inc), 4),
           "full_refresh_s": round(min(t_full), 4),
           "incremental_rows": st_inc["rows_recomputed"],
           "full_rows": st_full["rows_recomputed"],
           "incremental_speedup": round(float(speedup), 2),
           "speedup_gate_2x": bool(speedup >= 2.0),
           "preds_match": preds_match,
           "halo_rows_grown": srv.stats["halo_rows_grown"]}

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH)}")
    if not out["speedup_gate_2x"]:
        print("WARNING: incremental flush not >= 2x faster than full "
              "recompute")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
