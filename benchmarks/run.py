"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only tableX]

Prints CSV rows `table,key=value,...` per experiment (see each module).
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (fig1a_entropy_accuracy, fig3_convergence, kernels_micro,
               roofline, table2_overall, table3_scaling, table4_centralized,
               table5_partition_entropy)

MODULES = {
    "table5": table5_partition_entropy,
    "table2": table2_overall,
    "table3": table3_scaling,
    "table4": table4_centralized,
    "fig1a": fig1a_entropy_accuracy,
    "fig3": fig3_convergence,
    "kernels": kernels_micro,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(MODULES)
    for name in names:
        print(f"# ---- {name} ----", flush=True)
        t0 = time.time()
        try:
            MODULES[name].main()
        except Exception as e:  # noqa: BLE001
            print(f"{name},status=error,error={e!r}", file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
