"""Shared benchmark infra: cached EAT pipeline runs + CSV emission.

Each (dataset, method, parts, ablation) configuration runs once; results are
cached as JSON under results/bench_cache so Tables II/III/IV and Fig. 3 can
share runs.  Scales are the CPU-feasible stand-ins from graph/synthetic.py;
every emitted row carries the dataset name so the scale caveat is explicit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

from repro.pipeline import EATConfig, EATResult, run_eat_distgnn

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench_cache")

# benchmark-wide training scale (kept modest: single CPU core)
BENCH_EPOCHS = 24
BENCH_HIDDEN = 64
BENCH_BATCH = 256
BENCH_FANOUT = (5, 5)


# paper §IV: "For Flickr, we don't use the sampler" (too few nodes/epoch)
NO_CBS_DATASETS = {"flickr-s"}


def bench_config(dataset: str, *, method: str = "ew", parts: int = 4,
                 use_cbs: bool = True, use_gp: bool = True,
                 centralized: bool = False, seed: int = 0,
                 max_epochs: int | None = None) -> EATConfig:
    if dataset in NO_CBS_DATASETS:
        use_cbs = False
    if max_epochs is None:
        # a CBS "epoch" is a 25% mini-epoch — the paper runs the SAME epoch
        # count in both regimes (mini-epochs are simply ~4x cheaper), so CBS
        # configs get a proportionally larger epoch cap; early stopping and
        # the training-TIME metric keep the comparison honest
        max_epochs = BENCH_EPOCHS * 3 if use_cbs else BENCH_EPOCHS
    return EATConfig(
        dataset=dataset, num_parts=parts, partition_method=method,
        use_cbs=use_cbs, use_gp=use_gp, centralized=centralized,
        max_epochs=max_epochs, hidden_dim=BENCH_HIDDEN,
        batch_size=BENCH_BATCH, fanouts=BENCH_FANOUT, lr=3e-3, seed=seed,
    )


def _key(cfg: EATConfig) -> str:
    blob = json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def cached_run(cfg: EATConfig, verbose: bool = False) -> dict:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, _key(cfg) + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    result = run_eat_distgnn(cfg, verbose=verbose)
    payload = result.summary()
    payload["loss_history"] = result.loss_history
    payload["val_history"] = result.val_history
    payload["per_partition_micro"] = result.per_partition_micro.tolist()
    payload["partition_entropies"] = result.partition_entropies.tolist()
    with open(path, "w") as f:
        json.dump(payload, f)
    return payload


def emit(table: str, fields: dict) -> None:
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{table},{kv}")
