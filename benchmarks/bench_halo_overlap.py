"""Halo-overlap benchmark — the perf trajectory for PR 3.

Times the distributed full-graph forward (the eval hot path: per-layer halo
exchange + mean aggregation + dense transforms) through the SYNCHRONOUS
engine (exchange fully serialises before aggregation, dense compute over the
whole padded local space) against the OVERLAPPED boundary/interior split
forward (DESIGN.md §5: exchange issued first, interior aggregation + the
self-term matmul run while it is in flight, dense compute restricted to
owned rows, static degrees, no edge-mask multiply), on `products-s` at 4
and 8 partitions.

On the single-device stacked fallback the collectives carry no latency to
hide, so the measured win is the split layout's structural work reduction
(halo rows here are 70-85% of the padded local space).  On a real mesh the
exchange additionally overlaps the interior work:

    PYTHONPATH=src python benchmarks/bench_halo_overlap.py \
        --engine spmd --no-interpret

Emits ``results/BENCH_halo_overlap.json`` with per-config forward step
times, overlap/sync ratios, and the bytes each exchange moves (real halo
payload AND padded wire volume).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402
import numpy as np  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_halo_overlap.json")

MODES = {"sync": dict(overlap_halo=False),
         "overlap": dict(overlap_halo=True),
         "overlap_ring": dict(overlap_halo=True, ring_chunks=4)}


def build_case(dataset: str, parts: int, seed: int):
    from repro.core import partition_graph
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS[dataset])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, parts,
                        method="ew", seed=seed)
    pg = build_partitioned_graph(g, r.parts, parts)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=64,
                      num_classes=g.num_classes)
    return g, pg, model, model.make_loss_fn(), AdamW(lr=1e-3)


def make_forward_step(eng, params):
    """AOT-compile the engine's raw distributed forward (no metrics) in its
    own execution mode and return a timed callable."""
    from repro.engine import AXIS

    if eng.mode == "spmd":
        from jax.sharding import PartitionSpec as P

        from repro.engine.compat import shard_map_compat

        def shard_fn(prm, shard_s):
            sh = jax.tree.map(lambda x: x[0], shard_s)
            return eng.fwd(prm, sh)[None]

        fn = shard_map_compat(shard_fn, eng._mesh,
                              in_specs=(P(), P(AXIS)), out_specs=P(AXIS))
    else:
        def fn(prm, shards):
            return jax.vmap(eng.fwd, axis_name=AXIS,
                            in_axes=(None, 0))(prm, shards)

    compiled = jax.jit(fn).lower(params, eng.shards).compile()

    def step():
        jax.block_until_ready(compiled(params, eng.shards))

    return step


def time_step(step, repeats: int) -> dict:
    step()                                    # warm caches outside the window
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        step()
        times.append(time.perf_counter() - t0)
    return {"forward_s_median": round(float(np.median(times)), 5),
            "forward_s_mean": round(float(np.mean(times)), 5),
            "forward_s_min": round(float(np.min(times)), 5)}


def run_parts(args, parts: int) -> list[dict]:
    from repro.core import GPHyperParams
    from repro.engine import EngineConfig, SPMDEngine

    g, pg, model, loss_fn, opt = build_case(args.dataset, parts, args.seed)
    rows = []
    for mode, over_kw in MODES.items():
        cfg = EngineConfig(mode=args.engine, use_pallas_agg=args.pallas,
                           interpret=not args.no_interpret, **over_kw)
        eng = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(), cfg)
        params = model.init(args.seed)
        row = {"dataset": args.dataset, "parts": parts, "mode": mode,
               "engine": eng.mode, "pallas_agg": args.pallas,
               "interpret": not args.no_interpret,
               "max_nodes": pg.max_nodes, "own_cap": pg.own_cap,
               "n_int": pg.n_int.tolist(),
               "n_boundary": pg.n_boundary.tolist(),
               "halo_bytes_per_layer": pg.halo_bytes_per_layer,
               "padded_wire_bytes_per_exchange":
                   pg.padded_wire_bytes_per_exchange}
        row.update(time_step(make_forward_step(eng, params), args.repeats))
        print(json.dumps(row))
        rows.append(row)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products-s")
    ap.add_argument("--parts", type=int, nargs="*", default=[4, 8])
    ap.add_argument("--engine", default="stacked",
                    choices=("stacked", "spmd"),
                    help="stacked single-device fallback (default) or "
                         "shard_map over a partition mesh")
    ap.add_argument("--no-interpret", action="store_true",
                    help="compiled Pallas (real TPU mesh)")
    ap.add_argument("--pallas", action="store_true",
                    help="route aggregation through the Pallas kernel "
                         "(interpret mode is slow on CPU; default is the "
                         "jnp segment-op backend both sides)")
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.engine == "spmd":
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{max(args.parts)}").strip()

    rows = []
    for parts in args.parts:
        rows.extend(run_parts(args, parts))

    out = {"dataset": args.dataset, "engine": args.engine,
           "interpret": not args.no_interpret, "configs": rows}
    ok = True
    for parts in args.parts:
        sync = next(r for r in rows
                    if r["parts"] == parts and r["mode"] == "sync")
        for mode in ("overlap", "overlap_ring"):
            ovl = next(r for r in rows
                       if r["parts"] == parts and r["mode"] == mode)
            ratio = round(ovl["forward_s_median"]
                          / max(1e-9, sync["forward_s_median"]), 3)
            out[f"{mode}_vs_sync_{parts}p"] = ratio
            if mode == "overlap":
                out[f"overlap_below_0p9_{parts}p"] = ratio <= 0.9
                ok &= ratio <= 0.9

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps({k: v for k, v in out.items() if k != "configs"},
                     indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH)}")
    if not ok:
        print("WARNING: overlapped forward not <= 0.9x sync everywhere")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
