"""Roofline table from the dry-run JSON dumps (results/dryrun_*.json):
per (arch × shape × mesh) the three terms, the dominant bottleneck, and the
MODEL_FLOPS/HLO_FLOPs usefulness ratio."""
from __future__ import annotations

import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def rows(path: str):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def main() -> None:
    for fname, mesh in (("dryrun_1pod.json", "16x16"),
                        ("dryrun_2pod.json", "2x16x16")):
        for r in rows(os.path.join(RESULTS, fname)):
            if r.get("status") == "skipped":
                emit("roofline", {"mesh": mesh, "arch": r["arch"],
                                  "shape": r["shape"], "status": "skipped",
                                  "reason": r.get("reason", "")})
                continue
            if r.get("status") != "ok":
                emit("roofline", {"mesh": mesh, "arch": r.get("arch"),
                                  "shape": r.get("shape"), "status": "error"})
                continue
            emit("roofline", {
                "mesh": mesh, "arch": r["arch"], "shape": r["shape"],
                "variant": r.get("variant", "base"),
                "compute_s": f"{r['compute_s']:.3e}",
                "memory_s": f"{r['memory_s']:.3e}",
                "collective_s": f"{r['collective_s']:.3e}",
                "dominant": r["dominant"],
                "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
                "hbm_gb_per_chip": round(
                    (r.get("argument_bytes") or 0) / 1e9
                    + (r.get("temp_bytes") or 0) / 1e9, 2),
            })


if __name__ == "__main__":
    main()
