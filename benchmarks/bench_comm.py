"""Compressed-communication benchmark — the wire trajectory for PR 9.

Runs the full EAT pipeline on `products-s` with the communication layer in
four regimes:

  baseline       uncompressed: fp32 halo exchange rows, all_gather-spelled
                 gradient mean (P*(P-1)*B wire per sync);
  fp16_bucketed  fp16 halo quantization + bucketed ring all-reduce
                 (2*(P-1)*B per sync — 2/P of baseline);
  int8_bucketed  error-compensated int8 per-row halo quantization + the
                 same bucketed reduction — the PR's acceptance regime;
  int8_topk      int8 halo + top-k sparsified gradients with error
                 feedback (k = 1% of params as (value, index) pairs).

The acceptance gate (ISSUE 9): under int8_bucketed the reported
halo+gradient bytes/epoch must be <= 0.5x the uncompressed baseline AND
the final test micro-F1 within +-0.005 of the fp32 run, at 4 AND 8
partitions.  The fp16/top-k rows are recorded for the trade-off table,
not gated.

Emits ``results/BENCH_comm.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_comm.json")

MODES = {
    "baseline": dict(),
    "fp16_bucketed": dict(halo_compress="fp16", grad_compress="bucketed"),
    "int8_bucketed": dict(halo_compress="int8", grad_compress="bucketed"),
    "int8_topk": dict(halo_compress="int8", grad_compress="topk",
                      grad_topk_frac=0.01),
}


def run_parts(args, parts: int) -> list[dict]:
    from repro.pipeline import EATConfig, run_eat_distgnn

    rows = []
    for mode, comm_kw in MODES.items():
        cfg = EATConfig(dataset=args.dataset, num_parts=parts,
                        partition_method="ew", use_cbs=True, use_gp=False,
                        max_epochs=args.epochs, hidden_dim=64,
                        batch_size=128, fanouts=(5, 5), lr=3e-3,
                        seed=args.seed, use_pallas_agg=False,
                        async_generalize=True, **comm_kw)
        r = run_eat_distgnn(cfg)
        epochs = max(1, r.epochs_run)
        grad_pe = r.comm_grad_bytes / epochs
        halo_pe = float(np.mean(r.halo_exchange_history)) \
            if r.halo_exchange_history else 0.0
        row = {"dataset": args.dataset, "parts": parts, "mode": mode,
               "engine": r.engine_mode, "epochs_run": r.epochs_run,
               "halo_compress": cfg.halo_compress,
               "grad_compress": cfg.grad_compress,
               "grad_bytes_per_epoch": round(grad_pe, 1),
               "halo_exchange_bytes_per_epoch": round(halo_pe, 1),
               "wire_bytes_per_epoch": round(grad_pe + halo_pe, 1),
               "comm_grad_mb": round(r.comm_grad_bytes / 1e6, 3),
               "comm_halo_exchange_mb":
                   round(r.comm_halo_exchange_bytes / 1e6, 3),
               "test_micro": round(float(r.f1.micro), 4)}
        print(json.dumps(row))
        rows.append(row)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products-s")
    ap.add_argument("--parts", type=int, nargs="*", default=[4, 8])
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows = []
    for parts in args.parts:
        rows.extend(run_parts(args, parts))

    out = {"dataset": args.dataset, "epochs": args.epochs, "configs": rows}
    ok = True
    for parts in args.parts:
        base = next(r for r in rows
                    if r["parts"] == parts and r["mode"] == "baseline")
        for mode in ("fp16_bucketed", "int8_bucketed", "int8_topk"):
            c = next(r for r in rows
                     if r["parts"] == parts and r["mode"] == mode)
            ratio = round(c["wire_bytes_per_epoch"]
                          / max(1e-9, base["wire_bytes_per_epoch"]), 3)
            delta = round(c["test_micro"] - base["test_micro"], 4)
            out[f"{mode}_vs_baseline_{parts}p"] = ratio
            out[f"{mode}_micro_delta_{parts}p"] = delta
            if mode == "int8_bucketed":
                gate = ratio <= 0.5 and abs(delta) <= 0.005
                out[f"int8_bucketed_gate_{parts}p"] = gate
                ok &= gate

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps({k: v for k, v in out.items() if k != "configs"},
                     indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH)}")
    if not ok:
        print("WARNING: int8_bucketed failed the <=0.5x wire / +-0.005 "
              "micro-F1 gate somewhere")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
