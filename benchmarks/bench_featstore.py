"""Two-tier feature store benchmark — the memory/traffic trajectory for PR 10.

Runs the full EAT pipeline on `products-s` with the feature tier in four
regimes: all-resident baseline, and the two-tier store at hot_frac 0.5 /
0.25 / 0.1 (degree-ordered hot set, cold rows staged from the pinned host
store per compiled call).  Each row records the resident device feature
bytes, the cold-row host-to-device bytes per epoch, wall time per epoch,
and the final test micro-F1.

The acceptance gate (ISSUE 10): at hot_frac=0.25 the resident feature
bytes must be <= 0.5x the all-resident baseline AND the test micro-F1
within +-0.005 of it.  The 0.5/0.1 rows are recorded for the trade-off
table, not gated.

The second table is the bigger-than-device witness on `featstore-xl`
(wide features): with a device feature budget set BELOW the all-resident
footprint, the no-store run must refuse to build (FeatureBudgetError)
while `--feat-store --feat-groups 1` streams the eval partition-by-
partition under the same budget and trains end to end.

Emits ``results/BENCH_featstore.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_featstore.json")

HOT_FRACS = (0.5, 0.25, 0.1)


def run_products(args) -> list[dict]:
    from repro.pipeline import EATConfig, run_eat_distgnn

    rows = []
    for hot_frac in (None, *HOT_FRACS):
        kw = {} if hot_frac is None else dict(feat_store=True,
                                              hot_frac=hot_frac)
        cfg = EATConfig(dataset=args.dataset, num_parts=args.parts,
                        partition_method="ew", use_cbs=True, use_gp=False,
                        max_epochs=args.epochs, hidden_dim=64,
                        batch_size=128, fanouts=(5, 5), lr=3e-3,
                        seed=args.seed, use_pallas_agg=False,
                        async_generalize=True, **kw)
        t0 = time.monotonic()
        r = run_eat_distgnn(cfg)
        wall = time.monotonic() - t0
        epochs = max(1, r.epochs_run)
        row = {"dataset": args.dataset, "parts": args.parts,
               "mode": "all_resident" if hot_frac is None
               else f"feat_store_{hot_frac}",
               "hot_frac": hot_frac, "epochs_run": r.epochs_run,
               "resident_feature_bytes": int(r.resident_feature_bytes),
               "cold_h2d_bytes_per_epoch":
                   round(r.cold_h2d_bytes / epochs, 1),
               "cold_h2d_mb_total": round(r.cold_h2d_bytes / 1e6, 3),
               "epoch_time_s": round(wall / epochs, 3),
               "test_micro": round(float(r.f1.micro), 4)}
        print(json.dumps(row))
        rows.append(row)
    return rows


def run_bigger_than_stack(args) -> dict:
    """featstore-xl under a device feature budget below the all-resident
    footprint: no-store refuses to build, the streamed store trains."""
    from repro.core import partition_graph
    from repro.graph import (BENCHMARKS, build_partitioned_graph,
                             make_benchmark)
    from repro.graph.featstore import FeatureBudgetError, feat_peak_bytes
    from repro.pipeline import EATConfig, run_eat_distgnn

    g = make_benchmark(BENCHMARKS["featstore-xl"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels,
                        args.parts, method="ew", seed=args.seed)
    pg = build_partitioned_graph(g, r.parts, args.parts)
    base_peak = feat_peak_bytes(args.parts, pg.max_nodes, g.feature_dim, 4)
    budget_mb = base_peak * 0.7 / 1e6

    kw = dict(dataset="featstore-xl", num_parts=args.parts,
              partition_method="ew", use_cbs=True, use_gp=False,
              max_epochs=args.xl_epochs, hidden_dim=64, batch_size=128,
              fanouts=(5, 5), lr=3e-3, seed=args.seed,
              use_pallas_agg=False, async_generalize=False,
              feat_budget_mb=budget_mb)
    no_store_raises = False
    try:
        run_eat_distgnn(EATConfig(**kw))
    except FeatureBudgetError as e:
        no_store_raises = True
        print(json.dumps({"no_store_refused": str(e)[:160]}))

    t0 = time.monotonic()
    res = run_eat_distgnn(EATConfig(**kw, feat_store=True, hot_frac=0.25,
                                    feat_groups=1))
    wall = time.monotonic() - t0
    row = {"dataset": "featstore-xl", "parts": args.parts,
           "feat_budget_mb": round(budget_mb, 3),
           "all_resident_peak_mb": round(base_peak / 1e6, 3),
           "no_store_raises": no_store_raises,
           "store_epochs_run": res.epochs_run,
           "store_resident_feature_bytes": int(res.resident_feature_bytes),
           "store_cold_h2d_mb": round(res.cold_h2d_bytes / 1e6, 3),
           "store_wall_s": round(wall, 1),
           "store_test_micro": round(float(res.f1.micro), 4)}
    print(json.dumps(row))
    return row


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products-s")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--xl-epochs", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-xl", action="store_true")
    args = ap.parse_args()

    rows = run_products(args)
    out = {"dataset": args.dataset, "epochs": args.epochs, "configs": rows}

    base = next(r for r in rows if r["mode"] == "all_resident")
    ok = True
    for r in rows:
        if r["hot_frac"] is None:
            continue
        ratio = round(r["resident_feature_bytes"]
                      / max(1, base["resident_feature_bytes"]), 3)
        delta = round(r["test_micro"] - base["test_micro"], 4)
        out[f"resident_ratio_{r['hot_frac']}"] = ratio
        out[f"micro_delta_{r['hot_frac']}"] = delta
        if r["hot_frac"] == 0.25:
            gate = ratio <= 0.5 and abs(delta) <= 0.005
            out["featstore_gate_0.25"] = gate
            ok &= gate

    if not args.skip_xl:
        out["bigger_than_stack"] = run_bigger_than_stack(args)
        xl_ok = (out["bigger_than_stack"]["no_store_raises"]
                 and out["bigger_than_stack"]["store_epochs_run"] > 0)
        out["bigger_than_stack_gate"] = xl_ok
        ok &= xl_ok

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps({k: v for k, v in out.items() if k != "configs"},
                     indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH)}")
    if not ok:
        print("WARNING: feature store failed the <=0.5x resident / +-0.005 "
              "micro-F1 gate or the bigger-than-stack witness")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
