"""Table V: average entropy H(P) and time-to-partition across partitioning
algorithms (METIS vs EW, plus the RANDOM control), on three benchmarks."""
from __future__ import annotations

from repro.core import partition_graph
from repro.graph import BENCHMARKS, make_benchmark

from .common import emit

DATASETS = ("reddit-s", "yelp-s", "products-s")
METHODS = ("random", "metis", "ew", "ew_balanced")


def main() -> None:
    for ds in DATASETS:
        g = make_benchmark(BENCHMARKS[ds])
        for method in METHODS:
            r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                                method=method, seed=0)
            emit("table5", {
                "dataset": ds, "method": method,
                "H_P": round(r.stats.avg_entropy, 4),
                "var_H": round(r.stats.entropy_variance, 4),
                "edge_cut": r.stats.edge_cut,
                "weight_time_s": round(r.weight_time_s, 3),
                "partition_time_s": round(r.partition_time_s, 3),
                "total_time_s": round(r.total_time_s, 3),
            })


if __name__ == "__main__":
    main()
