"""Table II: DistDGL baseline (METIS, full epochs, no personalization) vs
EW+GP+CBS on each benchmark — micro/weighted F1, train time, speedup."""
from __future__ import annotations

from .common import bench_config, cached_run, emit

DATASETS = ("flickr-s", "reddit-s", "products-s", "papers-s")


def main() -> None:
    for ds in DATASETS:
        base = cached_run(bench_config(ds, method="metis", use_cbs=False,
                                       use_gp=False))
        ours = cached_run(bench_config(ds, method="ew", use_cbs=True,
                                       use_gp=True))
        speedup = (base["train_time_s"] / ours["train_time_s"]
                   if ours["train_time_s"] else float("nan"))
        emit("table2", {
            "dataset": ds,
            "baseline_micro": base["micro_f1"],
            "ours_micro": ours["micro_f1"],
            "baseline_weighted": base["weighted_f1"],
            "ours_weighted": ours["weighted_f1"],
            "baseline_train_s": base["train_time_s"],
            "ours_train_s": ours["train_time_s"],
            "speedup": round(speedup, 2),
            "micro_delta": round(ours["micro_f1"] - base["micro_f1"], 2),
        })


if __name__ == "__main__":
    main()
