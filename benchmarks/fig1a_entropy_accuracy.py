"""Fig. 1a: per-partition label entropy vs per-partition micro-F1 after
distributed training — the paper's motivating anti-correlation, with the
fitted regression slope."""
from __future__ import annotations

import numpy as np

from .common import bench_config, cached_run, emit


def main() -> None:
    cfg = bench_config("products-s", method="metis", parts=8,
                       use_cbs=True, use_gp=True)
    r = cached_run(cfg)
    ents = np.asarray(r["partition_entropies"])
    micro = np.asarray(r["per_partition_micro"]) * 100
    slope, intercept = np.polyfit(ents, micro, 1)
    corr = float(np.corrcoef(ents, micro)[0, 1])
    for p in range(len(ents)):
        emit("fig1a", {"partition": p, "entropy": round(float(ents[p]), 4),
                       "micro_f1": round(float(micro[p]), 2)})
    emit("fig1a_fit", {"slope": round(float(slope), 3),
                       "pearson_r": round(corr, 3),
                       "expected": "negative (higher entropy -> lower F1)"})


if __name__ == "__main__":
    main()
