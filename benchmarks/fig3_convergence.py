"""Fig. 3: convergence curves (training loss + validation micro-F1) for the
partitioning schemes; the personalization start is the paper's magenta line.
Emits one CSV row per epoch, plus the jump summary."""
from __future__ import annotations

import numpy as np

from .common import bench_config, cached_run, emit


def main() -> None:
    # flickr-s has a representative val split; products-s val saturates
    # (its OOD protocol trains/validates on head classes) — both recorded
    for ds in ("flickr-s", "products-s"):
        for method, gp in (("metis", False), ("ew", True)):
            r = cached_run(bench_config(ds, method=method, use_gp=gp,
                                        use_cbs=gp))
            label = "EW+GP(+CBS)" if gp else "DistDGL"
            for epoch, (l, v) in enumerate(zip(r["loss_history"],
                                               r["val_history"])):
                emit("fig3", {"dataset": ds, "curve": label, "epoch": epoch,
                              "loss": round(l, 4),
                              "val_micro": round(v * 100, 2),
                              "personalize_start": r["personalize_start"]})
            if gp and r["personalize_start"] > 0:
                ps = r["personalize_start"]
                pre = max(r["val_history"][:ps])
                post = max(r["val_history"][ps:])
                emit("fig3_jump", {
                    "dataset": ds,
                    "pre_personalization_best": round(pre * 100, 2),
                    "post_personalization_best": round(post * 100, 2),
                    "jump": round((post - pre) * 100, 2)})


if __name__ == "__main__":
    main()
