"""Async personalization benchmark — the perf trajectory for PR 2.

Compares phase-1 (personalization) between the lockstep baseline (host CBS
sampling + full-epoch `active` gating) and the async path (on-device CBS
draw + per-partition iteration budgets + masked variable-length scan) on
`products-s` at 4 and 8 partitions.

Emits ``results/BENCH_async_personalization.json`` with, per config:
epoch time (phase-0 mean and phase-1 per-epoch), phase-1 total step time
(the slowest host's cumulative personalization time — the paper's async
timing semantics), epochs-to-convergence, and final micro-F1.

    PYTHONPATH=src python benchmarks/bench_async.py
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import cached_run, emit  # noqa: E402

from repro.pipeline import EATConfig  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_async_personalization.json")

# modest single-CPU scale; a hard 25% phase split gives sync and async the
# IDENTICAL phase-0, so the comparison isolates the phase-1 mechanics.
# Eval runs the jnp segment-op path: on a CPU container the Pallas kernel
# is interpret-mode (slow python emulation) and eval cost is excluded from
# the step-time metrics being compared anyway.
BENCH_KW = dict(dataset="products-s", partition_method="ew", use_cbs=True,
                use_gp=True, max_epochs=20, hidden_dim=64, batch_size=256,
                fanouts=(5, 5), lr=3e-3, phase0_fraction=0.25, seed=0,
                use_pallas_agg=False)


def run_config(parts: int, async_p: bool) -> dict:
    cfg = EATConfig(num_parts=parts, async_personalize=async_p, **BENCH_KW)
    row = cached_run(cfg, verbose=True)
    keep = {k: row[k] for k in
            ("dataset", "method", "parts", "engine", "micro_f1", "macro_f1",
             "epoch_time_s", "epochs", "personalize_start",
             "phase1_time_s", "phase1_epochs", "train_time_s")}
    # bytes moved, not just seconds: the eval forward's per-layer halo
    # payload plus per-phase communication volume (grad all-reduce is
    # phase-0 only).  .get(): rows cached before these fields existed.
    for k in ("halo_bytes_per_layer", "comm_grad_mb", "comm_halo_mb",
              "comm_halo_phase0_mb", "comm_halo_phase1_mb"):
        keep[k] = row.get(k)
    keep["mode"] = "async" if async_p else "sync"
    keep["phase1_epoch_time_s"] = (
        round(row["phase1_time_s"] / max(1, row["phase1_epochs"]), 4))
    return keep


def main() -> int:
    rows = []
    for parts in (4, 8):
        for async_p in (False, True):
            r = run_config(parts, async_p)
            rows.append(r)
            emit("bench_async", r)

    out = {"dataset": "products-s", "configs": rows}
    for parts in (4, 8):
        sync = next(r for r in rows
                    if r["parts"] == parts and r["mode"] == "sync")
        asyn = next(r for r in rows
                    if r["parts"] == parts and r["mode"] == "async")
        out[f"phase1_speedup_{parts}p"] = round(
            sync["phase1_time_s"] / max(1e-9, asyn["phase1_time_s"]), 3)
        out[f"async_below_sync_{parts}p"] = (
            asyn["phase1_time_s"] < sync["phase1_time_s"])

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH)}")
    if not out["async_below_sync_8p"]:
        print("WARNING: async phase-1 not below lockstep at 8 partitions")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
