"""Async epoch benchmarks — the perf trajectory for PR 2 and PR 5.

Part 1 (PR 2) compares phase-1 (personalization) between the lockstep
baseline (host CBS sampling + full-epoch `active` gating) and the async
path (on-device CBS draw + per-partition iteration budgets + masked
variable-length scan) on `products-s` at 4 and 8 partitions.  Emits
``results/BENCH_async_personalization.json`` with, per config: epoch time
(phase-0 mean and phase-1 per-epoch), phase-1 total step time (the slowest
host's cumulative personalization time — the paper's async timing
semantics), epochs-to-convergence, and final micro-F1.

Part 2 (PR 5) compares phase-0 (generalization) between host sampling
(double-buffered NeighborSampler + the stacked-batch host→device transfer)
and the fused on-device path (``--async-generalize``: epoch draw + train
scan + validation eval in ONE device program).  Emits
``results/BENCH_async_generalization.json`` with per-config phase-0 epoch
step times AND the host→device payload per epoch — the transfer the device
sampler eliminates (a few PRNG-key bytes vs megabytes of stacked batches).
Note the async epoch time INCLUDES the fused eval (it is inseparable from
the one device call), while the host path's eval is excluded by the
pipeline's timing semantics — the reported async/host ratio is therefore
conservative.

    PYTHONPATH=src python benchmarks/bench_async.py
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import cached_run, emit  # noqa: E402

from repro.pipeline import EATConfig  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_async_personalization.json")
OUT_PATH_P0 = os.path.join(os.path.dirname(__file__), "..", "results",
                           "BENCH_async_generalization.json")

# modest single-CPU scale; a hard 25% phase split gives sync and async the
# IDENTICAL phase-0, so the comparison isolates the phase-1 mechanics.
# Eval runs the jnp segment-op path: on a CPU container the Pallas kernel
# is interpret-mode (slow python emulation) and eval cost is excluded from
# the step-time metrics being compared anyway.
BENCH_KW = dict(dataset="products-s", partition_method="ew", use_cbs=True,
                use_gp=True, max_epochs=20, hidden_dim=64, batch_size=256,
                fanouts=(5, 5), lr=3e-3, phase0_fraction=0.25, seed=0,
                use_pallas_agg=False)


def run_config(parts: int, async_p: bool) -> dict:
    cfg = EATConfig(num_parts=parts, async_personalize=async_p, **BENCH_KW)
    row = cached_run(cfg, verbose=True)
    keep = {k: row[k] for k in
            ("dataset", "method", "parts", "engine", "micro_f1", "macro_f1",
             "epoch_time_s", "epochs", "personalize_start",
             "phase1_time_s", "phase1_epochs", "train_time_s")}
    # bytes moved, not just seconds: the eval forward's per-layer halo
    # payload plus per-phase communication volume (grad all-reduce is
    # phase-0 only).  .get(): rows cached before these fields existed.
    for k in ("halo_bytes_per_layer", "comm_grad_mb", "comm_halo_mb",
              "comm_halo_phase0_mb", "comm_halo_phase1_mb"):
        keep[k] = row.get(k)
    keep["mode"] = "async" if async_p else "sync"
    keep["phase1_epoch_time_s"] = (
        round(row["phase1_time_s"] / max(1, row["phase1_epochs"]), 4))
    return keep


# phase-0 comparison: generalization only (no GP), so every epoch is a
# phase-0 epoch and the two regimes differ ONLY in where the epoch draw +
# batch materialisation run (host NumPy + transfer vs the fused device
# program)
P0_BENCH_KW = dict(dataset="products-s", partition_method="ew", use_cbs=True,
                   use_gp=False, max_epochs=6, hidden_dim=64, batch_size=256,
                   fanouts=(5, 5), lr=3e-3, seed=0, use_pallas_agg=False)


def run_phase0_config(parts: int, async_g: bool) -> dict:
    cfg = EATConfig(num_parts=parts, async_generalize=async_g, **P0_BENCH_KW)
    row = cached_run(cfg, verbose=True)
    keep = {k: row[k] for k in
            ("dataset", "method", "parts", "engine", "micro_f1",
             "epoch_time_s", "epochs", "train_time_s")}
    for k in ("epoch_time_with_eval_s", "phase0_iters_per_epoch",
              "host_to_device_mb_phase0", "comm_grad_mb",
              "comm_halo_phase0_mb"):
        keep[k] = row.get(k)
    keep["mode"] = "device" if async_g else "host"
    # the fused device call is inseparable from its validation eval, while
    # the host path's eval is a separate (excluded) call — so epoch_time_s
    # is conservative for the device path and epoch_time_with_eval_s (both
    # regimes pay their eval's 1/N share) is the apples-to-apples metric
    keep["step_time_includes_eval"] = bool(async_g)
    return keep


def bench_phase0() -> dict:
    rows = []
    for parts in (4, 8):
        for async_g in (False, True):
            r = run_phase0_config(parts, async_g)
            rows.append(r)
            emit("bench_async_generalization", r)
    out = {"dataset": "products-s", "configs": rows}
    for parts in (4, 8):
        host = next(r for r in rows
                    if r["parts"] == parts and r["mode"] == "host")
        dev = next(r for r in rows
                   if r["parts"] == parts and r["mode"] == "device")
        out[f"phase0_step_speedup_{parts}p"] = round(
            (host["epoch_time_with_eval_s"] or 0.0)
            / max(1e-9, dev["epoch_time_with_eval_s"] or 0.0), 3)
        out[f"phase0_step_speedup_train_only_{parts}p"] = round(
            host["epoch_time_s"] / max(1e-9, dev["epoch_time_s"]), 3)
        out[f"host_to_device_mb_saved_per_epoch_{parts}p"] = round(
            (host["host_to_device_mb_phase0"]
             - dev["host_to_device_mb_phase0"]) / max(1, host["epochs"]), 3)
    os.makedirs(os.path.dirname(OUT_PATH_P0), exist_ok=True)
    with open(OUT_PATH_P0, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH_P0)}")
    return out


def main() -> int:
    bench_phase0()

    rows = []
    for parts in (4, 8):
        for async_p in (False, True):
            r = run_config(parts, async_p)
            rows.append(r)
            emit("bench_async", r)

    out = {"dataset": "products-s", "configs": rows}
    for parts in (4, 8):
        sync = next(r for r in rows
                    if r["parts"] == parts and r["mode"] == "sync")
        asyn = next(r for r in rows
                    if r["parts"] == parts and r["mode"] == "async")
        out[f"phase1_speedup_{parts}p"] = round(
            sync["phase1_time_s"] / max(1e-9, asyn["phase1_time_s"]), 3)
        out[f"async_below_sync_{parts}p"] = (
            asyn["phase1_time_s"] < sync["phase1_time_s"])

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH)}")
    if not out["async_below_sync_8p"]:
        print("WARNING: async phase-1 not below lockstep at 8 partitions")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
