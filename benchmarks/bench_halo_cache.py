"""Historical halo-cache benchmark — the wire trajectory for PR 6.

Runs the full EAT pipeline on `products-s` with the eval-forward halo
exchange in three regimes:

  sync         every distributed eval pays the full two-layer exchange
               (2 * halo_bytes_per_layer per epoch);
  cache_k4     historical-embedding cache, full refresh every 4th eval,
               pure-cached evals in between ship ZERO halo bytes;
  cache_k4_cv  VR-GCN-style control-variate refresh: the same cadence, but
               the evals between full refreshes each re-ship one rotating
               chunk of the slot space (fresher rows, more wire than plain
               caching, still far less than always-exchange).

The acceptance gate: mean halo bytes/epoch under cache_k4 must be <= 0.5x
the always-exchange baseline at 4 AND 8 partitions (the refresh cadence
makes this structural: 2 refreshes in 6 epochs -> ~0.33x).  Final micro-F1
is recorded per regime so the wire saving is visibly not bought with
accuracy collapse.

Emits ``results/BENCH_halo_cache.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_halo_cache.json")

MODES = {"sync": dict(),
         "cache_k4": dict(halo_cache=True, halo_refresh_every=4),
         "cache_k4_cv": dict(halo_cache=True, halo_refresh_every=4,
                             halo_cv=True)}


def run_parts(args, parts: int) -> list[dict]:
    from repro.pipeline import EATConfig, run_eat_distgnn

    rows = []
    for mode, halo_kw in MODES.items():
        cfg = EATConfig(dataset=args.dataset, num_parts=parts,
                        partition_method="ew", use_cbs=True, use_gp=False,
                        max_epochs=args.epochs, hidden_dim=64,
                        batch_size=128, fanouts=(5, 5), lr=3e-3,
                        seed=args.seed, use_pallas_agg=False,
                        async_generalize=True, **halo_kw)
        r = run_eat_distgnn(cfg)
        hist = r.halo_exchange_history
        row = {"dataset": args.dataset, "parts": parts, "mode": mode,
               "engine": r.engine_mode, "epochs_run": r.epochs_run,
               "halo_bytes_per_layer": r.halo_bytes_per_layer,
               "halo_exchange_history": hist,
               "halo_bytes_per_epoch_mean": round(float(np.mean(hist)), 1),
               "comm_halo_exchange_mb": round(sum(hist) / 1e6, 3),
               "test_micro": round(float(r.f1.micro), 4)}
        print(json.dumps(row))
        rows.append(row)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products-s")
    ap.add_argument("--parts", type=int, nargs="*", default=[4, 8])
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows = []
    for parts in args.parts:
        rows.extend(run_parts(args, parts))

    out = {"dataset": args.dataset, "epochs": args.epochs, "configs": rows}
    ok = True
    for parts in args.parts:
        sync = next(r for r in rows
                    if r["parts"] == parts and r["mode"] == "sync")
        for mode in ("cache_k4", "cache_k4_cv"):
            c = next(r for r in rows
                     if r["parts"] == parts and r["mode"] == mode)
            ratio = round(c["halo_bytes_per_epoch_mean"]
                          / max(1e-9, sync["halo_bytes_per_epoch_mean"]), 3)
            out[f"{mode}_vs_sync_{parts}p"] = ratio
            out[f"{mode}_micro_delta_{parts}p"] = round(
                c["test_micro"] - sync["test_micro"], 4)
            if mode == "cache_k4":
                # the PR's acceptance gate; CV deliberately ships more wire
                # (fresher halo rows) so it is recorded, not gated
                out[f"cache_k4_below_0p5_{parts}p"] = ratio <= 0.5
                ok &= ratio <= 0.5

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps({k: v for k, v in out.items() if k != "configs"},
                     indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH)}")
    if not ok:
        print("WARNING: cached halo bytes/epoch not <= 0.5x sync everywhere")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
