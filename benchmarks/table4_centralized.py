"""Table IV: centralized GraphSAGE vs DistDGL vs EW+GP+CBS (micro-F1)."""
from __future__ import annotations

from .common import bench_config, cached_run, emit

DATASETS = ("flickr-s", "reddit-s", "products-s")


def main() -> None:
    for ds in DATASETS:
        central = cached_run(bench_config(ds, centralized=True, use_gp=False,
                                          use_cbs=False, method="metis"))
        base = cached_run(bench_config(ds, method="metis", use_cbs=False,
                                       use_gp=False))
        ours = cached_run(bench_config(ds, method="ew", use_cbs=True,
                                       use_gp=True))
        emit("table4", {
            "dataset": ds,
            "centralized_micro": central["micro_f1"],
            "distdgl_micro": base["micro_f1"],
            "ours_micro": ours["micro_f1"],
            "ours_beats_centralized": ours["micro_f1"] >= central["micro_f1"],
        })


if __name__ == "__main__":
    main()
