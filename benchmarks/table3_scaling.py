"""Table III: scaling with 4/8/16 partitions on the products stand-in —
training time, epoch time and micro-F1 for DistDGL vs EW+GP+CBS."""
from __future__ import annotations

from .common import bench_config, cached_run, emit


def main() -> None:
    for parts in (4, 8, 16):
        base = cached_run(bench_config("products-s", method="metis", parts=parts,
                                       use_cbs=False, use_gp=False))
        ours = cached_run(bench_config("products-s", method="ew", parts=parts,
                                       use_cbs=True, use_gp=True))
        emit("table3", {
            "parts": parts,
            "baseline_train_s": base["train_time_s"],
            "ours_train_s": ours["train_time_s"],
            "baseline_epoch_s": base["epoch_time_s"],
            "ours_epoch_s": ours["epoch_time_s"],
            "baseline_micro": base["micro_f1"],
            "ours_micro": ours["micro_f1"],
            "epoch_speedup": round(base["epoch_time_s"] /
                                   max(ours["epoch_time_s"], 1e-9), 2),
        })


if __name__ == "__main__":
    main()
