"""Full-graph training benchmark — the perf trajectory for the
differentiable-aggregation PR (DESIGN.md §6).

Times one full-batch phase-0 train step (``value_and_grad`` through the
distributed forward: per-layer halo exchange, blocked mean aggregation and
its transpose-blocked backward, cross-partition gradient mean, optimizer
update) with the aggregation routed through the Pallas custom-VJP op
(``kernel`` path) against the jnp segment-op fallback (``jnp`` path), on
the centralized (1-partition, Table IV) configuration and the partitioned
fleet.

On this CPU container the kernel path runs in Pallas INTERPRET mode, which
executes the kernel body in Python — the recorded kernel/jnp ratio is a
correctness-witnessed stand-in, not a speedup claim.  On a TPU mesh:

    PYTHONPATH=src python benchmarks/bench_fullgraph_grad.py \
        --engine spmd --no-interpret

Emits ``results/BENCH_fullgraph_train.json`` with per-config step times,
the kernel/jnp ratios, and trace evidence that BOTH the forward and the
backward Pallas kernels were staged on the differentiated path.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_fullgraph_train.json")


def build_case(dataset: str, parts: int, seed: int, hidden: int):
    from repro.core import partition_graph
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS[dataset])
    if parts == 1:
        parts_vec = np.zeros(g.num_nodes, dtype=np.int64)
    else:
        parts_vec = partition_graph(g.indptr, g.indices, g.features,
                                    g.labels, parts, method="ew",
                                    seed=seed).parts
    pg = build_partitioned_graph(g, parts_vec, parts)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=hidden,
                      num_classes=g.num_classes)
    return g, pg, model, model.make_loss_fn(), AdamW(lr=1e-3)


def time_fullgraph_steps(eng, model, seed: int, repeats: int):
    """phase0_fullgraph_epoch's returned dt is the compiled train-scan wall
    time only (AOT-compiled, eval excluded) — exactly the step metric."""
    params = model.init(seed)
    opt_state = eng.optimizer.init(params)
    eng.phase0_fullgraph_epoch(params, opt_state, iters=1)   # warm/AOT
    times = []
    for _ in range(repeats):
        params, opt_state, _, _, dt = eng.phase0_fullgraph_epoch(
            params, opt_state, iters=1)
        times.append(dt)
    return {"step_s_median": round(float(np.median(times)), 5),
            "step_s_mean": round(float(np.mean(times)), 5),
            "step_s_min": round(float(np.min(times)), 5)}


def run_parts(args, parts: int) -> list[dict]:
    from repro.core import GPHyperParams
    from repro.engine import EngineConfig, SPMDEngine
    from repro.kernels import segment_agg as sa

    g, pg, model, loss_fn, opt = build_case(args.dataset, parts, args.seed,
                                            args.hidden)
    rows = []
    for path, use_pallas in (("kernel", True), ("jnp", False)):
        cfg = EngineConfig(mode=args.engine, use_pallas_agg=use_pallas,
                           interpret=not args.no_interpret)
        eng = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(), cfg)
        before = sa.pallas_call_count()
        row = {"dataset": args.dataset, "parts": parts, "path": path,
               "engine": eng.mode, "interpret": not args.no_interpret,
               "num_nodes": g.num_nodes, "num_edges": g.num_edges,
               "max_nodes": pg.max_nodes,
               "halo_bytes_per_layer": pg.halo_bytes_per_layer}
        row.update(time_fullgraph_steps(eng, model, args.seed, args.repeats))
        row["pallas_calls_staged"] = sa.pallas_call_count() - before
        if path == "kernel":
            # 2 layers x (fwd + transpose bwd) in the grad trace + eval fwd
            assert row["pallas_calls_staged"] >= 5, row
        print(json.dumps(row))
        rows.append(row)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products-s")
    ap.add_argument("--parts", type=int, nargs="*", default=[1, 4],
                    help="1 = the centralized Table IV configuration")
    ap.add_argument("--engine", default="stacked",
                    choices=("stacked", "spmd"))
    ap.add_argument("--no-interpret", action="store_true",
                    help="compiled Pallas (real TPU mesh)")
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.engine == "spmd":
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{max(args.parts)}").strip()

    rows = []
    for parts in args.parts:
        rows.extend(run_parts(args, parts))

    out = {"dataset": args.dataset, "engine": args.engine,
           "interpret": not args.no_interpret, "configs": rows}
    for parts in args.parts:
        ker = next(r for r in rows
                   if r["parts"] == parts and r["path"] == "kernel")
        jnp_ = next(r for r in rows
                    if r["parts"] == parts and r["path"] == "jnp")
        out[f"kernel_vs_jnp_{parts}p"] = round(
            ker["step_s_median"] / max(1e-9, jnp_["step_s_median"]), 3)

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps({k: v for k, v in out.items() if k != "configs"},
                     indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
