"""Fault-tolerance benchmark — degraded-mode serving + recovery (PR 8).

Builds the serving engine from an ``SPMDEngine`` export on `products-s`
(P=4, stacked), then drives the same synthetic request stream as
``bench_serving.py`` through a scripted partition outage:

  healthy phase   — baseline p50/p99 tick latency and queries/s;
  degraded phase  — one partition failed: its queries answer from the
      frozen store with staleness tags, every update whose propagation
      cone touches it queues; p50/p99/QPS again (the whole point: the
      service keeps answering);
  recovery        — the partition comes back, the queued updates replay
      FIFO and flush in one tick; ``recovery_s`` is that tick's wall
      time, and the reconverged logits are checked BITWISE against a
      ``refresh_full()`` pass over the same store (the full-vs-
      incremental oracle).

Also records kill-and-resume behaviour of the training checkpointer on
the tiny benchmark: checkpoint save cost per epoch and resume-restart
cost (load + re-reaching the crashed epoch's state).

Emits ``results/BENCH_faults.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np  # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_faults.json")


def build(args):
    from repro.core import GPHyperParams, partition_graph
    from repro.engine import EngineConfig, SPMDEngine
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.serve import GNNServingEngine
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS[args.dataset])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels,
                        args.parts, method="ew", seed=args.seed)
    pg = build_partitioned_graph(g, r.parts, args.parts)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=64,
                      num_classes=g.num_classes)
    eng = SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3), pg,
                     GPHyperParams(),
                     EngineConfig(mode="stacked", use_pallas_agg=False))
    srv = GNNServingEngine.from_engine(eng, pg, model.init(args.seed))
    return g, srv


def drive(srv, g, rng, ticks, updates, queries):
    """Run the stream; returns (lat list, stale answers, queries asked)."""
    lat, stale = [], 0
    for _ in range(ticks):
        for v in rng.choice(g.num_nodes, updates, replace=False):
            srv.update_features(int(v), rng.normal(
                0, 1, g.feature_dim).astype(np.float32))
        srv.submit(rng.choice(g.num_nodes, queries, replace=False))
        t0 = time.perf_counter()
        _, st = srv.tick()
        lat.append(time.perf_counter() - t0)
        stale += len(st.get("staleness", {}))
    return lat, stale


def pctl(lat):
    p50, p99 = np.percentile(lat, [50, 99])
    return round(float(p50) * 1e3, 2), round(float(p99) * 1e3, 2)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products-s")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=12)
    ap.add_argument("--updates-per-tick", type=int, default=4)
    ap.add_argument("--queries-per-tick", type=int, default=32)
    ap.add_argument("--fail-partition", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g, srv = build(args)
    rng = np.random.default_rng(args.seed)
    U, Q = args.updates_per_tick, args.queries_per_tick

    # warm the jitted recompute/gather kernels out of the timed region
    drive(srv, g, rng, 2, U, Q)

    # ---- healthy baseline ----------------------------------------------
    t0 = time.time()
    lat_h, _ = drive(srv, g, rng, args.ticks, U, Q)
    qps_h = args.ticks * Q / (time.time() - t0)
    p50_h, p99_h = pctl(lat_h)

    # ---- degraded phase: one partition down ----------------------------
    srv.fail_partition(args.fail_partition)
    t0 = time.time()
    lat_d, stale = drive(srv, g, rng, args.ticks, U, Q)
    qps_d = args.ticks * Q / (time.time() - t0)
    p50_d, p99_d = pctl(lat_d)
    queued = srv.stats["updates_queued"]

    # ---- recovery: replay + flush in one tick --------------------------
    srv.recover_partition(args.fail_partition)
    t0 = time.perf_counter()
    srv.tick()
    recovery_s = time.perf_counter() - t0
    assert not srv._queue, "queue did not drain on recovery"

    # full-vs-incremental oracle: the replayed store must be bitwise a
    # from-scratch rematerialization of the same state
    inc = srv.export_logits()
    srv.refresh_full()
    reconverged = bool((inc == srv.export_logits()).all())

    # ---- training-side checkpoint/resume cost (tiny, f32 stacked) ------
    from repro.pipeline import EATConfig, run_eat_distgnn
    from repro.robustness import FaultPlan, InjectedCrash

    KW = dict(dataset="tiny", num_parts=4, batch_size=32, hidden_dim=16,
              fanouts=(3, 3), max_epochs=6, phase0_fraction=0.5,
              seed=args.seed, engine_mode="stacked")
    t0 = time.time()
    run_eat_distgnn(EATConfig(**KW))
    plain_s = time.time() - t0
    ck = tempfile.mkdtemp()
    t0 = time.time()
    try:
        run_eat_distgnn(EATConfig(**KW, checkpoint_dir=ck),
                        fault_plan=FaultPlan(crash_epochs=frozenset({4})))
    except InjectedCrash:
        pass
    crash_s = time.time() - t0
    t0 = time.time()
    res = run_eat_distgnn(EATConfig(**KW, checkpoint_dir=ck, resume=True))
    resume_s = time.time() - t0
    ckpt_bytes = sum(os.path.getsize(os.path.join(ck, n))
                     for n in os.listdir(ck))

    out = {"dataset": args.dataset, "parts": args.parts,
           "num_nodes": int(g.num_nodes), "ticks_per_phase": args.ticks,
           "updates_per_tick": U, "queries_per_tick": Q,
           "failed_partition": args.fail_partition,
           "healthy": {"p50_tick_ms": p50_h, "p99_tick_ms": p99_h,
                       "qps": round(float(qps_h), 1)},
           "degraded": {"p50_tick_ms": p50_d, "p99_tick_ms": p99_d,
                        "qps": round(float(qps_d), 1),
                        "stale_answers": int(stale),
                        "updates_queued": int(queued),
                        "replay_attempts": int(
                            srv.stats["replay_attempts"])},
           "recovery_s": round(float(recovery_s), 4),
           "replayed_updates": int(srv.stats["replayed"]),
           "reconverged_bitwise": reconverged,
           "train_resume": {
               "dataset": "tiny", "crash_epoch": 4,
               "uninterrupted_s": round(plain_s, 2),
               "run_to_crash_s": round(crash_s, 2),
               "resume_to_finish_s": round(resume_s, 2),
               "resumed_from_epoch": int(res.resumed_from_epoch),
               "checkpoint_dir_bytes": int(ckpt_bytes)}}

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(json.dumps(out, indent=2))
    print(f"wrote {os.path.normpath(OUT_PATH)}")
    if not reconverged:
        print("WARNING: post-recovery logits are not bitwise the full "
              "rematerialization")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
