"""End-to-end driver: distributed GNN training, the paper's headline
experiment (Table II row) at the largest CPU-feasible scale.

    PYTHONPATH=src python examples/distributed_gnn_training.py [--fast]

Runs the DistDGL-style baseline (METIS partitioning, plain epochs, pure
synchronous training) and EAT-DistGNN (EW + CBS + GP) on the OGBN-Products
stand-in with 4 logical hosts, then prints the head-to-head comparison the
paper reports: micro/weighted F1, training time, epoch time, and the
communication volumes.
"""
import argparse
import json

from repro.pipeline import EATConfig, run_eat_distgnn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller dataset")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=18)
    args = ap.parse_args()
    dataset = "tiny" if args.fast else "products-s"

    common = dict(dataset=dataset, num_parts=args.parts,
                  max_epochs=args.epochs, hidden_dim=64,
                  batch_size=256, fanouts=(8, 8), lr=3e-3)
    baseline = EATConfig(partition_method="metis", use_cbs=False,
                         use_gp=False, **common)
    ours = EATConfig(partition_method="ew", use_cbs=True, use_gp=True,
                     **common)

    print("== DistDGL baseline (METIS, no CBS, no GP) ==")
    rb = run_eat_distgnn(baseline, verbose=True)
    print("\n== EAT-DistGNN (EW + CBS + GP) ==")
    ro = run_eat_distgnn(ours, verbose=True)

    comparison = {
        "dataset": dataset,
        "baseline": rb.summary(),
        "eat_distgnn": ro.summary(),
        "micro_f1_delta": round(ro.f1.micro * 100 - rb.f1.micro * 100, 2),
        "speedup": round(rb.train_time_s / max(ro.train_time_s, 1e-9), 2),
    }
    print("\n" + json.dumps(comparison, indent=2))


if __name__ == "__main__":
    main()
