"""The paper's technique as a first-class LLM-framework feature.

    PYTHONPATH=src python examples/llm_entropy_sharding.py [--arch qwen2-0.5b]

Shards a domain-labelled corpus across data-parallel workers with the same
EW objective used for graphs (kNN doc-similarity graph + Algorithm-1
weights), trains a reduced zoo architecture through both GP phases, and
shows the per-shard domain specialisation that personalization buys:
each personalized replica beats the global model on ITS OWN shard's
held-out documents.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (GPHyperParams, broadcast_to_partitions,
                        make_personalize_step)
from repro.data import (CorpusSpec, DomainCorpus, ShardedBatcher,
                        shard_corpus_by_entropy)
from repro.models import Transformer
from repro.train.optim import AdamW, apply_updates


def eval_loss(model, params, corpus, docs) -> float:
    toks = jnp.asarray(corpus.tokens[docs])
    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full((len(docs), 1), -1, jnp.int32)], axis=1)
    return float(model.train_loss(params, {"tokens": toks, "labels": labels}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(d_model=128)
    model = Transformer(cfg)
    corpus = DomainCorpus(CorpusSpec(num_docs=480, doc_len=48,
                                     vocab_size=cfg.vocab_size,
                                     num_domains=8, seed=0))
    for method in ("random", "ew"):
        sh = shard_corpus_by_entropy(corpus, args.shards, method=method)
        print(f"{method:7s} shard domain entropies: "
              f"{sh.shard_entropies.round(3).tolist()}")
    shards = shard_corpus_by_entropy(corpus, args.shards, method="ew")
    batcher = ShardedBatcher(corpus, shards, batch_per_shard=8)

    # phase-0: synchronous generalization
    opt = AdamW(lr=3e-3, grad_clip=1.0)
    params = model.init(0)
    opt_state = opt.init(params)
    grad_fn = jax.jit(jax.value_and_grad(model.train_loss))

    @jax.jit
    def apply_grads(p, o, g):
        updates, o = opt.update(g, o, p)
        return apply_updates(p, updates), o

    for step in range(args.steps):
        nb = batcher.next_batch()
        acc = None
        for p in range(args.shards):
            _, g = grad_fn(params, {"tokens": jnp.asarray(nb["tokens"][p]),
                                    "labels": jnp.asarray(nb["labels"][p])})
            acc = g if acc is None else jax.tree.map(lambda a, b: a + b, acc, g)
        params, opt_state = apply_grads(
            params, opt_state, jax.tree.map(lambda g_: g_ / args.shards, acc))

    # phase-1: per-shard personalization
    pstep = jax.jit(make_personalize_step(model.train_loss, opt,
                                          GPHyperParams(lambda_prox=0.01)))
    pparams = broadcast_to_partitions(params, args.shards)
    popt = jax.vmap(opt.init)(pparams)
    active = jnp.ones((args.shards,), bool)
    for step in range(args.steps):
        nb = batcher.next_batch()
        pparams, popt, _ = pstep(pparams, popt,
                                 {"tokens": jnp.asarray(nb["tokens"]),
                                  "labels": jnp.asarray(nb["labels"])},
                                 params, active)

    # personalization wins on the shard's own held-out distribution
    rng = np.random.default_rng(1)
    print("\nshard  global-loss  personal-loss  (own held-out docs)")
    for p in range(args.shards):
        docs = shards.docs_of(p)
        held = rng.choice(docs, size=min(16, len(docs)), replace=False)
        lg = eval_loss(model, params, corpus, held)
        pp = jax.tree.map(lambda x: x[p], pparams)
        lp = eval_loss(model, pp, corpus, held)
        print(f"  {p}      {lg:7.4f}      {lp:7.4f}   "
              f"{'personalized wins' if lp < lg else ''}")


if __name__ == "__main__":
    main()
