"""Quickstart: the paper's three techniques in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Generate an imbalanced, homophilous graph (OGBN-Products stand-in).
2. Partition it with Algorithm 1 edge weights + weighted multilevel min-cut
   (EW) and compare the partition entropy against the METIS baseline.
3. Train distributed GraphSAGE with CBS sampling and GP two-phase training.
"""
import numpy as np

from repro.core import partition_graph
from repro.graph import BENCHMARKS, make_benchmark
from repro.pipeline import EATConfig, run_eat_distgnn


def main() -> None:
    graph = make_benchmark(BENCHMARKS["tiny"])
    print(graph.summary())

    # --- entropy-aware partitioning vs the baseline -----------------------
    for method in ("metis", "ew"):
        r = partition_graph(graph.indptr, graph.indices, graph.features,
                            graph.labels, 4, method=method, seed=0)
        print(f"{method:6s} avg-entropy={r.stats.avg_entropy:.4f} "
              f"edge-cut={r.stats.edge_cut} "
              f"partition-time={r.total_time_s:.2f}s")

    # --- full pipeline: EW + CBS + GP --------------------------------------
    cfg = EATConfig(dataset="tiny", num_parts=4, partition_method="ew",
                    use_cbs=True, use_gp=True, max_epochs=12,
                    hidden_dim=48, batch_size=128, fanouts=(5, 5), lr=3e-3)
    result = run_eat_distgnn(cfg, verbose=True)
    s = result.summary()
    print("\nEW+GP+CBS:", {k: s[k] for k in
                           ("micro_f1", "weighted_f1", "train_time_s",
                            "personalize_start")})


if __name__ == "__main__":
    main()
