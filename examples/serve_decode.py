"""Batched serving with KV caches — the serve_step the decode dry-run shapes
lower, running for real (reduced configs, CPU).

    PYTHONPATH=src python examples/serve_decode.py [--arch starcoder2-7b]

Demonstrates full-cache decode and the rolling sliding-window cache (the
long_500k mechanism) producing identical tokens when the context fits the
window.
"""
import argparse
from dataclasses import replace

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Transformer
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(0)
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.prefix_tokens:
        prompts["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.prefix_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.is_encoder_decoder:
        prompts["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)

    model = Transformer(cfg)
    params = model.init(0)

    engine = ServeEngine(model, params,
                         cache_size=args.prompt_len + args.new_tokens + 4)
    out = engine.generate(prompts, max_new_tokens=args.new_tokens)
    print(f"{cfg.name}: generated {out.shape} tokens")
    print(out)

    if cfg.family == "dense":
        # rolling cache (window >= context) must reproduce full-cache decode
        w = args.prompt_len + args.new_tokens + 4
        swa_cfg = replace(cfg, sliding_window=w)
        swa = ServeEngine(Transformer(swa_cfg), params, cache_size=w,
                          rolling=True)
        out_swa = swa.generate(prompts, max_new_tokens=args.new_tokens)
        match = bool((out == out_swa).all())
        print(f"rolling-cache decode matches full cache: {match}")
        assert match


if __name__ == "__main__":
    main()
