"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(11)


def _rand(shape, dtype):
    x = RNG.normal(0, 1, shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# ------------------------------------------------------------ segment_agg --

def _random_csr(n, max_deg, seed):
    rng = np.random.default_rng(seed)
    indptr = [0]
    indices = []
    for _ in range(n):
        k = int(rng.integers(0, max_deg + 1))
        indices.extend(rng.integers(0, n, k))
        indptr.append(indptr[-1] + k)
    return np.asarray(indptr), np.asarray(indices, dtype=np.int64)


@pytest.mark.parametrize("n,d,max_deg", [(64, 16, 4), (200, 48, 9), (300, 130, 6)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mean", [True, False])
def test_segment_agg_sweep(n, d, max_deg, dtype, mean):
    indptr, indices = _random_csr(n, max_deg, seed=n + max_deg)
    x = _rand((n, d), dtype)
    agg = ops.make_segment_agg(indptr, indices, mean=mean)
    got = agg(x)
    src = jnp.asarray(indices)
    dst = jnp.asarray(np.repeat(np.arange(n), np.diff(indptr)))
    want = ref.segment_agg_ref(x, src, dst, n, mean=mean)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_segment_agg_isolated_nodes():
    indptr = np.array([0, 0, 2, 2])
    indices = np.array([0, 2])
    x = _rand((3, 8), jnp.float32)
    agg = ops.make_segment_agg(indptr, indices, mean=True)
    out = agg(x)
    np.testing.assert_allclose(np.asarray(out[0]), 0.0)       # no in-edges
    np.testing.assert_allclose(np.asarray(out[1]),
                               np.asarray((x[0] + x[2]) / 2), rtol=1e-6)


# --------------------------------------------------------- flash_attention --

CASES = [
    # b, hq, hkv, sq, sk, dh, causal, window, q_off
    (2, 4, 2, 128, 128, 64, True, None, 0),
    (1, 8, 8, 200, 200, 32, True, None, 0),       # MHA, ragged seq
    (1, 4, 1, 96, 96, 64, True, None, 0),         # MQA
    (2, 4, 2, 256, 256, 64, True, 64, 0),         # sliding window
    (1, 4, 2, 1, 300, 64, True, None, 300),       # decode, ragged kv
    (1, 2, 2, 64, 64, 128, False, None, 0),       # encoder (bidirectional)
]


# Root cause of the 14 seed-time failures here: the kernel was written
# against the newer Pallas API name `pltpu.CompilerParams`, which jax 0.4.x
# ships as `pltpu.TPUCompilerParams` — every case died with AttributeError
# before any numerics ran (no tolerance problem; the math was never
# executed).  kernels/flash_attention.py now resolves whichever name the
# installed jax provides.
@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    b, hq, hkv, sq, sk, dh, causal, window, q_off = case
    q = _rand((b, hq, sq, dh), dtype)
    k = _rand((b, hkv, sk, dh), dtype)
    v = _rand((b, hkv, sk, dh), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_off, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_off)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_matches_model_chunked_attention():
    """The Pallas kernel and the model's pure-JAX chunked attention are
    twins: same math, different execution substrate."""
    from repro.models.layers import chunked_attention
    q = _rand((1, 4, 160, 64), jnp.float32)
    k = _rand((1, 2, 160, 64), jnp.float32)
    v = _rand((1, 2, 160, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    b = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------- rmsnorm --

@pytest.mark.parametrize("shape", [(4, 128), (3, 7, 512), (2, 5, 33, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = _rand(shape, dtype)
    w = _rand((shape[-1],), jnp.float32)
    got = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)
