"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes — including the custom VJP of the unified
aggregation op (``segment_mean_op``), whose backward must stage the
transpose-blocked kernel and match ``jax.grad`` of the jnp reference."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _jax_cache import CACHE_PRELUDE, REPO_ROOT
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)


def _rand(shape, dtype):
    x = RNG.normal(0, 1, shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# ------------------------------------------------------------ segment_agg --

def _random_csr(n, max_deg, seed):
    rng = np.random.default_rng(seed)
    indptr = [0]
    indices = []
    for _ in range(n):
        k = int(rng.integers(0, max_deg + 1))
        indices.extend(rng.integers(0, n, k))
        indptr.append(indptr[-1] + k)
    return np.asarray(indptr), np.asarray(indices, dtype=np.int64)


@pytest.mark.parametrize("n,d,max_deg", [(64, 16, 4), (200, 48, 9), (300, 130, 6)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mean", [True, False])
def test_segment_agg_sweep(n, d, max_deg, dtype, mean):
    indptr, indices = _random_csr(n, max_deg, seed=n + max_deg)
    x = _rand((n, d), dtype)
    agg = ops.make_segment_agg(indptr, indices, mean=mean)
    got = agg(x)
    src = jnp.asarray(indices)
    dst = jnp.asarray(np.repeat(np.arange(n), np.diff(indptr)))
    want = ref.segment_agg_ref(x, src, dst, n, mean=mean)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_segment_agg_isolated_nodes():
    indptr = np.array([0, 0, 2, 2])
    indices = np.array([0, 2])
    x = _rand((3, 8), jnp.float32)
    agg = ops.make_segment_agg(indptr, indices, mean=True)
    out = agg(x)
    np.testing.assert_allclose(np.asarray(out[0]), 0.0)       # no in-edges
    np.testing.assert_allclose(np.asarray(out[1]),
                               np.asarray((x[0] + x[2]) / 2), rtol=1e-6)


# -------------------------------------------------- segment_mean_op (VJP) --

def _edges_of(indptr, indices):
    n = len(indptr) - 1
    return (np.asarray(indices, np.int64),
            np.repeat(np.arange(n), np.diff(indptr)))


@pytest.mark.parametrize("n,d,max_deg", [(64, 16, 4), (300, 130, 6)])
@pytest.mark.parametrize("mean", [True, False])
def test_segment_mean_op_grad_matches_ref(n, d, max_deg, mean):
    """``jax.grad`` through the custom-VJP op == ``jax.grad`` through the
    jnp reference, on ragged CSR graphs including zero-degree rows."""
    indptr, indices = _random_csr(n, max_deg, seed=n + max_deg)
    src, dst = _edges_of(indptr, indices)
    x = _rand((n, d), jnp.float32)
    w = _rand((n, d), jnp.float32)
    agg = ops.make_segment_agg(indptr, indices, mean=mean)
    srcj, dstj = jnp.asarray(src), jnp.asarray(dst)
    g_op = jax.grad(lambda x: (agg(x) * w).sum())(x)
    g_ref = jax.grad(lambda x: (ref.segment_agg_ref(
        x, srcj, dstj, n, mean=mean) * w).sum())(x)
    np.testing.assert_allclose(np.asarray(g_op), np.asarray(g_ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("split_kind", ["mixed", "zero_range", "full_range"])
@pytest.mark.parametrize("mean", [True, False])
def test_segment_mean_op_rows_grad(split_kind, mean):
    """The row-range variant (traced ``row_base`` placement — the overlapped
    forward's boundary half) has the same VJP treatment: gradients match the
    jnp row-range oracle, including the empty (all-pad-block) range."""
    from repro.kernels.segment_agg import build_vjp_blocks, segment_mean_op

    rng = np.random.default_rng(5)
    n, d = 300, 24
    n_int = {"mixed": 141, "zero_range": n, "full_range": 0}[split_kind]
    rr = n - n_int
    deg = rng.integers(0, 6, rr) if rr else np.zeros(0, np.int64)
    rdst = np.repeat(np.arange(rr), deg)
    rsrc = rng.integers(0, n, int(deg.sum())).astype(np.int64)
    blocks = {k: jnp.asarray(v)
              for k, v in build_vjp_blocks(rsrc, rdst, rr, n).items()}
    x = _rand((n, d), jnp.float32)
    w = _rand((n, d), jnp.float32)
    f_op = lambda x: (segment_mean_op(
        x, blocks, num_rows=n, row_base=n_int, mean=mean) * w).sum()
    f_ref = lambda x: (ref.segment_agg_rows_ref(
        x, jnp.asarray(rsrc), jnp.asarray(rdst), max(1, rr), n_int, n,
        mean=mean) * w).sum()
    np.testing.assert_allclose(np.asarray(jax.grad(f_op)(x)),
                               np.asarray(jax.grad(f_ref)(x)),
                               atol=1e-5, rtol=1e-5)
    if split_kind == "zero_range":
        assert np.abs(np.asarray(jax.grad(f_op)(x))).max() == 0.0


def test_segment_mean_op_stages_fwd_and_bwd_kernels():
    """BOTH directions of the pass stage the Pallas kernel: the vjp's
    forward stages >= 1 call, applying the vjp stages >= 1 more (the
    transpose-blocked backward), and a ``jax.jit(jax.grad(...))`` trace
    stages both."""
    from repro.kernels import segment_agg as sa

    indptr, indices = _random_csr(100, 5, seed=3)
    agg = ops.make_segment_agg(indptr, indices, mean=True)
    x = _rand((100, 32), jnp.float32)

    before = sa.pallas_call_count()
    out, vjp = jax.vjp(agg, x)
    mid = sa.pallas_call_count()
    assert mid - before >= 1, "forward kernel never staged under jax.vjp"
    (gx,) = vjp(jnp.ones_like(out))
    after = sa.pallas_call_count()
    assert after - mid >= 1, "BACKWARD kernel never staged by the custom VJP"

    before = sa.pallas_call_count()
    jax.jit(jax.grad(lambda x: agg(x).sum())).lower(x)
    staged = sa.pallas_call_count() - before
    assert staged >= 2, f"expected fwd+bwd kernels in the grad trace, {staged}"


FP64_GRAD_SCRIPT = (
    CACHE_PRELUDE
    + "jax.config.update('jax_enable_x64', True)\n"
    + r"""
import numpy as np, jax.numpy as jnp
from jax.test_util import check_grads
from repro.kernels import ref
from repro.kernels.segment_agg import build_vjp_blocks, segment_mean_op

# NOTE on "fwd": forward-mode AD is undefined for jax.custom_vjp ops, so the
# forward direction is checked as bitwise primal equality against the fp64
# oracle (exact inputs — see below); "rev" runs numeric check_grads to
# SECOND order — the backward re-enters the custom VJP (transpose of the
# transpose), so grad-of-grad exercises the kernel too.
#
# "Bit-for-bit where exact": integer-valued features with POWER-OF-TWO
# degrees make every quantity dyadic — sums are exact in any order and the
# mean's divisions are exact — so kernel and oracle must agree to the last
# bit even though their reduction orders differ.  Non-dyadic degrees make
# the mean-mode GRADIENT order-dependent in the last ulp (each edge adds a
# rounded w/deg), which is what check_grads covers instead.
rng = np.random.default_rng(2)
n, d = 200, 16

def ragged_pow2_case(zero_frac, seed):
    r = np.random.default_rng(seed)
    deg = r.choice([1, 2, 4, 8], n)
    deg[r.random(n) < zero_frac] = 0          # zero-degree rows
    dst = np.repeat(np.arange(n), deg)
    src = r.integers(0, n, int(deg.sum())).astype(np.int64)
    return src, dst

for zero_frac, seed in ((0.25, 0), (0.9, 1)):
    src, dst = ragged_pow2_case(zero_frac, seed)
    blocks = {k: jnp.asarray(v) for k, v in build_vjp_blocks(src, dst, n, n).items()}
    xi = jnp.asarray(rng.integers(-8, 9, (n, d)).astype(np.float64))
    wi = jnp.asarray(rng.integers(-4, 5, (n, d)).astype(np.float64))
    xr = jnp.asarray(rng.normal(0, 1, (n, d)))
    for mean in (True, False):
        got = segment_mean_op(xi, blocks, num_rows=n, mean=mean)
        want = ref.segment_agg_ref(xi, jnp.asarray(src), jnp.asarray(dst), n, mean=mean)
        assert (np.asarray(got) == np.asarray(want)).all(), "fwd not bitwise"
        import jax
        g_op = jax.grad(lambda x: (segment_mean_op(x, blocks, num_rows=n, mean=mean) * wi).sum())(xi)
        g_rf = jax.grad(lambda x: (ref.segment_agg_ref(x, jnp.asarray(src), jnp.asarray(dst), n, mean=mean) * wi).sum())(xi)
        assert (np.asarray(g_op) == np.asarray(g_rf)).all(), "grad not bitwise"
        check_grads(lambda x: segment_mean_op(x, blocks, num_rows=n, mean=mean),
                    (xr,), order=2, modes=("rev",))

# row-range sub-ranges: block-unaligned offset AND the empty range whose
# structure is one all-pad block
for n_int in (137, n):
    rr = n - n_int
    deg = rng.integers(0, 5, rr) if rr else np.zeros(0, np.int64)
    rdst = np.repeat(np.arange(rr), deg)
    rsrc = rng.integers(0, n, int(deg.sum())).astype(np.int64)
    rb = {k: jnp.asarray(v) for k, v in build_vjp_blocks(rsrc, rdst, rr, n).items()}
    xr = jnp.asarray(rng.normal(0, 1, (n, d)))
    check_grads(lambda x: segment_mean_op(x, rb, num_rows=n, row_base=n_int),
                (xr,), order=2, modes=("rev",))
    if rr == 0:
        import jax
        g = jax.grad(lambda x: segment_mean_op(x, rb, num_rows=n, row_base=n_int).sum())(xr)
        assert np.abs(np.asarray(g)).max() == 0.0, "all-pad block leaked grad"
print("FP64_GRAD_OK")
"""
)


def test_segment_mean_op_fp64_check_grads():
    """fp64 gradient tier (subprocess: x64 must not leak): primal bitwise vs
    the fp64 oracle on exact inputs, bitwise grad parity, second-order
    ``check_grads`` on the ragged sweep, row-range sub-ranges and the
    all-pad block."""
    env = {"PYTHONPATH": os.path.join(REPO_ROOT, "src"),
           "PATH": "/usr/bin:/bin", "HOME": os.path.expanduser("~")}
    if "JAX_PLATFORMS" in os.environ:   # e.g. =cpu: skip accelerator probing
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    res = subprocess.run([sys.executable, "-c", FP64_GRAD_SCRIPT],
                         capture_output=True, text=True, timeout=1200,
                         env=env)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "FP64_GRAD_OK" in res.stdout


# --------------------------------------------------------- flash_attention --

CASES = [
    # b, hq, hkv, sq, sk, dh, causal, window, q_off
    (2, 4, 2, 128, 128, 64, True, None, 0),
    (1, 8, 8, 200, 200, 32, True, None, 0),       # MHA, ragged seq
    (1, 4, 1, 96, 96, 64, True, None, 0),         # MQA
    (2, 4, 2, 256, 256, 64, True, 64, 0),         # sliding window
    (1, 4, 2, 1, 300, 64, True, None, 300),       # decode, ragged kv
    (1, 2, 2, 64, 64, 128, False, None, 0),       # encoder (bidirectional)
]


# Root cause of the 14 seed-time failures here: the kernel was written
# against the newer Pallas API name `pltpu.CompilerParams`, which jax 0.4.x
# ships as `pltpu.TPUCompilerParams` — every case died with AttributeError
# before any numerics ran (no tolerance problem; the math was never
# executed).  kernels/flash_attention.py now resolves whichever name the
# installed jax provides.
@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    b, hq, hkv, sq, sk, dh, causal, window, q_off = case
    q = _rand((b, hq, sq, dh), dtype)
    k = _rand((b, hkv, sk, dh), dtype)
    v = _rand((b, hkv, sk, dh), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_off, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_off)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_matches_model_chunked_attention():
    """The Pallas kernel and the model's pure-JAX chunked attention are
    twins: same math, different execution substrate."""
    from repro.models.layers import chunked_attention
    q = _rand((1, 4, 160, 64), jnp.float32)
    k = _rand((1, 2, 160, 64), jnp.float32)
    v = _rand((1, 2, 160, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    b = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------- rmsnorm --

@pytest.mark.parametrize("shape", [(4, 128), (3, 7, 512), (2, 5, 33, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = _rand(shape, dtype)
    w = _rand((shape[-1],), jnp.float32)
    got = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)
