import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (CorpusSpec, DomainCorpus, ShardedBatcher,
                        shard_corpus_by_entropy)
from repro.train.checkpoint import CheckpointManager, load_pytree, save_pytree


@pytest.fixture(scope="module")
def corpus():
    return DomainCorpus(CorpusSpec(num_docs=300, doc_len=24, vocab_size=64,
                                   num_domains=6, seed=3))


def test_corpus_shapes(corpus):
    assert corpus.tokens.shape == (300, 24)
    assert corpus.tokens.max() < 64
    assert corpus.features.shape == (300, 32)
    assert set(np.unique(corpus.domains)) <= set(range(6))


def test_corpus_domain_imbalance(corpus):
    counts = np.bincount(corpus.domains, minlength=6)
    assert counts.max() > 2 * max(1, counts.min())


def test_entropy_sharding_beats_random(corpus):
    ew = shard_corpus_by_entropy(corpus, 4, method="ew")
    rnd = shard_corpus_by_entropy(corpus, 4, method="random")
    assert ew.shard_entropies.mean() < rnd.shard_entropies.mean()
    # every doc assigned
    assert sorted(np.concatenate([ew.docs_of(p) for p in range(4)]).tolist()) \
        == list(range(300))


def test_sharded_batcher(corpus):
    sh = shard_corpus_by_entropy(corpus, 4, method="ew")
    b = ShardedBatcher(corpus, sh, batch_per_shard=8).next_batch()
    assert b["tokens"].shape == (4, 8, 24)
    assert b["labels"].shape == (4, 8, 24)
    # labels are next-token-shifted with final -1
    assert (b["labels"][:, :, -1] == -1).all()
    np.testing.assert_array_equal(b["labels"][:, :, :-1], b["tokens"][:, :, 1:])


# ------------------------------------------------------------- checkpoint --

def test_pytree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)},
            "stack": [jnp.zeros(2), jnp.full((1,), 7.0)]}
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(path, tree, meta={"epoch": 3})
    back = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_manager_gp(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    params = {"w": jnp.ones((2, 2))}
    mgr.save_global(params, epoch=5, score=0.81)
    mgr.save_personal(2, jax.tree.map(lambda x: x * 3, params), epoch=9,
                      score=0.9)
    g = mgr.load_global(jax.tree.map(jnp.zeros_like, params))
    p2 = mgr.load_personal(2, jax.tree.map(jnp.zeros_like, params))
    assert float(g["w"][0, 0]) == 1.0
    assert float(p2["w"][0, 0]) == 3.0


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "x.npz")
    save_pytree(path, {"w": jnp.ones(3)})
    with pytest.raises(ValueError, match="shape mismatch"):
        load_pytree(path, {"w": jnp.zeros(4)})
