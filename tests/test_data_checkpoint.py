import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (CorpusSpec, DomainCorpus, ShardedBatcher,
                        shard_corpus_by_entropy)
from repro.train.checkpoint import CheckpointManager, load_pytree, save_pytree


@pytest.fixture(scope="module")
def corpus():
    return DomainCorpus(CorpusSpec(num_docs=300, doc_len=24, vocab_size=64,
                                   num_domains=6, seed=3))


def test_corpus_shapes(corpus):
    assert corpus.tokens.shape == (300, 24)
    assert corpus.tokens.max() < 64
    assert corpus.features.shape == (300, 32)
    assert set(np.unique(corpus.domains)) <= set(range(6))


def test_corpus_domain_imbalance(corpus):
    counts = np.bincount(corpus.domains, minlength=6)
    assert counts.max() > 2 * max(1, counts.min())


def test_entropy_sharding_beats_random(corpus):
    ew = shard_corpus_by_entropy(corpus, 4, method="ew")
    rnd = shard_corpus_by_entropy(corpus, 4, method="random")
    assert ew.shard_entropies.mean() < rnd.shard_entropies.mean()
    # every doc assigned
    assert sorted(np.concatenate([ew.docs_of(p) for p in range(4)]).tolist()) \
        == list(range(300))


def test_sharded_batcher(corpus):
    sh = shard_corpus_by_entropy(corpus, 4, method="ew")
    b = ShardedBatcher(corpus, sh, batch_per_shard=8).next_batch()
    assert b["tokens"].shape == (4, 8, 24)
    assert b["labels"].shape == (4, 8, 24)
    # labels are next-token-shifted with final -1
    assert (b["labels"][:, :, -1] == -1).all()
    np.testing.assert_array_equal(b["labels"][:, :, :-1], b["tokens"][:, :, 1:])


# ------------------------------------------------------------- checkpoint --

def test_pytree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)},
            "stack": [jnp.zeros(2), jnp.full((1,), 7.0)]}
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(path, tree, meta={"epoch": 3})
    back = load_pytree(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_manager_gp(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    params = {"w": jnp.ones((2, 2))}
    mgr.save_global(params, epoch=5, score=0.81)
    mgr.save_personal(2, jax.tree.map(lambda x: x * 3, params), epoch=9,
                      score=0.9)
    g = mgr.load_global(jax.tree.map(jnp.zeros_like, params))
    p2 = mgr.load_personal(2, jax.tree.map(jnp.zeros_like, params))
    assert float(g["w"][0, 0]) == 1.0
    assert float(p2["w"][0, 0]) == 3.0


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "x.npz")
    save_pytree(path, {"w": jnp.ones(3)})
    with pytest.raises(ValueError, match="shape mismatch"):
        load_pytree(path, {"w": jnp.zeros(4)})


def test_checkpoint_manager_best_model_bookkeeping(tmp_path):
    """update_global / update_personal persist only on strict improvement
    and report what they did; the stored payload is always the best seen."""
    mgr = CheckpointManager(str(tmp_path))
    like = {"w": jnp.zeros((2, 2))}

    assert mgr.update_global({"w": jnp.full((2, 2), 1.0)}, epoch=0,
                             score=0.5) is True
    assert mgr.update_global({"w": jnp.full((2, 2), 2.0)}, epoch=1,
                             score=0.5) is False        # ties don't replace
    assert mgr.update_global({"w": jnp.full((2, 2), 3.0)}, epoch=2,
                             score=0.4) is False        # worse doesn't either
    assert float(mgr.load_global(like)["w"][0, 0]) == 1.0
    meta = mgr.global_meta()
    assert meta["epoch"] == 0 and meta["score"] == 0.5
    assert mgr.update_global({"w": jnp.full((2, 2), 4.0)}, epoch=3,
                             score=0.6) is True
    assert float(mgr.load_global(like)["w"][0, 0]) == 4.0
    assert mgr.global_meta() == {"epoch": 3, "score": 0.6, "phase": 0}

    # personal tracks are independent per partition
    assert mgr.update_personal(0, {"w": jnp.full((2, 2), 7.0)}, epoch=4,
                               score=0.3) is True
    assert mgr.update_personal(1, {"w": jnp.full((2, 2), 8.0)}, epoch=4,
                               score=0.2) is True
    assert mgr.update_personal(0, {"w": jnp.full((2, 2), 9.0)}, epoch=5,
                               score=0.25) is False
    assert float(mgr.load_personal(0, like)["w"][0, 0]) == 7.0
    assert float(mgr.load_personal(1, like)["w"][0, 0]) == 8.0
    assert mgr.personal_meta(0)["score"] == 0.3


def test_checkpoint_fp64_bitwise_roundtrip(tmp_path):
    """fp64 payloads survive save/load with no widening or quantization:
    the raw 64-bit patterns are identical (numpy templates exercise the
    numpy-passthrough branch of load_pytree)."""
    rng = np.random.default_rng(0)
    tree = {"w": rng.standard_normal((16, 8)),          # float64
            "tiny": np.nextafter(np.zeros(4), 1.0),     # denormals
            "odd": np.array([np.pi, -0.0, np.inf, 1e-308])}
    path = os.path.join(tmp_path, "f64.npz")
    save_pytree(path, tree)
    back = load_pytree(path, {k: np.zeros_like(v) for k, v in tree.items()})
    for k in tree:
        assert back[k].dtype == np.float64
        np.testing.assert_array_equal(
            tree[k].view(np.uint64), np.asarray(back[k]).view(np.uint64))


def test_checkpoint_bf16_exact_payload(tmp_path):
    """bf16 is widened to f32 in the archive (npz has no bf16) and cast
    back on load; the round trip restores the EXACT 16-bit payload."""
    bits = np.arange(0, 1 << 16, 7, dtype=np.uint16)    # sweep bit patterns
    vals = jax.lax.bitcast_convert_type(jnp.asarray(bits),
                                        jnp.bfloat16)
    finite = np.isfinite(np.asarray(vals, np.float32))
    vals = jnp.where(jnp.asarray(finite), vals, jnp.bfloat16(0))
    tree = {"b": vals}
    path = os.path.join(tmp_path, "bf16.npz")
    save_pytree(path, tree)
    back = load_pytree(path, {"b": jnp.zeros_like(vals)})
    assert back["b"].dtype == jnp.bfloat16
    orig_bits = np.asarray(
        jax.lax.bitcast_convert_type(vals, jnp.uint16))
    back_bits = np.asarray(
        jax.lax.bitcast_convert_type(back["b"], jnp.uint16))
    np.testing.assert_array_equal(orig_bits, back_bits)
