"""PR-8 robustness: deterministic fault injection, checksummed
epoch-granular checkpoints, bitwise kill-and-resume, degraded serving.

1. :class:`FaultPlan` is a pure seeded schedule — same seed, same faults,
   including the corruption helpers' byte offsets.
2. ``save_pytree``/``load_pytree`` integrity: atomic writes leave no tmp
   droppings, a bit-flip raises :class:`CheckpointCorruptError` naming the
   offending entry, and template/archive key drift reports the FULL
   missing + unexpected sets in one :class:`CheckpointKeyError`.
3. :class:`RunCheckpointer`: last-K retention, manifest rebuild after a
   torn index write, and newest-valid fallback past corrupted archives.
4. Kill-and-resume parity (the tentpole contract): a run crashed by an
   injected fault at ANY epoch boundary and resumed from its checkpoint
   finishes with final params and val micro-F1 **bit-for-bit identical**
   to the uninterrupted run — f32 in-process here (phase-0 and phase-1
   crash points, halo cache on), fp64 in subprocesses for both the
   stacked and shard_map engines (``jax_enable_x64`` cannot leak).
5. Degraded serving: a failed partition's queries keep answering from its
   frozen store with staleness tags, updates touching its cone queue with
   bounded-backoff retry, and after recovery the FIFO replay reconverges
   bitwise against BOTH oracles (``refresh_full`` on the same engine and
   a fresh engine over ``apply_updates_to_graph``'s rebuilt graph).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from _jax_cache import CACHE_PRELUDE, REPO_ROOT

SUBPROC_ENV = {"PYTHONPATH": os.path.join(REPO_ROOT, "src"),
               "PATH": "/usr/bin:/bin", "HOME": os.path.expanduser("~")}


# --------------------------------------------------------------------------
# 1. FaultPlan determinism
# --------------------------------------------------------------------------

def test_fault_plan_random_deterministic():
    from repro.robustness import FaultPlan

    kw = dict(num_parts=4, max_epochs=20, serve_ticks=10,
              serve_fail_prob=0.3)
    a = FaultPlan.random(3, **kw)
    b = FaultPlan.random(3, **kw)
    assert a.crash_epochs == b.crash_epochs
    assert a.straggler == b.straggler
    assert a.drop_refresh_epochs == b.drop_refresh_epochs
    assert a.serve_fail == b.serve_fail and a.serve_recover == b.serve_recover
    c = FaultPlan.random(4, **kw)
    assert (a.crash_epochs, a.straggler, a.drop_refresh_epochs) != \
           (c.crash_epochs, c.straggler, c.drop_refresh_epochs)


def test_fault_plan_straggler_vector_and_queries():
    from repro.robustness import FaultPlan

    plan = FaultPlan(crash_epochs=frozenset({2}),
                     straggler={1: {0: 0.5, 3: 1.5}},
                     drop_refresh_epochs=frozenset({4}),
                     serve_fail={2: (1,)}, serve_recover={5: (1,)})
    assert plan.crash_at(2) and not plan.crash_at(1)
    np.testing.assert_array_equal(plan.straggler_delay(1, 4),
                                  [0.5, 0.0, 0.0, 1.5])
    assert plan.straggler_delay(0, 4).sum() == 0.0
    assert plan.drop_halo_refresh(4) and not plan.drop_halo_refresh(3)
    assert plan.serve_events(2) == [("fail", 1)]
    assert plan.serve_events(5) == [("recover", 1)]
    assert plan.serve_events(3) == []


def test_fault_plan_corrupt_offsets_deterministic(tmp_path):
    from repro.robustness import FaultPlan

    payload = bytes(range(256)) * 40
    p1, p2 = tmp_path / "ck.npz", tmp_path / "same_name"
    os.mkdir(p2)
    p2 = p2 / "ck.npz"
    p1.write_bytes(payload)
    p2.write_bytes(payload)
    plan = FaultPlan(seed=9)
    info1 = plan.corrupt(str(p1))
    info2 = plan.corrupt(str(p2))
    assert info1 == info2                       # offset is seed+name+size pure
    assert p1.read_bytes() == p2.read_bytes() != payload
    tr = plan.corrupt(str(p1), mode="truncate")
    assert tr["kept_bytes"] < tr["orig_bytes"]
    assert os.path.getsize(p1) == tr["kept_bytes"]


# --------------------------------------------------------------------------
# 2. save_pytree / load_pytree integrity
# --------------------------------------------------------------------------

def _small_tree():
    return {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"w": np.ones((2, 2), np.float64)}}


def test_save_pytree_atomic_no_tmp_left(tmp_path):
    from repro.train.checkpoint import load_pytree, save_pytree

    path = str(tmp_path / "t.npz")
    save_pytree(path, _small_tree())
    assert not [n for n in os.listdir(tmp_path) if ".tmp." in n]
    out = load_pytree(path, _small_tree())
    np.testing.assert_array_equal(out["a"], _small_tree()["a"])
    assert out["b"]["w"].dtype == np.float64


def test_crc_mismatch_names_offending_entry(tmp_path):
    from repro.train.checkpoint import (CheckpointCorruptError, load_pytree,
                                        save_pytree)

    path = str(tmp_path / "t.npz")
    save_pytree(path, _small_tree())
    mp = path + ".meta.json"
    with open(mp) as f:
        doc = json.load(f)
    doc["crc32"]["a"] ^= 1                      # silent-corruption model
    with open(mp, "w") as f:
        json.dump(doc, f)
    with pytest.raises(CheckpointCorruptError, match="entry 'a'.*crc32"):
        load_pytree(path, _small_tree())


def test_bitflipped_archive_raises_corrupt_error(tmp_path):
    import struct
    import zipfile

    from repro.robustness import flip_bit
    from repro.train.checkpoint import (CheckpointCorruptError, load_pytree,
                                        save_pytree)

    path = str(tmp_path / "t.npz")
    save_pytree(path, _small_tree())
    with zipfile.ZipFile(path) as z:            # locate entry 'a's payload
        zi = z.getinfo("a.npy")
    with open(path, "rb") as f:
        f.seek(zi.header_offset + 26)
        nlen, elen = struct.unpack("<HH", f.read(4))
    data_start = zi.header_offset + 30 + nlen + elen
    flip_bit(path, data_start + zi.file_size - 4)   # lands in array bytes
    with pytest.raises(CheckpointCorruptError, match="entry 'a'"):
        load_pytree(path, _small_tree())


def test_key_mismatch_reports_both_sets(tmp_path):
    from repro.train.checkpoint import (CheckpointKeyError, load_pytree,
                                        save_pytree)

    path = str(tmp_path / "t.npz")
    save_pytree(path, {"a": np.ones(2), "b": np.ones(2)})
    bad_template = {"b": np.ones(2), "c": np.ones(2)}
    with pytest.raises(CheckpointKeyError) as ei:
        load_pytree(path, bad_template)
    msg = str(ei.value)
    assert "missing" in msg and "'c'" in msg     # template wants, archive lacks
    assert "unexpected" in msg and "'a'" in msg  # archive has, template lacks


# --------------------------------------------------------------------------
# 3. RunCheckpointer retention / fallback
# --------------------------------------------------------------------------

def _run_ck(tmp_path, **kw):
    from repro.robustness import RunCheckpointer

    return RunCheckpointer(str(tmp_path / "ck"), **kw)


def _arrays(step):
    return {"p": np.full((3,), float(step)), "o": np.arange(4) + step}


def test_run_checkpointer_retention(tmp_path):
    ck = _run_ck(tmp_path, keep_last=3)
    for s in range(1, 6):
        ck.save(s, _arrays(s), {"epoch": s})
    assert ck.steps() == [3, 4, 5]
    assert ck.latest_step() == 5
    on_disk = sorted(n for n in os.listdir(ck.dir) if n.endswith(".npz"))
    assert on_disk == ["ckpt_000003.npz", "ckpt_000004.npz",
                       "ckpt_000005.npz"]
    assert ck.peek(4) == {"epoch": 4}
    arrays, host = ck.load(4, _arrays(0))
    assert host == {"epoch": 4}
    np.testing.assert_array_equal(arrays["p"], [4.0, 4.0, 4.0])


def test_run_checkpointer_falls_back_past_corruption(tmp_path):
    from repro.robustness import FaultPlan
    from repro.train.checkpoint import CheckpointCorruptError

    ck = _run_ck(tmp_path, keep_last=3)
    for s in range(1, 4):
        ck.save(s, _arrays(s), {"epoch": s})
    FaultPlan(seed=2).corrupt(ck._npz(3))        # newest archive damaged
    arrays, host, step = ck.load_latest(lambda h: _arrays(0))
    assert step == 2 and host == {"epoch": 2}
    np.testing.assert_array_equal(arrays["p"], [2.0, 2.0, 2.0])
    for s in (1, 2):                             # now everything is corrupt
        from repro.robustness import truncate_file
        truncate_file(ck._npz(s), 0.3)
    with pytest.raises(CheckpointCorruptError, match="no valid checkpoint"):
        ck.load_latest(lambda h: _arrays(0))


def test_run_checkpointer_rebuilds_torn_manifest(tmp_path):
    ck = _run_ck(tmp_path, keep_last=5)
    for s in (1, 2):
        ck.save(s, _arrays(s), {"epoch": s})
    with open(ck._manifest_path(), "w") as f:
        f.write('{"steps": [1, 2')                # torn mid-write
    assert ck.steps() == [1, 2]                   # rebuilt from the archives
    _, host, step = ck.load_latest(lambda h: _arrays(0))
    assert step == 2


def test_load_latest_empty_dir_returns_none(tmp_path):
    assert _run_ck(tmp_path).load_latest(lambda h: _arrays(0)) is None


# --------------------------------------------------------------------------
# 4a. f32 in-process kill-and-resume parity (stacked, halo cache on)
# --------------------------------------------------------------------------

_PIPE_KW = dict(dataset="tiny", num_parts=4, batch_size=32, hidden_dim=16,
                fanouts=(3, 3), max_epochs=6, phase0_fraction=0.5, seed=7,
                engine_mode="stacked", halo_cache=True, halo_refresh_every=2)


@pytest.fixture(scope="module")
def baseline_run():
    from repro.pipeline import EATConfig, run_eat_distgnn

    return run_eat_distgnn(EATConfig(**_PIPE_KW))


def _tree_equal(a, b):
    import jax

    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    return ta == tb and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _crash_and_resume(tmp_path, crash_epoch, baseline):
    from repro.pipeline import EATConfig, run_eat_distgnn
    from repro.robustness import FaultPlan, InjectedCrash

    ck = str(tmp_path / "ck")
    with pytest.raises(InjectedCrash):
        run_eat_distgnn(EATConfig(**_PIPE_KW, checkpoint_dir=ck),
                        fault_plan=FaultPlan(
                            crash_epochs=frozenset({crash_epoch})))
    res = run_eat_distgnn(EATConfig(**_PIPE_KW, checkpoint_dir=ck,
                                    resume=True))
    assert res.resumed_from_epoch == crash_epoch
    assert _tree_equal(res.final_params, baseline.final_params), \
        "resumed final params are not bitwise the uninterrupted run's"
    assert res.f1.micro == baseline.f1.micro
    assert res.val_history == baseline.val_history
    assert res.loss_history == baseline.loss_history


def test_resume_parity_phase0_crash(tmp_path, baseline_run):
    _crash_and_resume(tmp_path, 1, baseline_run)


def test_resume_parity_phase1_crash(tmp_path, baseline_run):
    _crash_and_resume(tmp_path, 4, baseline_run)


def test_straggler_and_dropped_refresh_leave_numerics_alone(baseline_run):
    from repro.pipeline import EATConfig, run_eat_distgnn
    from repro.robustness import FaultPlan

    plan = FaultPlan(straggler={1: {2: 0.75}},
                     drop_refresh_epochs=frozenset({2}))
    res = run_eat_distgnn(EATConfig(**_PIPE_KW), fault_plan=plan)
    assert _tree_equal(res.final_params, baseline_run.final_params)
    assert res.straggler_delay_s == 0.75
    # epoch 2 would have paid a full refresh (age % 2 == 0): the dropped
    # payload shows up as zero exchanged bytes, the cache serves stale
    assert baseline_run.halo_exchange_history[2] > 0
    assert res.halo_exchange_history[2] == 0
    assert res.halo_exchange_history[4] == baseline_run.halo_exchange_history[4]


def test_resume_refuses_mismatched_fingerprint(tmp_path):
    from repro.pipeline import EATConfig, run_eat_distgnn
    from repro.robustness import FaultPlan, InjectedCrash

    ck = str(tmp_path / "ck")
    with pytest.raises(InjectedCrash):
        run_eat_distgnn(EATConfig(**_PIPE_KW, checkpoint_dir=ck),
                        fault_plan=FaultPlan(crash_epochs=frozenset({1})))
    other = dict(_PIPE_KW, seed=8)
    with pytest.raises(ValueError, match="refusing to resume"):
        run_eat_distgnn(EATConfig(**other, checkpoint_dir=ck, resume=True))


def test_engine_drop_next_halo_refresh_plan():
    import jax.numpy as jnp
    from repro.core import GPHyperParams, partition_graph
    from repro.engine import EngineConfig, SPMDEngine
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS["tiny"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                        method="ew", seed=0)
    pg = build_partitioned_graph(g, r.parts, 4)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes)
    eng = SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3), pg,
                     GPHyperParams(),
                     EngineConfig(mode="stacked", use_pallas_agg=False,
                                  halo_cache=True, halo_refresh_every=2))
    assert eng._halo_plan() != (0, 0)            # age 0 → full refresh due
    eng.drop_next_halo_refresh()
    assert eng._halo_plan() == (0, 0)            # payload lost in transit
    assert eng.halo_refresh_drops == 1
    assert eng._halo_plan() != (0, 0)            # one-shot: next is normal
    st = eng.halo_cache_state()
    assert st is not None and st[1] == 0
    eng.restore_halo_cache_state(st[0], 5)
    assert eng.halo_cache_state()[1] == 5


# --------------------------------------------------------------------------
# 4b. fp64 kill-and-resume parity (subprocess; stacked AND shard_map)
# --------------------------------------------------------------------------

_FP64_RESUME_BODY = """
import json, os, tempfile
import numpy as np
from repro.pipeline import EATConfig, run_eat_distgnn
from repro.robustness import FaultPlan, InjectedCrash

KW = dict(dataset="tiny", num_parts=4, batch_size=32, hidden_dim=16,
          fanouts=(3, 3), max_epochs=6, phase0_fraction=0.5, seed=7,
          engine_mode=MODE, halo_cache=True, halo_refresh_every=2,
          dtype="float64")
base = run_eat_distgnn(EATConfig(**KW))
leaves_a = jax.tree.leaves(base.final_params)
out = {}
for crash in (1, 4):                 # a phase-0 and a phase-1 boundary
    ck = tempfile.mkdtemp()
    try:
        run_eat_distgnn(EATConfig(**KW, checkpoint_dir=ck),
                        fault_plan=FaultPlan(
                            crash_epochs=frozenset({crash})))
        raise AssertionError("fault did not fire")
    except InjectedCrash:
        pass
    res = run_eat_distgnn(EATConfig(**KW, checkpoint_dir=ck, resume=True))
    leaves_b = jax.tree.leaves(res.final_params)
    md = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
             for a, b in zip(leaves_a, leaves_b))
    out[f"crash{crash}"] = {
        "resumed_from": res.resumed_from_epoch,
        "params_maxdiff": md,
        "f1_equal": bool(res.f1.micro == base.f1.micro),
        "val_hist_equal": bool(res.val_history == base.val_history)}
print("RESULTS " + json.dumps(out))
"""


def _run_fp64_resume(mode, extra_env=None):
    script = (CACHE_PRELUDE
              + "import jax\njax.config.update('jax_enable_x64', True)\n"
              + f"MODE = {mode!r}\n" + _FP64_RESUME_BODY)
    env = dict(SUBPROC_ENV, **(extra_env or {}))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=1800,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def _check_fp64_resume(out):
    for crash, r in out.items():
        assert r["params_maxdiff"] == 0.0, (crash, r)
        assert r["f1_equal"] and r["val_hist_equal"], (crash, r)
    assert out["crash1"]["resumed_from"] == 1
    assert out["crash4"]["resumed_from"] == 4


@pytest.mark.slow
def test_fp64_resume_bitwise_stacked():
    _check_fp64_resume(_run_fp64_resume("stacked"))


@pytest.mark.slow
def test_fp64_resume_bitwise_spmd():
    _check_fp64_resume(_run_fp64_resume(
        "spmd",
        extra_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=4"}))


# --------------------------------------------------------------------------
# 5. degraded-mode serving
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_parts():
    """Graph + partition assignment + a builder for FRESH serving engines
    (each degradation test mutates its own engine)."""
    import jax.numpy as jnp
    from repro.core import GPHyperParams, partition_graph
    from repro.engine import EngineConfig, SPMDEngine
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.serve import GNNServingEngine
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS["tiny"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                        method="ew", seed=0)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes)
    prm = model.init(0)
    cfg = EngineConfig(mode="stacked", use_pallas_agg=False,
                       dtype=jnp.float32)

    def build(graph=None):
        pg = build_partitioned_graph(graph if graph is not None else g,
                                     r.parts, 4)
        eng = SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3), pg,
                         GPHyperParams(), cfg)
        return GNNServingEngine(model, prm, pg,
                                eng.export_serving_state(prm))

    owned = [np.where(build().owner_part == p)[0].astype(int)
             for p in range(4)]
    return g, build, owned


def test_degraded_queries_staleness_and_frozen_store(serve_parts):
    g, build, owned = serve_parts
    srv = build()
    gid = int(owned[1][0])
    row = int(srv.owner_row[gid])
    frozen = srv.h[0][1][row].copy()

    srv.fail_partition(1)
    vec = np.full(g.feature_dim, 3.5, np.float32)
    srv.update_features(gid, vec)
    assert srv.stats["updates_queued"] == 1
    np.testing.assert_array_equal(srv.h[0][1][row], frozen)  # applied nowhere

    srv.submit([gid, int(owned[0][0])])
    results, st = srv.tick()
    assert gid in results                        # still answered, from frozen
    assert st["staleness"] == {gid: 1}           # failed 1 tick ago
    assert st["health"][1] == "failed"
    assert srv.stats["degraded_queries"] == 1
    srv.tick()
    srv.submit([gid])
    _, st3 = srv.tick()
    assert st3["staleness"][gid] == 3            # age grows per tick

    # updates NOT touching the failed cone still apply immediately
    far = None
    for cand in owned[0]:
        srv2_probe = srv._should_queue_feat(int(cand))
        if not srv2_probe:
            far = int(cand)
            break
    if far is not None:
        before = srv.stats["updates_queued"]
        srv.update_features(far, np.zeros(g.feature_dim, np.float32))
        assert srv.stats["updates_queued"] == before
    with pytest.raises(RuntimeError, match="healthy"):
        srv.refresh_full()


def test_flaky_partition_retry_backoff_and_bitwise_reconvergence(serve_parts):
    from repro.serve import apply_updates_to_graph

    g, build, owned = serve_parts
    srv = build()
    rng = np.random.default_rng(11)

    srv.set_fault_plan(_flaky_plan())
    feats, adds, removes = {}, [], []
    down_ticks = 9
    for t in range(1, 16):
        if t == 2:                               # ops landing mid-outage
            for k in range(3):
                gid = int(owned[1][k])
                vec = rng.standard_normal(g.feature_dim).astype(np.float32)
                srv.update_features(gid, vec)
                feats[gid] = vec
            u, v = int(owned[2][0]), int(owned[1][1])
            srv.add_edge(u, v)
            adds.append((u, v))
            vrow = int(srv.owner_row[v])
            if len(srv.nbr_gid[1][vrow]):
                ru = int(srv.nbr_gid[1][vrow][0])
                srv.remove_edge(ru, v)
                removes.append((ru, v))
        srv.tick()

    assert srv.health == ["healthy"] * 4
    assert srv._queue == [] and srv.stats["replayed"] == len(feats) + 2
    # backoff keeps retries bounded: 1,2,4,8,8... gated attempts while down
    assert srv.stats["replay_attempts"] <= 2 + down_ticks // 2

    inc = srv.export_logits()
    srv.refresh_full()                           # full-vs-incremental oracle
    np.testing.assert_array_equal(inc, srv.export_logits())
    fresh = build(apply_updates_to_graph(g, feature_updates=feats,
                                         add_edges=adds,
                                         remove_edges=removes))
    np.testing.assert_array_equal(inc, fresh.export_logits())


def _flaky_plan():
    from repro.robustness import FaultPlan

    return FaultPlan(serve_fail={1: (1,)}, serve_recover={10: (1,)})


def test_fifo_replay_order_last_write_wins(serve_parts):
    g, build, owned = serve_parts
    srv = build()
    gid = int(owned[2][0])
    srv.fail_partition(2)
    first = np.full(g.feature_dim, 1.0, np.float32)
    second = np.full(g.feature_dim, 2.0, np.float32)
    srv.update_features(gid, first)
    srv.update_features(gid, second)             # FIFO behind the first
    assert srv.stats["updates_queued"] == 2
    srv.recover_partition(2)
    srv.tick()
    np.testing.assert_array_equal(
        srv.h[0][2][int(srv.owner_row[gid])], second)


def test_random_plan_drives_serve_events(serve_parts):
    from repro.robustness import FaultPlan

    _, build, _ = serve_parts
    srv = build()
    plan = FaultPlan.random(5, num_parts=4, max_epochs=0, serve_ticks=12,
                            serve_fail_prob=0.4, down_ticks=2)
    assert plan.serve_fail                       # seed 5 does schedule faults
    srv.set_fault_plan(plan)
    saw_failed = False
    for _ in range(20):
        _, st = srv.tick()
        saw_failed = saw_failed or "failed" in st["health"]
    assert saw_failed
    assert srv.health == ["healthy"] * 4         # every failure recovered
    assert srv.stats["recoveries"] == srv.stats["failovers"] > 0
