"""End-to-end behaviour tests for the paper's system.

These run the full EAT-DistGNN pipeline (EW partitioning -> CBS -> GP) on a
tiny synthetic benchmark and assert the paper's three behavioural claims:

  1. the pipeline trains (final micro-F1 far above chance);
  2. personalization actually starts and contributes (the Fig. 3 jump);
  3. CBS mini-epochs shorten the epoch (the 2-3x epoch-time mechanism).
"""
import functools

import numpy as np
import pytest

from repro.pipeline import EATConfig, run_eat_distgnn
from repro.roofline import collective_bytes_from_hlo


@pytest.fixture(scope="module")
def full_run():
    # flatten_tol 0.08: the trigger must fire within the short test budget
    # (the paper triggers on "loss starts to flatten"; tol is its knob)
    cfg = EATConfig(dataset="tiny", num_parts=4, partition_method="ew",
                    use_cbs=True, use_gp=True, max_epochs=16, hidden_dim=48,
                    batch_size=128, fanouts=(5, 5), lr=3e-3, seed=0,
                    flatten_tol=0.08)
    return run_eat_distgnn(cfg)


def test_pipeline_learns(full_run):
    r = full_run
    chance = 1.0 / 5   # 5 classes (imbalanced: majority ~ 0.38)
    assert r.f1.micro > 0.30
    assert r.epochs_run <= 16
    assert np.isfinite(r.loss_history).all()


def test_personalization_started_and_helped(full_run):
    r = full_run
    assert r.personalize_start_epoch > 0, "personalization never triggered"
    pre = max(r.val_history[: r.personalize_start_epoch])
    post = max(r.val_history[r.personalize_start_epoch:])
    assert post >= pre  # Fig. 3: micro-F1 jump (or at least no regression)


@functools.lru_cache(maxsize=1)
def _cbs_ablation_runs():
    base = EATConfig(dataset="tiny", num_parts=2, partition_method="metis",
                     use_cbs=False, use_gp=False, max_epochs=2,
                     hidden_dim=32, batch_size=64, fanouts=(5, 5), seed=1)
    cbs = EATConfig(dataset="tiny", num_parts=2, partition_method="metis",
                    use_cbs=True, use_gp=False, max_epochs=2,
                    hidden_dim=32, batch_size=64, fanouts=(5, 5), seed=1)
    return run_eat_distgnn(base), run_eat_distgnn(cbs)


def test_cbs_shortens_epoch():
    """CBS mini-epochs do strictly less WORK per epoch: fewer training
    batches drawn (25% mini-epochs vs the full train set).  Deterministic —
    scan lengths, not wall clock, so machine load cannot flake it; the
    wall-clock rendering of the same claim lives in the `timing` lane
    (test_cbs_shortens_epoch_wallclock)."""
    r_base, r_cbs = _cbs_ablation_runs()
    assert r_base.phase0_iter_history and r_cbs.phase0_iter_history
    assert len(r_cbs.phase0_iter_history) == len(r_base.phase0_iter_history)
    # mini-epoch = 25% of train nodes -> strictly fewer batches EVERY epoch
    assert max(r_cbs.phase0_iter_history) < min(r_base.phase0_iter_history), (
        r_cbs.phase0_iter_history, r_base.phase0_iter_history)


@pytest.mark.timing
def test_cbs_shortens_epoch_wallclock():
    """The paper's wall-clock claim (the 2-3x epoch-time mechanism).  Wall
    time depends on machine load, so this runs in the quarantined `timing`
    lane of scripts/ci.sh: one automatic retry, excluded from the 30 s
    runtime gate and from tier-1."""
    r_base, r_cbs = _cbs_ablation_runs()
    assert r_cbs.epoch_time_s < r_base.epoch_time_s


def test_gp_cuts_gradient_traffic():
    """Phase-1 stops all-reduce traffic: same epochs, less comm than pure
    phase-0 training."""
    gp = EATConfig(dataset="tiny", num_parts=4, partition_method="metis",
                   use_cbs=True, use_gp=True, max_epochs=10, hidden_dim=32,
                   batch_size=64, fanouts=(4, 4), seed=2, flatten_tol=0.5)
    nogp = EATConfig(dataset="tiny", num_parts=4, partition_method="metis",
                     use_cbs=True, use_gp=False, max_epochs=10, hidden_dim=32,
                     batch_size=64, fanouts=(4, 4), seed=2)
    r_gp = run_eat_distgnn(gp)
    r_nogp = run_eat_distgnn(nogp)
    if r_gp.personalize_start_epoch > 0 and r_nogp.epochs_run >= r_gp.epochs_run:
        assert r_gp.comm_grad_bytes < r_nogp.comm_grad_bytes


# --------------------------------------------------------------- roofline --

def test_collective_parser():
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(f32[1,128]{1,0} %x), replica_groups={}
  %ar.1 = bf16[4,4]{1,0} all-reduce(bf16[4,4]{1,0} %y), to_apply=%add
  ROOT %a2a = f32[8,32]{1,0} all-to-all(f32[8,32]{1,0} %z), dimensions={0}
  %cp-start = u32[2]{0} collective-permute-start(u32[2]{0} %w)
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 16 * 128 * 4
    assert out["all-reduce"] == 4 * 4 * 2
    assert out["all-to-all"] == 8 * 32 * 4
    assert out["collective-permute"] == 2 * 4


def test_serve_engine_greedy():
    from repro.configs import get_config
    from repro.models import Transformer
    from repro.serve import ServeEngine
    import jax.numpy as jnp

    cfg = get_config("qwen2-0.5b").reduced()
    model = Transformer(cfg)
    engine = ServeEngine(model, model.init(0), cache_size=96)
    rng = np.random.default_rng(0)
    prompts = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 16)),
                                     jnp.int32)}
    out = engine.generate(prompts, max_new_tokens=8)
    assert out.shape == (3, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    # greedy decoding is deterministic
    out2 = engine.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(out, out2)


class _ScriptedModel:
    """Stub whose decode emits a fixed per-row token script: logits put all
    mass on script[:, cache_len + 1], so greedy decoding replays the script
    exactly — the controllable harness for the EOS/done semantics."""

    cfg = None

    def __init__(self, script):
        import jax.numpy as jnp

        self.script = jnp.asarray(script, jnp.int32)   # (B, >= max_new)
        self.vocab = int(np.asarray(script).max()) + 1

    def prefill(self, params, batch, *, cache_size=None):
        import jax
        import jax.numpy as jnp

        logits = jax.nn.one_hot(self.script[:, 0], self.vocab) * 10.0
        return logits, {"t": jnp.zeros(())}, 0

    def decode_step(self, params, token, caches, cache_len, *, rolling=False):
        import jax

        nxt = jax.lax.dynamic_index_in_dim(self.script, cache_len + 1,
                                           axis=1, keepdims=False)
        return jax.nn.one_hot(nxt, self.vocab) * 10.0, caches


def test_serve_engine_freezes_rows_past_eos():
    """Regression: rows that emitted EOS must stay frozen at eos_id for the
    rest of the sequence, not keep sampling over it (per-row EOS at
    different steps)."""
    from repro.serve import ServeEngine

    eos = 9
    script = np.array([
        [5, eos, 7, 6, 5, 4],     # EOS at t=1; script keeps emitting junk
        [eos, 3, 4, 5, 6, 7],     # EOS at t=0
        [1, 2, 3, 4, 5, 6],       # never finishes
    ])
    model = _ScriptedModel(script)
    engine = ServeEngine(model, params=None, cache_size=8)
    out = engine.generate({"tokens": np.zeros((3, 4), np.int32)},
                          max_new_tokens=5, eos_id=eos)
    np.testing.assert_array_equal(
        out, [[5, eos, eos, eos, eos],
              [eos, eos, eos, eos, eos],
              [1, 2, 3, 4, 5]])


def test_serve_engine_pads_to_max_new_tokens_when_all_done():
    """Regression: the return width must depend only on max_new_tokens, not
    on when this particular batch finished — early-done batches pad the
    tail with eos_id (a lone row's shape can't change because a slower row
    shared its batch)."""
    from repro.serve import ServeEngine

    eos = 9
    script = np.array([[3, eos, 1, 1, 1], [eos, 2, 2, 2, 2]])
    engine = ServeEngine(_ScriptedModel(script), params=None, cache_size=8)
    out = engine.generate({"tokens": np.zeros((2, 4), np.int32)},
                          max_new_tokens=5, eos_id=eos)
    np.testing.assert_array_equal(out, [[3, eos, eos, eos, eos],
                                        [eos, eos, eos, eos, eos]])
    # batch composition must not change a row's output
    solo = ServeEngine(_ScriptedModel(script[:1]), params=None, cache_size=8)
    out_solo = solo.generate({"tokens": np.zeros((1, 4), np.int32)},
                             max_new_tokens=5, eos_id=eos)
    np.testing.assert_array_equal(out_solo, out[:1])


def test_serve_engine_truncates_when_all_done_with_flag():
    from repro.serve import ServeEngine

    eos = 9
    script = np.array([[3, eos, 1, 1, 1], [eos, 2, 2, 2, 2]])
    engine = ServeEngine(_ScriptedModel(script), params=None, cache_size=8)
    out = engine.generate({"tokens": np.zeros((2, 4), np.int32)},
                          max_new_tokens=5, eos_id=eos, truncate_done=True)
    np.testing.assert_array_equal(out, [[3, eos], [eos, eos]])


def test_serve_engine_skips_trailing_decode():
    """The token of the final position needs no further decode: exactly
    max_new_tokens - 1 decode calls when nothing finishes early."""
    from repro.serve import ServeEngine

    script = np.array([[1, 2, 3, 4, 5, 6]])
    engine = ServeEngine(_ScriptedModel(script), params=None, cache_size=8)
    calls = []
    inner = engine._decode
    engine._decode = lambda *a, **k: (calls.append(1), inner(*a, **k))[1]
    out = engine.generate({"tokens": np.zeros((1, 4), np.int32)},
                          max_new_tokens=4)
    np.testing.assert_array_equal(out, [[1, 2, 3, 4]])
    assert len(calls) == 3
