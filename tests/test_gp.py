import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gp import (EarlyStopper, GPController, GPHyperParams,
                           GPScheduleConfig, broadcast_to_partitions,
                           loss_flattened, make_generalize_step,
                           make_personalize_step)
from repro.train.losses import prox_penalty
from repro.train.optim import SGDM, AdamW, apply_updates


# --------------------------------------------------------------- schedule --

def test_loss_flattened_detects_plateau():
    falling = [5.0, 4.0, 3.0, 2.0, 1.0, 0.5, 0.25]
    flat = [1.0] * 10
    assert not loss_flattened(falling, window=3, tol=0.02)
    assert loss_flattened(flat, window=3, tol=0.02)


def test_early_stopper_patience():
    s = EarlyStopper(patience=2)
    assert s.update(0.5, 0)         # best
    assert not s.update(0.4, 1)
    assert not s.update(0.4, 2)
    assert not s.update(0.4, 3)
    assert s.stopped
    assert s.best == 0.5 and s.best_epoch == 0


def test_controller_phases():
    ctrl = GPController(num_partitions=3,
                        config=GPScheduleConfig(max_epochs=50, min_phase0_epochs=2))
    for i in range(6):
        ctrl.record_phase0(1.0, 0.5)          # flat losses
    assert ctrl.should_personalize()
    ctrl.start_personalization()
    assert ctrl.phase == 1
    # partition 1 keeps improving, 0 and 2 stall -> they stop first
    for i in range(12):
        scores = np.array([0.5, 0.5 + 0.01 * i, 0.5])
        ctrl.record_phase1(scores)
        if ctrl.done:
            break
    assert not ctrl.active_partitions[0]
    assert not ctrl.active_partitions[2]


def test_phase1_budgets_track_stoppers():
    """The engine's budget API: stopped partitions get 0, live ones their own
    natural mini-epoch iteration count (scalar broadcasts, arrays pass
    through); tapering sheds iterations as patience burns."""
    ctrl = GPController(num_partitions=3,
                        config=GPScheduleConfig(max_epochs=50,
                                                min_phase0_epochs=1))
    for _ in range(6):
        ctrl.record_phase0(1.0, 0.5)
    ctrl.start_personalization()
    np.testing.assert_array_equal(ctrl.phase1_budgets(7), [7, 7, 7])
    np.testing.assert_array_equal(ctrl.phase1_budgets([3, 9, 5]), [3, 9, 5])
    # stall partitions 0 and 2 until their stop fires
    for i in range(12):
        ctrl.record_phase1(np.array([0.5, 0.5 + 0.01 * i, 0.5]))
        if not ctrl.active_partitions[0]:
            break
    b = ctrl.phase1_budgets(7)
    assert b[0] == 0 and b[2] == 0 and b[1] == 7
    assert b.dtype == np.int32
    # taper: a live partition burning patience sheds iterations but keeps >= 1
    ctrl2 = GPController(num_partitions=2,
                         config=GPScheduleConfig(max_epochs=50,
                                                 min_phase0_epochs=1))
    for _ in range(6):
        ctrl2.record_phase0(1.0, 0.5)
    ctrl2.start_personalization()
    ctrl2.record_phase1(np.array([0.9, 0.5]))
    ctrl2.record_phase1(np.array([0.1, 0.6]))   # partition 0: 1 bad epoch
    t = ctrl2.phase1_budgets(10, taper=True)
    assert 1 <= t[0] < 10 and t[1] == 10


# ------------------------------------------------------------------ steps --

def _quadratic_loss(target):
    def loss_fn(params, batch):
        return jnp.sum((params["w"] - target) ** 2) + 0.0 * batch["x"].sum()
    return loss_fn


def test_generalize_step_descends():
    loss_fn = _quadratic_loss(jnp.ones(4))
    opt = SGDM(lr=0.1, momentum=0.0)
    params = {"w": jnp.zeros(4)}
    opt_state = opt.init(params)
    step = jax.jit(make_generalize_step(loss_fn, opt))
    batch = {"x": jnp.zeros(1)}
    losses = []
    for _ in range(20):
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < 0.05 * losses[0]


def test_personalize_step_prox_pull():
    """With a huge lambda the personal weights must stay near W^G even when
    the local loss pulls elsewhere."""
    opt = SGDM(lr=0.02, momentum=0.0)
    global_params = {"w": jnp.zeros(3)}
    targets = jnp.stack([jnp.ones(3), -jnp.ones(3)])   # two partitions

    def loss_fn(params, batch):
        return jnp.sum((params["w"] - batch["t"]) ** 2)

    def run(lam):
        pstep = jax.jit(make_personalize_step(
            loss_fn, opt, GPHyperParams(lambda_prox=lam)))
        pparams = broadcast_to_partitions(global_params, 2)
        popt = jax.vmap(opt.init)(pparams)
        active = jnp.ones(2, bool)
        batch = {"t": targets}
        for _ in range(100):
            pparams, popt, losses = pstep(pparams, popt, batch, global_params, active)
        return pparams

    free = run(0.0)
    tight = run(20.0)
    # free personalization reaches local optima
    assert jnp.allclose(free["w"][0], jnp.ones(3), atol=0.05)
    assert jnp.allclose(free["w"][1], -jnp.ones(3), atol=0.05)
    # prox-regularized stays near the global model
    dist_free = prox_penalty({"w": free["w"][0]}, global_params)
    dist_tight = prox_penalty({"w": tight["w"][0]}, global_params)
    assert dist_tight < 0.2 * dist_free


def test_personalize_active_mask_freezes():
    opt = SGDM(lr=0.1, momentum=0.0)
    global_params = {"w": jnp.zeros(2)}

    def loss_fn(params, batch):
        return jnp.sum(params["w"] ** 2) - 2 * jnp.sum(params["w"])  # min at 1

    pstep = jax.jit(make_personalize_step(loss_fn, opt,
                                          GPHyperParams(use_prox=False)))
    pparams = broadcast_to_partitions(global_params, 2)
    popt = jax.vmap(opt.init)(pparams)
    active = jnp.array([True, False])
    batch = {"x": jnp.zeros((2, 1))}
    for _ in range(10):
        pparams, popt, _ = pstep(pparams, popt, batch, global_params, active)
    assert float(jnp.abs(pparams["w"][0] - 1.0).max()) < 0.2   # trained
    assert float(jnp.abs(pparams["w"][1]).max()) == 0.0        # frozen


def test_personalize_no_cross_partition_leakage():
    """Each partition's result must depend only on its own batch."""
    opt = SGDM(lr=0.1, momentum=0.0)
    gp = {"w": jnp.zeros(2)}

    def loss_fn(params, batch):
        return jnp.sum((params["w"] - batch["t"]) ** 2)

    pstep = jax.jit(make_personalize_step(loss_fn, opt, GPHyperParams(use_prox=False)))
    base = jnp.stack([jnp.ones(2), 2 * jnp.ones(2)])
    for other in (5.0, -3.0):
        pparams = broadcast_to_partitions(gp, 2)
        popt = jax.vmap(opt.init)(pparams)
        batch = {"t": base.at[1].set(other)}
        pparams, _, _ = pstep(pparams, popt, batch, gp, jnp.ones(2, bool))
        first = np.asarray(pparams["w"][0])
        if other == 5.0:
            ref = first
    assert np.allclose(first, ref)


# -------------------------------------------------------------- optimizers --

def test_adamw_decoupled_decay():
    opt = AdamW(lr=0.1, weight_decay=0.5)
    params = {"w": jnp.ones(3)}
    state = opt.init(params)
    zero_grads = {"w": jnp.zeros(3)}
    updates, state = opt.update(zero_grads, state, params)
    new = apply_updates(params, updates)
    assert float(new["w"][0]) < 1.0   # decay shrinks weights w/o gradient
