"""SPMD engine parity harness (the tentpole's self-verification).

1. float64 bit-for-bit: the fused SPMD engine (stacked vmap mode) reproduces
   the sequential per-partition reference EXACTLY — losses, updated params,
   per-partition validation micro-F1 and test predictions — across
   seeds × {ew, metis, random} × {cbs, uniform}.  Runs in a subprocess so
   ``jax_enable_x64`` cannot leak into other tests.
2. Budget parity: random per-partition iteration budgets (including 0 and
   full-epoch) through the masked variable-length scan reproduce the
   sequential per-partition loops bit-for-bit in fp64; an all-zero budget
   step leaves params AND optimizer state bitwise unchanged.
3. Async-path parity: the fully-on-device phase-1 (device CBS draw + fanout
   + gather inside the fused step) matches the sequential reference running
   the SAME PRNG programs one partition at a time, bit-for-bit in fp64.
   Likewise the fused phase-0 program (epoch draw + train scan + FUSED
   validation eval, with and without CBS) — stacked in the shared
   subprocess, AND under shard_map on a real 4-device mesh (bitwise there
   too: its only collectives are data movement, no pmean), with the fused
   eval bitwise equal to a standalone evaluate().
4. shard_map mode: with 4 forced host devices the mesh engine matches the
   stacked engine to collective-reduction rounding (<= a few f32 ulps).
5. Pallas on the hot path: the distributed eval forward demonstrably stages
   ``segment_agg`` (trace-time call counter) and agrees with the jnp
   segment-op reference.
6. segment_agg property sweep: Pallas vs ref over ragged degree
   distributions — power-law, isolated nodes, single giant hub.
7. Full-graph training: phase-0 ``value_and_grad`` through the distributed
   forward (halo-exchange VJP + the custom-VJP aggregation op) matches the
   sequential reference bit-for-bit in fp64, and the Pallas path stages the
   forward AND transpose kernels while matching the jnp path in f32.
8. Historical halo cache: staleness 0 (refresh every eval) == the sync
   forward bitwise (stacked AND real spmd mesh); cached mode == the
   sequential stale-aggregation oracle AND an independent closed-form stale
   oracle bitwise in fp64 (standalone evaluate AND the fused async epoch);
   comm counters report only the refreshed-row payload (CV chunks partition
   one full exchange); the pure-cached spmd program lowers with no
   all_to_all at all.
9. Compressed communication (PR-9): error-compensated fp16/int8 halo
   quantization and bucketed/top-k gradient reduction each match the
   sequential fp64 oracle bit-for-bit (the oracle models the quantize /
   dequantize / residual arithmetic exactly); compress=off stays bitwise
   the pre-PR-9 forward; ring schedules and the halo cache compose.
10. Two-tier feature store (PR-10): the feat-store engine (hot rows
   resident, cold rows staged from the host per compiled call) equals the
   all-resident engine bit-for-bit — sync phases, hot_frac extremes, the
   fused async epochs with a feat-store device sampler, and the halo-cache
   / int8 compositions — in BOTH stacked and real-mesh shard_map modes;
   hot_frac=1.0 stages zero cold bytes.

Flaky-surface hardening: ALL fast fp64 checks (1–3) share ONE subprocess
per module (one interpreter + one set of XLA compilations), and every
subprocess enables the persistent compilation cache under ``.jax_cache/``
so reruns skip compilation entirely.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _jax_cache import CACHE_PRELUDE, REPO_ROOT

SUBPROC_ENV = {"PYTHONPATH": os.path.join(REPO_ROOT, "src"),
               "PATH": "/usr/bin:/bin", "HOME": os.path.expanduser("~")}

# --------------------------------------------------------------------------
# shared harness body (runs inside the test process AND inside subprocesses)
# --------------------------------------------------------------------------

HARNESS = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import partition_graph, GPHyperParams, broadcast_to_partitions
from repro.core.sampler import CBSampler, build_device_epoch_sampler
from repro.engine import (EngineConfig, SPMDEngine, SequentialReference,
                          stack_epoch_batches)
from repro.graph import (BENCHMARKS, GraphSAGE, NeighborSampler,
                         build_partitioned_graph, make_benchmark)
from repro.train.optim import AdamW

P = 4
BATCH = 32

def build_case(method, seed, use_cbs, dtype):
    g = make_benchmark(BENCHMARKS["tiny"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, P,
                        method=method, seed=seed)
    pg = build_partitioned_graph(g, r.parts, P)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes)
    loss_fn = model.make_loss_fn()
    opt = AdamW(lr=3e-3, grad_clip=5.0)
    neigh = NeighborSampler(g, fanouts=(3, 3), seed=seed)
    host_train = [g.train_idx[r.parts[g.train_idx] == p] for p in range(P)]
    samplers = [CBSampler(g.indptr, g.indices, g.labels, host_train[p],
                          batch_size=BATCH,
                          subset_fraction=0.25 if use_cbs else 1.0,
                          class_balanced=use_cbs, seed=seed + p)
                for p in range(P)]
    feats = np.asarray(g.features, dtype)

    def make_batch(nodes):
        k = len(nodes)
        if k < BATCH:
            nodes = np.concatenate([nodes, np.zeros(BATCH - k, nodes.dtype)])
        mask = np.zeros(BATCH, dtype)
        mask[:k] = 1
        b = neigh.sample(nodes)
        x_t, x_1, x_2 = b.feature_views(feats)
        return {"x_t": jnp.asarray(x_t), "x_1": jnp.asarray(x_1),
                "x_2": jnp.asarray(x_2),
                "labels": jnp.asarray(g.labels[nodes]),
                "mask": jnp.asarray(mask)}

    return g, pg, model, loss_fn, opt, samplers, make_batch, host_train


def tree_maxdiff(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def run_pair(engA, engB, model, opt, samplers, make_batch, seed, dtype,
             budgets=None):
    '''One phase-0 epoch + one phase-1 epoch + test eval through both
    engines on IDENTICAL batches; returns max diffs.  ``budgets`` defaults
    to the pre-async gate (one frozen partition, full epoch elsewhere).'''
    params = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(seed))
    opt_state = opt.init(params)
    b0, _, _ = stack_epoch_batches(samplers, make_batch, P)
    pA, oA, lA, vA, _ = engA.phase0_epoch(params, opt_state, b0)
    pB, oB, lB, vB, _ = engB.phase0_epoch(params, opt_state, b0)
    d = {"p0_loss": float(np.abs(np.asarray(lA) - np.asarray(lB)).max()),
         "p0_val": float(np.abs(np.asarray(vA) - np.asarray(vB)).max()),
         "p0_params": tree_maxdiff(pA, pB)}
    pp = broadcast_to_partitions(pA, P)
    po = jax.vmap(opt.init)(pp)
    b1, _, _ = stack_epoch_batches(samplers, make_batch, P)
    iters = jax.tree_util.tree_leaves(b1)[0].shape[0]
    if budgets is None:
        active = np.ones(P, bool)
        active[seed % P] = False      # one frozen host: gate parity too
        budgets = np.where(active, iters, 0)
    budgets = jnp.asarray(np.asarray(budgets, np.int32))
    ppA, poA, l1A, v1A, _ = engA.phase1_epoch(pp, po, b1, pA, budgets)
    ppB, poB, l1B, v1B, _ = engB.phase1_epoch(pp, po, b1, pB, budgets)
    d.update({"p1_loss": float(np.abs(np.asarray(l1A) - np.asarray(l1B)).max()),
              "p1_val": float(np.abs(np.asarray(v1A) - np.asarray(v1B)).max()),
              "p1_params": tree_maxdiff(ppA, ppB),
              "p1_opt": tree_maxdiff(poA, poB)})
    mA, prA = engA.evaluate(ppA, "test")
    mB, prB = engB.evaluate(ppB, "test")
    d["test_micro"] = float(np.abs(np.asarray(mA) - np.asarray(mB)).max())
    d["test_pred_mismatch"] = int((np.asarray(prA) != np.asarray(prB)).sum())
    return d


def budget_vectors(iters, seed):
    '''The satellite's budget sweep: all-zero, all-full, and random mixed
    vectors that include a 0 and a full-epoch entry.'''
    rng = np.random.default_rng(seed)
    mixed = rng.integers(0, iters + 1, P)
    mixed[rng.integers(0, P)] = 0
    mixed[(rng.integers(0, P - 1) + np.argmin(mixed) + 1) % P] = iters
    return {"zero": np.zeros(P, np.int64),
            "full": np.full(P, iters, np.int64),
            "mixed": mixed}


def run_budget_parity(eng, seq, model, opt, samplers, make_batch, seed, dtype):
    '''Masked-scan budget parity (engine vs sequential, bit-for-bit) plus
    the all-zero-budget no-op check (params AND opt state bitwise).'''
    params = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(seed))
    pp = broadcast_to_partitions(params, P)
    po = jax.vmap(opt.init)(pp)
    b1, _, _ = stack_epoch_batches(samplers, make_batch, P)
    iters = jax.tree_util.tree_leaves(b1)[0].shape[0]
    out = {}
    for tag, bud in budget_vectors(iters, seed).items():
        budj = jnp.asarray(bud.astype(np.int32))
        ppA, poA, lA, vA, _ = eng.phase1_epoch(pp, po, b1, params, budj)
        ppB, poB, lB, vB, _ = seq.phase1_epoch(pp, po, b1, params, budj)
        out[f"{tag}_params"] = tree_maxdiff(ppA, ppB)
        out[f"{tag}_opt"] = tree_maxdiff(poA, poB)
        out[f"{tag}_loss"] = float(np.abs(np.asarray(lA) - np.asarray(lB)).max())
        out[f"{tag}_val"] = float(np.abs(np.asarray(vA) - np.asarray(vB)).max())
        if tag == "zero":
            out["zero_noop_params"] = tree_maxdiff(ppA, pp)
            out["zero_noop_opt"] = tree_maxdiff(poA, po)
    return out


def run_overlap_parity(pg, model, loss_fn, opt, samplers, make_batch, seed,
                       dtype):
    '''Boundary/interior split forward parity (the PR-3 tentpole):
      1. overlapped stacked engine == overlapped sequential reference,
         bit-for-bit through run_pair (phases + eval);
      2. overlapped == SYNCHRONOUS forward bit-for-bit on owned rows
         (micro-F1 over the owned masks must match exactly; halo/pad
         logit rows are not meaningful in either forward);
      3. the chunked ppermute ring delivers bit-identical results to the
         single all_to_all exchange.'''
    from repro.engine import SequentialReference, SPMDEngine
    kw = dict(mode="stacked", use_pallas_agg=False, dtype=dtype)
    engO = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                      EngineConfig(overlap_halo=True, **kw))
    seqO = SequentialReference(model, loss_fn, opt, pg, GPHyperParams(),
                               EngineConfig(overlap_halo=True, **kw))
    d = {"seq_" + k: v for k, v in run_pair(
        engO, seqO, model, opt, samplers, make_batch, seed, dtype).items()}

    engS = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                      EngineConfig(**kw))
    engR = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                      EngineConfig(overlap_halo=True, ring_chunks=3, **kw))
    params = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(seed))
    pp = broadcast_to_partitions(params, P)
    for split in ("val", "test"):
        mS, prS = engS.evaluate(pp, split)
        mO, prO = engO.evaluate(pp, split)
        mR, prR = engR.evaluate(pp, split)
        prS, prO, prR = map(np.asarray, (prS, prO, prR))
        d[f"{split}_micro"] = float(np.abs(np.asarray(mS) - np.asarray(mO)).max())
        d[f"{split}_pred_owned"] = int(sum(
            (prS[p, : pg.n_own[p]] != prO[p, : pg.n_own[p]]).sum()
            for p in range(P)))
        d[f"{split}_ring_micro"] = float(np.abs(np.asarray(mR) - np.asarray(mO)).max())
        d[f"{split}_ring_pred"] = int((prR != prO).sum())
    return d


def run_fullgraph_parity(eng, seq, model, opt, seed, dtype, iters=2):
    '''Full-graph phase-0 (value_and_grad THROUGH the distributed forward:
    halo exchange VJP + the aggregation op) — fused engine vs the
    sequential reference differentiating the Python-loop forward.'''
    params = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(seed))
    opt_state = opt.init(params)
    pA, oA, lA, vA, _ = eng.phase0_fullgraph_epoch(params, opt_state, iters)
    pB, oB, lB, vB, _ = seq.phase0_fullgraph_epoch(params, opt_state, iters)
    return {"loss": float(np.abs(np.asarray(lA) - np.asarray(lB)).max()),
            "val": float(np.abs(np.asarray(vA) - np.asarray(vB)).max()),
            "params": tree_maxdiff(pA, pB),
            "opt": tree_maxdiff(oA, oB)}


def run_phase0_async_parity(eng, seq, g, host_train, model, opt, seed, dtype):
    '''Fused phase-0 device program (on-device epoch draw + synchronous
    train scan with the cross-partition gradient mean + the FUSED validation
    eval) vs the sequential oracle running the SAME PRNG programs — for the
    CBS-weighted draw AND the uniform no-CBS shuffle — plus the fused-eval
    == standalone evaluate() bitwise check.'''
    out = {}
    for tag, cbs in (("cbs", True), ("uni", False)):
        ds = build_device_epoch_sampler(g, host_train, P, batch_size=BATCH,
                                        subset_fraction=0.25 if cbs else 1.0,
                                        class_balanced=cbs, fanouts=(3, 3),
                                        dtype=dtype)
        eng.set_device_sampler(ds)
        seq.set_device_sampler(ds)
        params = jax.tree.map(lambda x: jnp.asarray(x, dtype),
                              model.init(seed))
        opt_state = opt.init(params)
        keys = jax.random.split(jax.random.PRNGKey(seed ^ 0x6E02), P)
        pA, oA, lA, vA, _ = eng.phase0_epoch_async(params, opt_state, keys)
        pB, oB, lB, vB, _ = seq.phase0_epoch_async(params, opt_state, keys)
        out[f"{tag}_params"] = tree_maxdiff(pA, pB)
        out[f"{tag}_opt"] = tree_maxdiff(oA, oB)
        out[f"{tag}_loss"] = float(np.abs(np.asarray(lA)
                                          - np.asarray(lB)).max())
        out[f"{tag}_val"] = float(np.abs(np.asarray(vA)
                                         - np.asarray(vB)).max())
        mS, _ = eng.evaluate(pA, "val", per_partition_params=False)
        out[f"{tag}_fused_eval"] = float(np.abs(np.asarray(vA)
                                                - np.asarray(mS)).max())
    return out


def run_halo_cache_parity(pg, model, loss_fn, opt, seed, dtype):
    '''Historical halo cache parity (the PR-6 tentpole):
      1. staleness 0 (K=1): the cached engine == the sync forward bitwise
         across a sequence of evals with changing params, every eval paying
         the full exchange;
      2. K=3, cv off/on: cached stacked engine == cached sequential oracle
         bitwise across the eval sequence, with equal byte counters;
      3. counters: full-refresh evals report 2*halo_bytes_per_layer, pure-
         cached evals 0, and the CV chunk payloads sum to one full exchange
         over a refresh cycle;
      4. an INDEPENDENT closed-form stale oracle (cv off): at eval t the h1
         halo rows must equal layer-1 outputs under the params of the last
         full refresh r = (t // K) * K — derived with no incremental cache
         state, so a shared off-by-one in engine + sequential cannot hide.'''
    from repro.graph.distributed import make_ref_mean_agg

    kw = dict(mode="stacked", use_pallas_agg=False, dtype=dtype)
    mk = lambda **o: SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                                EngineConfig(**kw, **o))
    mkseq = lambda **o: SequentialReference(model, loss_fn, opt, pg,
                                            GPHyperParams(),
                                            EngineConfig(**kw, **o))
    base = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(seed))
    pseq = [jax.tree.map(lambda x: x * (1.0 + 0.05 * i), base)
            for i in range(6)]
    full = 2 * pg.halo_bytes_per_layer
    out = {}

    sync = mk()
    k1 = mk(halo_cache=True, halo_refresh_every=1)
    d = b = 0
    for prm in pseq[:3]:
        mS, prS = sync.evaluate(prm, "val", per_partition_params=False)
        mC, prC = k1.evaluate(prm, "val", per_partition_params=False)
        d = max(d, float(jnp.abs(mS - mC).max()),
                float((np.asarray(prS) != np.asarray(prC)).sum()))
        b += int(k1.last_halo_exchange_bytes != full)
    out["staleness0"] = d
    out["staleness0_bytes"] = float(b)

    for tag, cv in (("plain", False), ("cv", True)):
        eng = mk(halo_cache=True, halo_refresh_every=3, halo_cv=cv)
        seq = mkseq(halo_cache=True, halo_refresh_every=3, halo_cv=cv)
        d = b = 0
        byte_seq = []
        for prm in pseq:
            mA, prA = eng.evaluate(prm, "val", per_partition_params=False)
            mB, prB = seq.evaluate(prm, "val", per_partition_params=False)
            d = max(d, float(jnp.abs(mA - mB).max()),
                    float((np.asarray(prA) != np.asarray(prB)).sum()))
            b += int(eng.last_halo_exchange_bytes
                     != seq.last_halo_exchange_bytes)
            byte_seq.append(eng.last_halo_exchange_bytes)
        out[f"{tag}_vs_seq"] = d
        out[f"{tag}_bytes_mismatch"] = float(b)
        if cv:
            out["cv_cycle"] = float(byte_seq[0] != full
                                    or sum(byte_seq[1:3]) != full
                                    or byte_seq[3] != full
                                    or 0 in byte_seq[1:3])
        else:
            out["plain_cached_bytes"] = float(
                byte_seq[0] != full or byte_seq[1] != 0
                or byte_seq[2] != 0 or byte_seq[3] != full)

    send_idx = jnp.asarray(pg.send_idx)
    send_mask = jnp.asarray(pg.send_mask, dtype)
    recv_pos = jnp.asarray(pg.recv_pos)
    feats = jnp.asarray(pg.features, dtype)
    agg = make_ref_mean_agg(pg.max_nodes)
    shards = [{"edge_src": jnp.asarray(pg.edge_src[p]),
               "edge_dst": jnp.asarray(pg.edge_dst[p]),
               "edge_mask": jnp.asarray(pg.edge_mask[p], dtype)}
              for p in range(P)]

    def exchange(hs):
        sent = [hs[p][send_idx[p]] * send_mask[p][..., None]
                for p in range(P)]
        res = []
        for q in range(P):
            recv = jnp.stack([sent[p][q] for p in range(P)])
            res.append(hs[q].at[recv_pos[q].reshape(-1)].set(
                recv.reshape(-1, hs[q].shape[-1])))
        return res

    def layer1(prm, hs):
        return [jax.nn.relu(hs[p] @ prm.layer1.w_self
                            + agg(hs[p], shards[p]) @ prm.layer1.w_neigh
                            + prm.layer1.b) for p in range(P)]

    # h0 never goes stale in VALUE: features are constant, so the cached
    # feature-halo rows equal a live exchange and the whole staleness story
    # lives in the h1 halo rows
    hs = exchange([feats[p] for p in range(P)])
    eng = mk(halo_cache=True, halo_refresh_every=3)
    d = 0
    for t, prm in enumerate(pseq):
        _, prA = eng.evaluate(prm, "val", per_partition_params=False)
        h1_cur = layer1(prm, hs)
        h1_stale = layer1(pseq[(t // 3) * 3], hs)
        sent = [h1_stale[p][send_idx[p]] * send_mask[p][..., None]
                for p in range(P)]
        preds = []
        for q in range(P):
            recv = jnp.stack([sent[p][q] for p in range(P)])
            h1 = h1_cur[q].at[recv_pos[q].reshape(-1)].set(
                recv.reshape(-1, h1_cur[q].shape[-1]))
            logits = (h1 @ prm.layer2.w_self
                      + agg(h1, shards[q]) @ prm.layer2.w_neigh
                      + prm.layer2.b)
            preds.append(jnp.argmax(logits, axis=-1))
        d = max(d, float((np.asarray(prA)
                          != np.asarray(jnp.stack(preds))).sum()))
    out["closed_form"] = d
    return out


def run_halo_cache_async_parity(pg, g, host_train, model, loss_fn, opt,
                                seed, dtype):
    '''The cached fused async epoch (cache carried as state through the one
    device program) == the sequential oracle, bitwise, across 3 epochs at
    K=2 — exercising full-refresh AND pure-cached fused evals.'''
    kw = dict(mode="stacked", use_pallas_agg=False, dtype=dtype,
              halo_cache=True, halo_refresh_every=2)
    eng = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                     EngineConfig(**kw))
    seq = SequentialReference(model, loss_fn, opt, pg, GPHyperParams(),
                              EngineConfig(**kw))
    ds = build_device_epoch_sampler(g, host_train, P, batch_size=BATCH,
                                    subset_fraction=1.0,
                                    class_balanced=False, fanouts=(3, 3),
                                    dtype=dtype)
    eng.set_device_sampler(ds)
    seq.set_device_sampler(ds)
    params = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(seed))
    pA = pB = params
    oA = oB = opt.init(params)
    keys0 = jax.random.split(jax.random.PRNGKey(seed ^ 0x6E02), P)
    d = b = 0
    for e in range(3):
        keys = jax.vmap(jax.random.fold_in, (0, None))(keys0, e)
        pA, oA, lA, vA, _ = eng.phase0_epoch_async(pA, oA, keys)
        pB, oB, lB, vB, _ = seq.phase0_epoch_async(pB, oB, keys)
        d = max(d, tree_maxdiff(pA, pB),
                float(np.abs(np.asarray(lA) - np.asarray(lB)).max()),
                float(np.abs(np.asarray(vA) - np.asarray(vB)).max()))
        b += int(eng.last_halo_exchange_bytes != seq.last_halo_exchange_bytes)
    return {"async_cached": d, "async_cached_bytes": float(b)}


def run_comm_compress_parity(pg, model, loss_fn, opt, samplers, make_batch,
                             seed, dtype):
    '''Compressed communication parity (the PR-9 tentpole):
      1. compressed phase-0 gradient reduction (bucketed psum spelling and
         top-k EF sparsification): compressed stacked engine == the
         sequential fp64 oracle bit-for-bit on SHARED drawn batches, and
         stacked bucketed == plain mode-none params bitwise;
      2. quantized halo eval (fp16 / int8 with carried residual feedback):
         engine eval sequence == oracle bitwise with equal byte counters,
         strictly below the uncompressed wire size; compress=off reports
         EXACTLY pg.halo_bytes_per_layer per layer (the pre-PR-9 lock);
      3. the chunked ppermute ring moves bit-identical compressed payloads
         (quantization happens BEFORE the collective);
      4. int8 composes with the PR-6 halo cache: refresh payloads quantize,
         the cache stores dequantized rows, engine == oracle bitwise.'''
    kw = dict(mode="stacked", use_pallas_agg=False, dtype=dtype)
    mk = lambda cls, **o: cls(model, loss_fn, opt, pg, GPHyperParams(),
                              EngineConfig(**kw, **o))
    out = {}
    base = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(seed))
    opt_state = opt.init(base)
    b0, _, _ = stack_epoch_batches(samplers, make_batch, P)
    pN, _, _, _, _ = mk(SPMDEngine).phase0_epoch(base, opt_state, b0)
    for gmode in ("bucketed", "topk"):
        eng = mk(SPMDEngine, grad_compress=gmode, grad_bucket_kb=1)
        seq = mk(SequentialReference, grad_compress=gmode, grad_bucket_kb=1)
        pA, oA, lA, vA, _ = eng.phase0_epoch(base, opt_state, b0)
        pB, oB, lB, vB, _ = seq.phase0_epoch(base, opt_state, b0)
        out[f"{gmode}_params"] = tree_maxdiff(pA, pB)
        out[f"{gmode}_opt"] = tree_maxdiff(oA, oB)
        out[f"{gmode}_loss"] = float(np.abs(np.asarray(lA)
                                            - np.asarray(lB)).max())
        out[f"{gmode}_val"] = float(np.abs(np.asarray(vA)
                                           - np.asarray(vB)).max())
        if gmode == "bucketed":
            out["bucketed_vs_none"] = tree_maxdiff(pA, pN)

    pseq = [jax.tree.map(lambda x: x * (1.0 + 0.05 * i), base)
            for i in range(3)]
    full = model.num_layers * pg.halo_bytes_per_layer
    out["none_wire_eq_pg"] = float(
        mk(SPMDEngine).halo_wire_bytes_per_layer != pg.halo_bytes_per_layer)
    for hmode in ("fp16", "int8"):
        eng = mk(SPMDEngine, halo_compress=hmode)
        seq = mk(SequentialReference, halo_compress=hmode)
        ring = mk(SPMDEngine, halo_compress=hmode, ring_chunks=3)
        d = ringd = bad_bytes = 0.0
        for prm in pseq:
            mA, prA = eng.evaluate(prm, "val", per_partition_params=False)
            mB, prB = seq.evaluate(prm, "val", per_partition_params=False)
            mR, prR = ring.evaluate(prm, "val", per_partition_params=False)
            d = max(d, float(jnp.abs(mA - mB).max()),
                    float((np.asarray(prA) != np.asarray(prB)).sum()))
            ringd = max(ringd, float(jnp.abs(mA - mR).max()),
                        float((np.asarray(prA) != np.asarray(prR)).sum()))
            bad_bytes += int(eng.last_halo_exchange_bytes
                             != seq.last_halo_exchange_bytes)
            bad_bytes += int(not (0 < eng.last_halo_exchange_bytes < full))
        out[f"{hmode}_eval"] = d
        out[f"{hmode}_ring"] = ringd
        out[f"{hmode}_bytes"] = bad_bytes

    engC = mk(SPMDEngine, halo_compress="int8", halo_cache=True,
              halo_refresh_every=2)
    seqC = mk(SequentialReference, halo_compress="int8", halo_cache=True,
              halo_refresh_every=2)
    d = bad_bytes = 0.0
    for prm in pseq + pseq[:1]:
        mA, prA = engC.evaluate(prm, "val", per_partition_params=False)
        mB, prB = seqC.evaluate(prm, "val", per_partition_params=False)
        d = max(d, float(jnp.abs(mA - mB).max()),
                float((np.asarray(prA) != np.asarray(prB)).sum()))
        bad_bytes += int(engC.last_halo_exchange_bytes
                         != seqC.last_halo_exchange_bytes)
    out["cached_int8"] = d
    out["cached_int8_bytes"] = bad_bytes
    return out


def run_async_parity(eng, seq, g, host_train, model, opt, seed, dtype):
    '''Fully-on-device phase-1 (device CBS draw + fanout + gather inside the
    fused step) vs the sequential reference running the SAME PRNG programs.'''
    ds = build_device_epoch_sampler(g, host_train, P, batch_size=BATCH,
                                    subset_fraction=0.25,
                                    class_balanced=True, fanouts=(3, 3),
                                    dtype=dtype)
    eng.set_device_sampler(ds)
    seq.set_device_sampler(ds)
    params = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(seed))
    pp = broadcast_to_partitions(params, P)
    po = jax.vmap(opt.init)(pp)
    keys = jax.random.split(jax.random.PRNGKey(seed), P)
    budgets = jnp.asarray(
        np.minimum(np.arange(P), ds.num_batches).astype(np.int32))
    ppA, poA, lA, vA, _ = eng.phase1_epoch_async(pp, po, keys, budgets, params)
    ppB, poB, lB, vB, _ = seq.phase1_epoch_async(pp, po, keys, budgets, params)
    i_run = np.asarray(lA).shape[0]
    return {"params": tree_maxdiff(ppA, ppB),
            "opt": tree_maxdiff(poA, poB),
            "loss": float(np.abs(np.asarray(lA)
                                 - np.asarray(lB)[:i_run]).max()),
            "val": float(np.abs(np.asarray(vA) - np.asarray(vB)).max())}


def run_featstore_parity(pg, g, host_train, model, loss_fn, opt, samplers,
                         make_batch, seed, dtype):
    '''Two-tier feature store parity (the PR-10 tentpole):
      1. sync phases + test eval: the feat-store engine (hot rows resident,
         cold rows staged host-side per compiled call) == the all-resident
         engine bit-for-bit through run_pair;
      2. hot_frac extremes: 0.0 (everything staged) and 1.0 (everything
         resident, ZERO cold bytes) both reproduce the resident eval;
      3. compositions: feat_store x PR-6 halo cache and feat_store x PR-9
         int8 halo quantization each == the same composition all-resident;
      4. the fully-fused async epochs (phase-0 epoch program and phase-1
         budgeted scan) with a feat-store device sampler == the all-resident
         sampler running the SAME PRNG programs.'''
    kw = dict(mode="stacked", use_pallas_agg=False, dtype=dtype)
    mk = lambda **o: SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                                EngineConfig(**kw, **o))
    out = {}
    base = mk()
    fs = mk(feat_store=True, hot_frac=0.25)
    for k, v in run_pair(fs, base, model, opt, samplers, make_batch,
                         seed, dtype).items():
        out[f"sync_{k}"] = v
    params = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(seed))
    pseq = [jax.tree.map(lambda x: x * (1.0 + 0.05 * i), params)
            for i in range(3)]
    cases = [("hot0", dict(hot_frac=0.0), {}),
             ("hot1", dict(hot_frac=1.0), {}),
             ("cache", dict(hot_frac=0.25, halo_cache=True,
                            halo_refresh_every=2),
              dict(halo_cache=True, halo_refresh_every=2)),
             ("int8", dict(hot_frac=0.25, halo_compress="int8"),
              dict(halo_compress="int8"))]
    for tag, fso, refo in cases:
        eA = mk(feat_store=True, **fso)
        eB = mk(**refo)
        d = 0.0
        for prm in pseq:
            mA, prA = eA.evaluate(prm, "val", per_partition_params=False)
            mB, prB = eB.evaluate(prm, "val", per_partition_params=False)
            d = max(d, float(jnp.abs(mA - mB).max()),
                    float((np.asarray(prA) != np.asarray(prB)).sum()))
        out[f"{tag}_eval"] = d
        if tag == "hot1":           # all-hot must never stage a cold byte
            out["hot1_cold_bytes"] = float(eA.cold_h2d_bytes)
    dsF = build_device_epoch_sampler(g, host_train, P, batch_size=BATCH,
                                     subset_fraction=0.25,
                                     class_balanced=True, fanouts=(3, 3),
                                     dtype=dtype, feat_store=True,
                                     hot_frac=0.25)
    dsR = build_device_epoch_sampler(g, host_train, P, batch_size=BATCH,
                                     subset_fraction=0.25,
                                     class_balanced=True, fanouts=(3, 3),
                                     dtype=dtype)
    fs.set_device_sampler(dsF)
    base.set_device_sampler(dsR)
    opt_state = opt.init(params)
    keys = jax.random.split(jax.random.PRNGKey(seed ^ 0x10FE), P)
    pA, oA, lA, vA, _ = fs.phase0_epoch_async(params, opt_state, keys)
    pB, oB, lB, vB, _ = base.phase0_epoch_async(params, opt_state, keys)
    out["p0a_params"] = tree_maxdiff(pA, pB)
    out["p0a_opt"] = tree_maxdiff(oA, oB)
    out["p0a_loss"] = float(np.abs(np.asarray(lA) - np.asarray(lB)).max())
    out["p0a_val"] = float(np.abs(np.asarray(vA) - np.asarray(vB)).max())
    pp = broadcast_to_partitions(pA, P)
    po = jax.vmap(opt.init)(pp)
    budgets = jnp.asarray(
        np.minimum(np.arange(P), dsF.num_batches).astype(np.int32))
    ppA, poA, l1A, v1A, _ = fs.phase1_epoch_async(pp, po, keys, budgets, pA)
    ppB, poB, l1B, v1B, _ = base.phase1_epoch_async(pp, po, keys, budgets, pB)
    out["p1a_params"] = tree_maxdiff(ppA, ppB)
    out["p1a_opt"] = tree_maxdiff(poA, poB)
    out["p1a_loss"] = float(np.abs(np.asarray(l1A) - np.asarray(l1B)).max())
    out["p1a_val"] = float(np.abs(np.asarray(v1A) - np.asarray(v1B)).max())
    return out
"""

# --------------------------------------------------------------------------
# ONE fp64 subprocess for the whole module: smoke parity + budget matrix +
# async-path parity share a single interpreter and compilation set
# --------------------------------------------------------------------------

FP64_SHARED_SCRIPT = (
    CACHE_PRELUDE
    + "jax.config.update('jax_enable_x64', True)\n"
    + HARNESS
    + r"""
import json
out = {}
cfg = EngineConfig(mode="stacked", use_pallas_agg=False, dtype=jnp.float64)
g, pg, model, loss_fn, opt, samplers, make_batch, host_train = build_case(
    "ew", 0, True, np.float64)
eng = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(), cfg)
seq = SequentialReference(model, loss_fn, opt, pg, GPHyperParams(), cfg)
out["smoke"] = run_pair(eng, seq, model, opt, samplers, make_batch, 0,
                        jnp.float64)
out["budget"] = run_budget_parity(eng, seq, model, opt, samplers, make_batch,
                                  0, jnp.float64)
out["async"] = run_async_parity(eng, seq, g, host_train, model, opt, 0,
                                jnp.float64)
out["phase0_async"] = run_phase0_async_parity(eng, seq, g, host_train, model,
                                              opt, 0, jnp.float64)
out["overlap"] = run_overlap_parity(pg, model, loss_fn, opt, samplers,
                                    make_batch, 0, jnp.float64)
out["fullgraph"] = run_fullgraph_parity(eng, seq, model, opt, 0, jnp.float64)
cfgO = EngineConfig(mode="stacked", use_pallas_agg=False, overlap_halo=True,
                    dtype=jnp.float64)
engO = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(), cfgO)
seqO = SequentialReference(model, loss_fn, opt, pg, GPHyperParams(), cfgO)
out["fullgraph_overlap"] = run_fullgraph_parity(engO, seqO, model, opt, 0,
                                                jnp.float64)
out["halo_cache"] = run_halo_cache_parity(pg, model, loss_fn, opt, 0,
                                          jnp.float64)
out["halo_cache_async"] = run_halo_cache_async_parity(pg, g, host_train,
                                                      model, loss_fn, opt, 0,
                                                      jnp.float64)
out["comm_compress"] = run_comm_compress_parity(pg, model, loss_fn, opt,
                                                samplers, make_batch, 0,
                                                jnp.float64)
out["featstore"] = run_featstore_parity(pg, g, host_train, model, loss_fn,
                                        opt, samplers, make_batch, 0,
                                        jnp.float64)
print("RESULTS", json.dumps(out))
"""
)


@pytest.fixture(scope="module")
def fp64_shared():
    res = subprocess.run([sys.executable, "-c", FP64_SHARED_SCRIPT],
                         capture_output=True, text=True, timeout=1800,
                         env=SUBPROC_ENV)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS "):])


def test_engine_matches_sequential_fp64_smoke(fp64_shared):
    """Single-config fast variant of the bit-for-bit check (tier-1: the full
    matrix runs under -m slow)."""
    assert all(v == 0 for v in fp64_shared["smoke"].values()), fp64_shared["smoke"]


def test_budget_parity_and_zero_budget_noop_fp64(fp64_shared):
    """Random per-partition budgets (incl. 0 and full-epoch) through the
    masked scan == sequential loops bit-for-bit; an all-zero budget step is
    a bitwise no-op on params and optimizer state."""
    assert all(v == 0 for v in fp64_shared["budget"].values()), fp64_shared["budget"]


def test_async_device_sampling_parity_fp64(fp64_shared):
    """The fully-on-device async phase-1 == sequential reference running the
    same per-partition PRNG programs, bit-for-bit in fp64."""
    assert all(v == 0 for v in fp64_shared["async"].values()), fp64_shared["async"]


def test_phase0_async_parity_fp64(fp64_shared):
    """The fused phase-0 device program (epoch draw + train scan + fused
    eval) == the sequential oracle running the same PRNG programs, bit-for-
    bit in fp64, with AND without CBS; the fused eval == a standalone
    evaluate() on the resulting params, also bitwise."""
    assert all(v == 0 for v in fp64_shared["phase0_async"].values()), \
        fp64_shared["phase0_async"]


def test_overlap_split_forward_parity_fp64(fp64_shared):
    """The boundary/interior split forward: overlapped engine == overlapped
    sequential reference bit-for-bit; overlapped == synchronous forward
    bit-for-bit on owned rows (micro-F1 and owned predictions); the chunked
    ppermute ring == the all_to_all exchange bit-for-bit."""
    assert all(v == 0 for v in fp64_shared["overlap"].values()), \
        fp64_shared["overlap"]


def test_halo_cache_parity_fp64(fp64_shared):
    """Historical halo cache: staleness 0 (K=1) == the sync forward bitwise;
    K=3 (cv off AND on) cached engine == cached sequential oracle bitwise
    across a 6-eval sequence; == an independent closed-form stale oracle
    (h1 halo rows recomputed from the last-refresh params, no incremental
    cache state); comm counters report only the refreshed-row payload, with
    CV chunks summing to one full exchange per cycle."""
    assert all(v == 0 for v in fp64_shared["halo_cache"].values()), \
        fp64_shared["halo_cache"]


def test_halo_cache_async_parity_fp64(fp64_shared):
    """The cached fused phase-0 async epoch (halo cache carried as state
    through the one device program) == the sequential oracle bitwise across
    3 epochs at K=2, including the byte counters."""
    assert all(v == 0 for v in fp64_shared["halo_cache_async"].values()), \
        fp64_shared["halo_cache_async"]


def test_fullgraph_train_parity_fp64(fp64_shared):
    """Full-graph phase-0 training: the fused engine's value_and_grad
    through the distributed forward (gradients crossing partitions via the
    halo exchange's VJP) == the sequential reference differentiating the
    Python-loop forward, bit-for-bit in fp64 — for both the synchronous and
    the overlapped split forward."""
    assert all(v == 0 for v in fp64_shared["fullgraph"].values()), \
        fp64_shared["fullgraph"]
    assert all(v == 0 for v in fp64_shared["fullgraph_overlap"].values()), \
        fp64_shared["fullgraph_overlap"]


def test_comm_compress_parity_fp64(fp64_shared):
    """PR-9: quantized halo exchange (fp16/int8 with error feedback) and
    compressed gradient reduction (bucketed/top-k) match the sequential
    fp64 oracle bit-for-bit; stacked bucketed == mode none; the ppermute
    ring moves bit-identical compressed payloads; int8 composes with the
    halo cache; byte counters agree, stay positive, and sit strictly below
    the uncompressed wire size (compress=off reports exactly the old
    accounting)."""
    assert all(v == 0 for v in fp64_shared["comm_compress"].values()), \
        fp64_shared["comm_compress"]


def test_featstore_parity_fp64(fp64_shared):
    """PR-10: the two-tier feature store is bitwise invisible — training +
    eval through the feat-store engine (sync run_pair phases, hot_frac 0.0
    and 1.0 extremes, the fused async phase-0/phase-1 epochs with a
    feat-store device sampler, and the compositions with the halo cache and
    int8 halo quantization) all equal the all-resident engine bit-for-bit,
    and hot_frac=1.0 stages zero cold bytes."""
    assert all(v == 0 for v in fp64_shared["featstore"].values()), \
        fp64_shared["featstore"]


# --------------------------------------------------------------------------
# the full (slow) fp64 matrix: seeds × methods × sampler regimes, each with
# the gate smoke AND a random budget vector
# --------------------------------------------------------------------------

FP64_MATRIX_SCRIPT = (
    CACHE_PRELUDE
    + "jax.config.update('jax_enable_x64', True)\n"
    + HARNESS
    + r"""
import itertools, json
failures = {}
for method, seed, use_cbs in itertools.product(
        ("ew", "metis", "random"), (0, 1), (True, False)):
    cfg = EngineConfig(mode="stacked", use_pallas_agg=False,
                       dtype=jnp.float64)
    g, pg, model, loss_fn, opt, samplers, make_batch, host_train = build_case(
        method, seed, use_cbs, np.float64)
    eng = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(), cfg)
    seq = SequentialReference(model, loss_fn, opt, pg, GPHyperParams(), cfg)
    d = run_pair(eng, seq, model, opt, samplers, make_batch, seed, jnp.float64)
    d.update({"bud_" + k: v for k, v in run_budget_parity(
        eng, seq, model, opt, samplers, make_batch, seed, jnp.float64).items()})
    if any(v != 0 for v in d.values()):
        failures[f"{method}/seed{seed}/cbs={use_cbs}"] = d
print("FAILURES", json.dumps(failures))
"""
)


@pytest.mark.slow
def test_engine_matches_sequential_bitforbit_fp64():
    """Fused SPMD engine == sequential reference, bit-for-bit in float64,
    across partition methods, seeds, sampler regimes and budget vectors."""
    # 12 configs × (compile + run); generous timeout — a loaded host can be
    # an order of magnitude slower than the unloaded wall time
    res = subprocess.run([sys.executable, "-c", FP64_MATRIX_SCRIPT],
                         capture_output=True, text=True, timeout=5400,
                         env=SUBPROC_ENV)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("FAILURES")][0]
    assert line == "FAILURES {}", line


SPMD_SCRIPT = (
    "import os\n"
    "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
    + CACHE_PRELUDE
    + HARNESS
    + r"""
import json
g, pg, model, loss_fn, opt, samplers, make_batch, host_train = build_case(
    "ew", 0, True, np.float32)
eng = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                 EngineConfig(mode="spmd", use_pallas_agg=True))
stk = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                 EngineConfig(mode="stacked", use_pallas_agg=True))
assert eng.mode == "spmd", eng.mode
d = run_pair(eng, stk, model, opt, samplers, make_batch, 0, jnp.float32)
print("DIFFS", json.dumps(d))
"""
)


def test_spmd_shard_map_matches_stacked():
    """shard_map over a real 4-device partition mesh == single-device
    stacked vmap, up to collective-reduction rounding (few f32 ulps)."""
    res = subprocess.run([sys.executable, "-c", SPMD_SCRIPT],
                         capture_output=True, text=True, timeout=1800,
                         env=SUBPROC_ENV)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("DIFFS")][0]
    d = json.loads(line[len("DIFFS "):])
    # pmean (tree-wise collective) vs stacked jnp.sum/P, and per-device vs
    # vmapped batch reductions, may differ in the last ulp; everything
    # downstream must stay within tight float32 slack.  Micro-F1/argmax get
    # a hair of slack too: an ulp-level param drift can legitimately flip
    # the argmax of a near-tied logit pair on a handful of nodes.
    assert d["p0_loss"] <= 1e-6 and d["p1_loss"] <= 1e-5, d
    assert d["p0_params"] <= 1e-6 and d["p1_params"] <= 1e-5, d
    assert d["p0_val"] <= 5e-3 and d["p1_val"] <= 5e-3, d
    assert d["test_micro"] <= 5e-3 and d["test_pred_mismatch"] <= 3, d


SPMD_FP64_ASYNC_SCRIPT = (
    "import os\n"
    "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
    + CACHE_PRELUDE
    + "jax.config.update('jax_enable_x64', True)\n"
    + HARNESS
    + r"""
import json
g, pg, model, loss_fn, opt, samplers, make_batch, host_train = build_case(
    "ew", 0, True, np.float64)
cfg = EngineConfig(mode="spmd", use_pallas_agg=False, dtype=jnp.float64)
cfgS = EngineConfig(mode="stacked", use_pallas_agg=False, dtype=jnp.float64)
eng = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(), cfg)
assert eng.mode == "spmd", eng.mode
seq = SequentialReference(model, loss_fn, opt, pg, GPHyperParams(), cfgS)
d = run_phase0_async_parity(eng, seq, g, host_train, model, opt, 0,
                            jnp.float64)
# staleness 0 on the REAL mesh: a K=1 cached spmd engine == the sync spmd
# forward bitwise, and every eval pays the full exchange
engC = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                  EngineConfig(mode="spmd", use_pallas_agg=False,
                               dtype=jnp.float64, halo_cache=True,
                               halo_refresh_every=1))
base = jax.tree.map(lambda x: jnp.asarray(x, jnp.float64), model.init(0))
dd = bb = 0
for i in range(3):
    prm = jax.tree.map(lambda x: x * (1.0 + 0.1 * i), base)
    mS, prS = eng.evaluate(prm, "val", per_partition_params=False)
    mC, prC = engC.evaluate(prm, "val", per_partition_params=False)
    dd = max(dd, float(jnp.abs(mS - mC).max()),
             float((np.asarray(prS) != np.asarray(prC)).sum()))
    bb += int(engC.last_halo_exchange_bytes != 2 * pg.halo_bytes_per_layer)
d["spmd_staleness0"] = dd
d["spmd_staleness0_bytes"] = float(bb)
# structural wire witness: the refresh plan is a host-side constant, so the
# pure-cached spmd eval program must lower with NO all_to_all at all — the
# wire win is structural, not just a zeroed counter.  (The stacked-vmap mode
# cannot witness this: vmap resolves collectives to data movement at trace
# time.)
engD = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                  EngineConfig(mode="spmd", use_pallas_agg=False,
                               dtype=jnp.float64, halo_cache=True,
                               halo_refresh_every=4))
hlo_full = jax.jit(lambda p, c: engD._eval_spmd_cached(
    p, c, "val", False, (0, engD.max_send))).lower(
    base, engD._halo_state).as_text()
hlo_cached = jax.jit(lambda p, c: engD._eval_spmd_cached(
    p, c, "val", False, (0, 0))).lower(base, engD._halo_state).as_text()
d["hlo_collective_witness"] = float("all_to_all" not in hlo_full
                                    or "all_to_all" in hlo_cached)
# feat-store on the REAL mesh: the hot/cold split (and its halo-cache /
# int8-quantization compositions) is bitwise invisible under shard_map too —
# the staged cold tier enters the program before any collective runs
for tag, o in (("plain", {}),
               ("cache", dict(halo_cache=True, halo_refresh_every=2)),
               ("int8", dict(halo_compress="int8"))):
    eF = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                    EngineConfig(mode="spmd", use_pallas_agg=False,
                                 dtype=jnp.float64, feat_store=True,
                                 hot_frac=0.25, **o))
    assert eF.mode == "spmd", eF.mode
    eR = SPMDEngine(model, loss_fn, opt, pg, GPHyperParams(),
                    EngineConfig(mode="spmd", use_pallas_agg=False,
                                 dtype=jnp.float64, **o))
    fd = 0.0
    for i in range(3):
        prm = jax.tree.map(lambda x: x * (1.0 + 0.1 * i), base)
        mF, prF = eF.evaluate(prm, "val", per_partition_params=False)
        mR, prR = eR.evaluate(prm, "val", per_partition_params=False)
        fd = max(fd, float(jnp.abs(mF - mR).max()),
                 float((np.asarray(prF) != np.asarray(prR)).sum()))
    d[f"spmd_featstore_{tag}"] = fd
print("RESULTS", json.dumps(d))
"""
)


def test_phase0_async_spmd_parity_fp64():
    """The fused phase-0 program under shard_map on a REAL 4-device
    partition mesh == the sequential oracle, bit-for-bit in fp64 (CBS and
    uniform draws).  Bitwise across a real mesh is achievable because the
    program's only collectives are pure data movement (the epoch has no
    pmean: the gradient all-reduce is an all_gather followed by the same
    deterministic local stack-sum the oracle performs, and the fused eval's
    exchange is an all_to_all).  Also checks halo-cache staleness 0 on the
    real mesh: a K=1 cached spmd engine == the sync spmd forward bitwise."""
    res = subprocess.run([sys.executable, "-c", SPMD_FP64_ASYNC_SCRIPT],
                         capture_output=True, text=True, timeout=1800,
                         env=SUBPROC_ENV)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULTS")][0]
    d = json.loads(line[len("RESULTS "):])
    assert all(v == 0 for v in d.values()), d


# --------------------------------------------------------------------------
# Pallas segment_agg on the hot path
# --------------------------------------------------------------------------

def _build_f32_engines(use_pallas):
    from repro.core import partition_graph, GPHyperParams
    from repro.engine import EngineConfig, SPMDEngine
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS["tiny"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                        method="ew", seed=0)
    pg = build_partitioned_graph(g, r.parts, 4)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes)
    opt = AdamW(lr=1e-3)
    eng = SPMDEngine(model, model.make_loss_fn(), opt, pg, GPHyperParams(),
                     EngineConfig(mode="stacked", use_pallas_agg=use_pallas))
    return model, eng


def test_distributed_forward_calls_pallas_segment_agg():
    """The engine's eval forward must stage the Pallas kernel (trace-time
    call counter) and agree with the jnp segment-op reference engine."""
    from repro.core.gp.trainer import broadcast_to_partitions
    from repro.kernels import segment_agg as sa

    model, eng_pal = _build_f32_engines(use_pallas=True)
    _, eng_ref = _build_f32_engines(use_pallas=False)
    params = broadcast_to_partitions(model.init(0), 4)

    before = sa.pallas_call_count()
    micro_pal, preds_pal = eng_pal.evaluate(params, "val")
    after = sa.pallas_call_count()
    assert after > before, "segment_agg_pallas was never staged by the engine"

    micro_ref, preds_ref = eng_ref.evaluate(params, "val")
    np.testing.assert_allclose(np.asarray(micro_pal), np.asarray(micro_ref),
                               atol=1e-6)
    agree = (np.asarray(preds_pal) == np.asarray(preds_ref)).mean()
    assert agree > 0.999, f"pallas/ref argmax agreement only {agree}"


def test_fullgraph_train_through_pallas_kernel():
    """Full-graph phase-0 through the Pallas path: the train scan must stage
    the aggregation kernel in BOTH directions (forward + the custom VJP's
    transpose kernel), and the resulting parameters must match the jnp
    segment-op engine to float32 rounding."""
    import jax.numpy as jnp

    from repro.kernels import segment_agg as sa

    model, eng_pal = _build_f32_engines(use_pallas=True)
    _, eng_ref = _build_f32_engines(use_pallas=False)
    params = model.init(0)
    opt_state = eng_pal.optimizer.init(params)

    before = sa.pallas_call_count()
    pP, oP, lP, vP, _ = eng_pal.phase0_fullgraph_epoch(params, opt_state, 2)
    staged = sa.pallas_call_count() - before
    # 2 layers x (fwd + transpose-bwd) in the train trace, + the eval fwd
    assert staged >= 5, f"expected fwd AND bwd kernels staged, got {staged}"

    pR, oR, lR, vR, _ = eng_ref.phase0_fullgraph_epoch(params, opt_state, 2)
    np.testing.assert_allclose(np.asarray(lP), np.asarray(lR), atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(pP),
                    jax.tree_util.tree_leaves(pR)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # training moved the params (the step is not a no-op)
    moved = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(pP), jax.tree_util.tree_leaves(params)))
    assert moved > 0


# --------------------------------------------------------------------------
# segment_agg ragged-degree property sweep (Pallas kernel vs ref oracle)
# --------------------------------------------------------------------------

def _csr_from_degrees(deg, n, rng):
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n, int(indptr[-1]))
    return indptr, indices.astype(np.int64)


def _degree_profile(kind, n, rng):
    if kind == "powerlaw":
        deg = np.minimum((1.0 / rng.power(2.0, n) - 1).astype(np.int64), 200)
        return np.maximum(deg, 0)
    if kind == "isolated":
        deg = rng.integers(0, 6, n)
        deg[rng.random(n) < 0.5] = 0          # half the graph isolated
        return deg
    if kind == "giant_hub":
        deg = rng.integers(0, 4, n)
        deg[int(rng.integers(0, n))] = 5000   # one hub spanning many blocks
        return deg
    raise ValueError(kind)


@pytest.mark.parametrize("kind", ["powerlaw", "isolated", "giant_hub"])
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("mean", [True, False])
def test_segment_agg_ragged_degree_sweep(kind, seed, mean):
    """Pallas blocked segment aggregation == jnp oracle on adversarial
    degree distributions (ragged blocks, empty rows, single giant hub)."""
    from repro.kernels import ops, ref

    import zlib

    rng = np.random.default_rng([seed, zlib.crc32(kind.encode())])
    n = 300
    deg = _degree_profile(kind, n, rng)
    indptr, indices = _csr_from_degrees(deg, n, rng)
    x = jnp.asarray(rng.normal(0, 1, (n, 24)).astype(np.float32))
    agg = ops.make_segment_agg(indptr, indices, mean=mean)
    got = np.asarray(agg(x))
    src = jnp.asarray(indices)
    dst = jnp.asarray(np.repeat(np.arange(n), np.diff(indptr)))
    want = np.asarray(ref.segment_agg_ref(x, src, dst, n, mean=mean))
    # hub rows sum thousands of values: scale tolerance with degree
    tol = 1e-4 * max(1.0, float(deg.max()) ** 0.5) if not mean else 2e-4
    np.testing.assert_allclose(got, want, atol=tol, rtol=2e-4)
    if mean:
        assert np.abs(got[deg == 0]).max() == 0.0  # empty rows stay zero


# --------------------------------------------------------------------------
# row-range (masked) segment_agg variant: the overlapped forward's boundary
# pass — ragged sub-ranges incl. the zero-boundary / all-boundary partitions
# --------------------------------------------------------------------------

def _padded_blocks(blocks):
    """Pad an EdgeBlocks to >= 1 block (the zero-range case), the same
    guard engine.stacking applies when stacking split structures."""
    from repro.kernels.segment_agg import BN, EdgeBlocks

    if blocks.num_blocks:
        return blocks
    be = blocks.edges_per_block
    return EdgeBlocks(
        num_nodes=0, num_blocks=1, edges_per_block=be,
        src=np.zeros((1, be), np.int32), local_dst=np.zeros((1, be), np.int32),
        mask=np.zeros((1, be), np.float32), deg=np.ones((1, BN), np.float32))


@pytest.mark.parametrize("split_kind",
                         ["zero_boundary", "all_boundary", "mixed",
                          "unaligned_tail"])
@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("mean", [True, False])
def test_segment_agg_rows_ragged_range_sweep(split_kind, seed, mean):
    """``segment_agg_rows`` (blocked aggregation over a REBASED destination
    sub-range, placed at a row offset) == the jnp row-range oracle, across
    ragged range positions: empty range (zero-boundary partition), the full
    node space (all-boundary), and block-unaligned interior offsets."""
    import zlib

    from repro.kernels import ref
    from repro.kernels.segment_agg import build_edge_blocks, segment_agg_rows

    rng = np.random.default_rng([seed, zlib.crc32(split_kind.encode())])
    n = 300
    n_int = {"zero_boundary": n, "all_boundary": 0,
             "mixed": int(rng.integers(1, n - 1)),
             "unaligned_tail": n - 37}[split_kind]
    range_rows = n - n_int
    deg = rng.integers(0, 8, range_rows) if range_rows else np.zeros(0, np.int64)
    indptr = np.zeros(range_rows + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n, int(indptr[-1])).astype(np.int64)
    x = jnp.asarray(rng.normal(0, 1, (n, 24)).astype(np.float32))

    blocks = _padded_blocks(build_edge_blocks(indptr, indices))
    msgs = x[jnp.asarray(blocks.src.reshape(-1))]
    got = np.asarray(segment_agg_rows(
        msgs, jnp.asarray(blocks.local_dst), jnp.asarray(blocks.mask),
        jnp.asarray(blocks.deg), row_base=n_int, num_rows=n, mean=mean))
    want = np.asarray(ref.segment_agg_rows_ref(
        x, jnp.asarray(indices),
        jnp.asarray(np.repeat(np.arange(range_rows), deg)),
        max(1, range_rows), n_int, n, mean=mean))
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)
    # rows outside [row_base, n) are exactly zero — the guarantee the
    # bitwise-safe per-row select in the overlapped forward relies on
    assert np.abs(got[:n_int]).max(initial=0.0) == 0.0


# --------------------------------------------------------------------------
# historical halo cache: in-process structural witnesses (f32)
# --------------------------------------------------------------------------

def _build_halo_engine(**halo_kw):
    from repro.core import partition_graph, GPHyperParams
    from repro.engine import EngineConfig, SPMDEngine
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS["tiny"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                        method="ew", seed=0)
    pg = build_partitioned_graph(g, r.parts, 4)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes)
    eng = SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3), pg,
                     GPHyperParams(),
                     EngineConfig(mode="stacked", use_pallas_agg=False,
                                  halo_cache=True, **halo_kw))
    return pg, model, eng


def test_halo_slot_bytes_full_range_matches_per_layer():
    """halo_slot_bytes is the refreshed-payload meter: the full slot range
    reproduces halo_bytes_per_layer, the empty range is free, and any chunk
    split partitions the payload exactly (what the CV accounting relies on)."""
    pg, _, _ = _build_halo_engine(halo_refresh_every=2)
    max_s = pg.send_idx.shape[-1]
    assert pg.halo_slot_bytes(0, max_s) == pg.halo_bytes_per_layer
    assert pg.halo_slot_bytes(0, 0) == 0
    mid = max_s // 2
    assert (pg.halo_slot_bytes(0, mid) + pg.halo_slot_bytes(mid, max_s)
            == pg.halo_bytes_per_layer)


def test_halo_cache_rejects_incompatible_configs():
    """overlap_halo hides the exchange the cache removes (pick one), and
    full-graph training must differentiate through a LIVE exchange."""
    from repro.core import partition_graph, GPHyperParams
    from repro.engine import EngineConfig, SPMDEngine
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS["tiny"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                        method="ew", seed=0)
    pg = build_partitioned_graph(g, r.parts, 4)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes)
    mk = lambda cfg: SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3),
                                pg, GPHyperParams(), cfg)
    with pytest.raises(ValueError, match="overlap"):
        mk(EngineConfig(mode="stacked", use_pallas_agg=False,
                        halo_cache=True, overlap_halo=True))
    eng = mk(EngineConfig(mode="stacked", use_pallas_agg=False,
                          halo_cache=True))
    params = model.init(0)
    with pytest.raises(ValueError, match="full-graph"):
        eng.phase0_fullgraph_epoch(params, None, iters=1)
