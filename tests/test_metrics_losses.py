import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic random-sampling shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.train.losses import cross_entropy_loss, focal_loss, prox_penalty
from repro.train.metrics import f1_scores, f1_scores_jnp
from repro.models.transformer import chunked_ce_loss


# ---------------------------------------------------------------- metrics --

def test_f1_perfect():
    preds = np.array([0, 1, 2, 2, 1])
    r = f1_scores(preds, preds, 3)
    assert r.micro == r.macro == r.weighted == 1.0


def test_f1_known_case():
    # classic 2-class example
    labels = np.array([0, 0, 0, 1, 1])
    preds = np.array([0, 0, 1, 1, 0])
    r = f1_scores(preds, labels, 2)
    # class0: tp=2 fp=1 fn=1 -> f1=2*2/(4+1+1)=0.8/..: 4/(4+2)=0.666..? compute:
    # f1_0 = 2*2/(2*2+1+1)=4/6; f1_1 = 2*1/(2*1+1+1)=2/4
    assert r.per_class[0] == pytest.approx(4 / 6)
    assert r.per_class[1] == pytest.approx(0.5)
    assert r.micro == pytest.approx(3 / 5)          # accuracy
    assert r.weighted == pytest.approx((4 / 6) * 0.6 + 0.5 * 0.4)


def test_f1_ignores_unlabelled():
    labels = np.array([0, 1, -1, -1])
    preds = np.array([0, 1, 1, 0])
    assert f1_scores(preds, labels, 2).micro == 1.0


@given(st.integers(1, 500))
@settings(max_examples=25, deadline=None)
def test_f1_jnp_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    n, k = 200, 6
    labels = rng.integers(0, k, n)
    preds = rng.integers(0, k, n)
    r = f1_scores(preds, labels, k)
    micro, macro, weighted = f1_scores_jnp(jnp.asarray(preds),
                                           jnp.asarray(labels), k)
    assert float(micro) == pytest.approx(r.micro, abs=1e-5)
    assert float(macro) == pytest.approx(r.macro, abs=1e-5)
    assert float(weighted) == pytest.approx(r.weighted, abs=1e-5)


def test_micro_f1_is_accuracy():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 4, 300)
    preds = rng.integers(0, 4, 300)
    assert f1_scores(preds, labels, 4).micro == pytest.approx(
        (preds == labels).mean())


# ----------------------------------------------------------------- losses --

def test_ce_uniform_logits():
    logits = jnp.zeros((8, 10))
    labels = jnp.arange(8) % 10
    assert float(cross_entropy_loss(logits, labels)) == pytest.approx(
        np.log(10), abs=1e-5)


def test_ce_masks_negative_labels():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(6, 4)),
                         jnp.float32)
    labels = jnp.array([0, 1, 2, -1, -1, -1])
    a = cross_entropy_loss(logits, labels)
    b = cross_entropy_loss(logits[:3], labels[:3])
    assert float(a) == pytest.approx(float(b), rel=1e-6)


def test_focal_downweights_easy():
    """Well-classified example contributes far less under focal loss."""
    easy = jnp.array([[10.0, 0.0]])
    hard = jnp.array([[0.5, 0.0]])
    lab = jnp.array([0])
    ce_ratio = float(cross_entropy_loss(hard, lab) / cross_entropy_loss(easy, lab))
    fl_ratio = float(focal_loss(hard, lab) / focal_loss(easy, lab))
    assert fl_ratio > 10 * ce_ratio


def test_prox_penalty_zero_at_global():
    p = {"a": jnp.ones((3, 3)), "b": {"c": jnp.zeros(5)}}
    assert float(prox_penalty(p, p)) == 0.0
    q = jax.tree.map(lambda x: x + 1.0, p)
    assert float(prox_penalty(q, p)) == pytest.approx(9 + 5)


def test_chunked_ce_matches_dense():
    rng = np.random.default_rng(0)
    t, d, v = 64, 16, 50
    h = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, t), jnp.int32)
    labels = labels.at[5].set(-1)
    want = cross_entropy_loss(h @ w, labels)
    for chunk in (8, 16, 64, 37):
        got = chunked_ce_loss(h, w, labels, chunk=chunk)
        assert float(got) == pytest.approx(float(want), rel=1e-5), chunk


def test_chunked_ce_grad_matches_dense():
    rng = np.random.default_rng(1)
    t, d, v = 32, 8, 20
    h = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, t), jnp.int32)
    g1 = jax.grad(lambda w_: chunked_ce_loss(h, w_, labels, chunk=8))(w)
    g2 = jax.grad(lambda w_: cross_entropy_loss(h @ w_, labels))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_f1_out_of_range_preds_are_fn_only():
    """An out-of-range prediction names no class: fn on the true class,
    fp nowhere — and the numpy path must not crash or wrap indices."""
    labels = np.array([0, 1, 1])
    preds = np.array([-3, 2, 0])       # negative, == num_classes, valid-miss
    tp_fp_fn = f1_scores(preds, labels, 2)
    # class 0: tp=0 fp=1(from pred 0 on label 1) fn=1; class 1: tp=0 fp=0 fn=2
    assert tp_fp_fn.per_class.tolist() == [0.0, 0.0]
    micro, macro, weighted = f1_scores_jnp(jnp.asarray(preds),
                                           jnp.asarray(labels), 2)
    assert float(micro) == pytest.approx(tp_fp_fn.micro, abs=1e-6)
    # a negative pred must NOT be counted as class 0: one real class-0 fp
    # (the valid miss), not two
    labels2 = np.array([1, 1])
    preds2 = np.array([-1, 0])
    m_np = f1_scores(preds2, labels2, 2)
    m_j = f1_scores_jnp(jnp.asarray(preds2), jnp.asarray(labels2), 2)
    assert float(m_j[0]) == pytest.approx(m_np.micro, abs=1e-6)


@given(st.integers(1, 500))
@settings(max_examples=25, deadline=None)
def test_f1_jnp_matches_numpy_adversarial(seed):
    """Parity sweep with adversarial preds: negatives, == num_classes,
    beyond num_classes, mixed with unlabelled and all-invalid labels."""
    rng = np.random.default_rng(seed)
    n, k = 120, 5
    labels = rng.integers(0, k, n)
    labels[rng.random(n) < 0.3] = -1          # unlabelled mix
    if seed % 5 == 0:
        labels[:] = -1                        # all-invalid labels
    preds = rng.integers(-2, k + 2, n)        # includes -2..-1 and k..k+1
    r = f1_scores(preds, labels, k)
    micro, macro, weighted = f1_scores_jnp(jnp.asarray(preds),
                                           jnp.asarray(labels), k)
    assert float(micro) == pytest.approx(r.micro, abs=1e-5)
    assert float(macro) == pytest.approx(r.macro, abs=1e-5)
    assert float(weighted) == pytest.approx(r.weighted, abs=1e-5)


def test_f1_all_preds_out_of_range():
    labels = np.array([0, 1, 2])
    preds = np.array([3, 4, -1])
    r = f1_scores(preds, labels, 3)
    assert r.micro == 0.0 and r.macro == 0.0 and r.weighted == 0.0
    micro, macro, weighted = f1_scores_jnp(jnp.asarray(preds),
                                           jnp.asarray(labels), 3)
    assert float(micro) == 0.0 and float(macro) == 0.0
