"""Partitioned GNN serving engine tests (the PR-7 tentpole).

1. fp64 bitwise oracle (subprocess, so ``jax_enable_x64`` cannot leak):
   after ANY scripted sequence of feature updates, cross-partition edge
   additions (including a source the partition had never seen — halo
   growth) and edge removals, the served logits equal a from-scratch
   ``SequentialReference`` forward over the rebuilt graph bit-for-bit —
   across two stacked update rounds, so the incremental dirty-set path
   cannot drift from the full recompute.
2. Query batching: one fused device gather per owning partition per tick,
   results equal to the store rows.
3. Pallas aggregation path: serving with ``segment_mean_op`` on the
   recompute kernel agrees with the jnp segment-op path.
4. Layer-count comm accounting: a 3-layer SAGE reports
   ``num_layers * halo_bytes_per_layer`` per full refresh (regression for
   the hardcoded ``2 *`` in ``_halo_tick``) and still matches the
   sequential reference's predictions.
5. AOT cache-key stability: re-evaluating with FRESH identically-sharded
   arrays must not recompile (``compile_count`` regression).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _jax_cache import CACHE_PRELUDE, REPO_ROOT

SUBPROC_ENV = {"PYTHONPATH": os.path.join(REPO_ROOT, "src"),
               "PATH": "/usr/bin:/bin", "HOME": os.path.expanduser("~")}


# --------------------------------------------------------------------------
# shared tiny-graph serving fixture (f32, in-process tests)
# --------------------------------------------------------------------------

def _build(num_layers=2, dtype=jnp.float32, **cfg_kw):
    from repro.core import GPHyperParams, partition_graph
    from repro.engine import EngineConfig, SPMDEngine
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS["tiny"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                        method="ew", seed=0)
    pg = build_partitioned_graph(g, r.parts, 4)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes, num_layers=num_layers)
    cfg = EngineConfig(mode="stacked", use_pallas_agg=False, dtype=dtype,
                      **cfg_kw)
    eng = SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3), pg,
                     GPHyperParams(), cfg)
    prm = jax.tree.map(lambda x: jnp.asarray(x, dtype), model.init(0))
    return g, r, pg, model, cfg, eng, prm


@pytest.fixture(scope="module")
def served():
    from repro.serve import GNNServingEngine

    g, r, pg, model, cfg, eng, prm = _build()
    export = eng.export_serving_state(prm)
    srv = GNNServingEngine(model, prm, pg, export)
    return g, pg, model, prm, export, srv


# --------------------------------------------------------------------------
# 1. the fp64 bitwise serving oracle
# --------------------------------------------------------------------------

ORACLE_SCRIPT = CACHE_PRELUDE + """
import jax
jax.config.update('jax_enable_x64', True)
import numpy as np, jax.numpy as jnp
from repro.core import partition_graph, GPHyperParams
from repro.engine import EngineConfig, SPMDEngine
from repro.engine.sequential import SequentialReference
from repro.graph import BENCHMARKS, GraphSAGE, build_partitioned_graph, \\
    make_benchmark
from repro.serve import GNNServingEngine, apply_updates_to_graph
from repro.train.optim import AdamW

g = make_benchmark(BENCHMARKS["tiny"])
P = 4
r = partition_graph(g.indptr, g.indices, g.features, g.labels, P,
                    method="ew", seed=0)
pg = build_partitioned_graph(g, r.parts, P)
model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                  num_classes=g.num_classes)
cfg = EngineConfig(mode="stacked", use_pallas_agg=False, dtype=jnp.float64)
eng = SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3), pg,
                 GPHyperParams(), cfg)
prm = jax.tree.map(lambda x: jnp.asarray(x, jnp.float64), model.init(0))
srv = GNNServingEngine(model, prm, pg, eng.export_serving_state(prm),
                       planner_compact_after=1)


def oracle_logits(graph):
    # from-scratch forward on the REBUILT graph, same partition assignment
    pg2 = build_partitioned_graph(graph, r.parts, P)
    seq = SequentialReference(model, model.make_loss_fn(), AdamW(lr=1e-3),
                              pg2, config=cfg)
    logits = seq._full_forward([prm] * P)
    out = np.zeros((graph.num_nodes, model.num_classes))
    for p in range(P):
        n = int(pg2.n_own[p])
        out[np.asarray(pg2.global_ids[p])[:n]] = np.asarray(logits[p])[:n]
    return out


assert (srv.export_logits() == oracle_logits(g)).all(), "initial not bitwise"

# scripted round 1: random feature updates (float32 — graph features are
# f32, the oracle quantizes through them), a cross-partition edge add whose
# source the destination partition has NEVER seen (halo growth), a
# same-partition add, and a removal
rng = np.random.default_rng(7)
parts = r.parts
fupd = {int(v): rng.normal(0, 1, g.feature_dim).astype(np.float32)
        for v in rng.choice(g.num_nodes, 5, replace=False)}
target = None
for v in range(g.num_nodes):
    p = parts[v]
    for u in range(g.num_nodes):
        if u == v or parts[u] == p or u in srv.g2l[p] or u in g.neighbors(v):
            continue
        target = (u, v); break
    if target: break
adds = [target]
for v in range(g.num_nodes):
    p = parts[v]
    cand = [u for u in range(g.num_nodes)
            if u != v and parts[u] == p and u not in g.neighbors(v)]
    if cand:
        adds.append((cand[0], v)); break
v0 = next(v for v in range(g.num_nodes) if len(g.neighbors(v)) > 1)
rem = [(int(g.neighbors(v0)[0]), v0)]

for gid, vec in fupd.items():
    srv.update_features(gid, vec)
for u, v in adds:
    assert srv.add_edge(u, v)
for u, v in rem:
    assert srv.remove_edge(u, v)
st = srv.flush()
assert st["rows_recomputed"] > 0 and srv.stats["halo_rows_grown"] > 0
g2 = apply_updates_to_graph(g, fupd, adds, rem)
s2, o2 = srv.export_logits(), oracle_logits(g2)
bad = np.flatnonzero((s2 != o2).any(axis=1))
assert bad.size == 0, (bad.size, float(np.abs(s2 - o2).max()))

# round 2 ON TOP (sequence property): more features + remove the added edge
fupd2 = {int(v): rng.normal(0, 1, g.feature_dim).astype(np.float32)
         for v in rng.choice(g.num_nodes, 3, replace=False)}
rem2 = [adds[0]]
for gid, vec in fupd2.items():
    srv.update_features(gid, vec)
for u, v in rem2:
    assert srv.remove_edge(u, v)
srv.flush()
g3 = apply_updates_to_graph(g2, fupd2, (), rem2)
assert (srv.export_logits() == oracle_logits(g3)).all(), "round 2 not bitwise"
# compact_after=1: the static-CSC removal in round 1 compacted eagerly and
# serving stayed bitwise THROUGH the compaction
assert srv.planner.compactions >= 1, srv.planner.compactions

# query batching: one fused gather per owning partition, rows match store
q = [0, 1, 2, 3, 17, 101]
srv.submit(q)
before = srv.stats["gather_calls"]
res, _ = srv.tick()
assert srv.stats["gather_calls"] - before \\
    == len({int(srv.owner_part[x]) for x in q})
full = srv.export_logits()
assert all((v == full[k]).all() for k, v in res.items())
print("SERVE-ORACLE-OK")
"""


@pytest.mark.slow
def test_serving_bitwise_oracle_fp64():
    r = subprocess.run([sys.executable, "-c", ORACLE_SCRIPT],
                       capture_output=True, text=True, env=SUBPROC_ENV,
                       cwd=REPO_ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SERVE-ORACLE-OK" in r.stdout


# --------------------------------------------------------------------------
# 2-3. in-process f32: export handoff, batching counters, Pallas path
# --------------------------------------------------------------------------

def test_export_matches_evaluate(served):
    """export_serving_state's logits reproduce evaluate()'s predictions."""
    g, pg, model, prm, export, srv = served
    assert tuple(a.shape[-1] for a in export["layers"]) \
        == tuple(model.layer_dims[:-1])
    preds = np.full(g.num_nodes, -1)
    for p in range(pg.num_parts):
        n = int(pg.n_own[p])
        own = np.asarray(pg.global_ids[p])[:n]
        preds[own] = np.asarray(export["logits"][p])[:n].argmax(-1)
    assert (srv.export_logits().argmax(-1) == preds).all()


def test_query_batching_one_gather_per_partition(served):
    g, pg, model, prm, export, srv = served
    q = [0, 5, 9, 42, 311]
    srv.submit(q)
    before = srv.stats["gather_calls"]
    res, lat = srv.tick()
    owning = {int(srv.owner_part[x]) for x in q}
    assert srv.stats["gather_calls"] - before == len(owning)
    assert set(res) == set(q)
    full = srv.export_logits()
    assert all((v == full[k]).all() for k, v in res.items())


def test_pallas_recompute_path_matches_ref(served):
    """Serving with the Pallas segment kernel on the recompute path agrees
    with the jnp segment-op path after identical updates."""
    from repro.serve import GNNServingEngine

    g, pg, model, prm, export, _ = served
    rng = np.random.default_rng(3)
    upd = {int(v): rng.normal(0, 1, g.feature_dim).astype(np.float32)
           for v in rng.choice(g.num_nodes, 4, replace=False)}
    outs = []
    for pallas in (False, True):
        srv = GNNServingEngine(model, prm, pg, export,
                               use_pallas_agg=pallas, interpret=True)
        for gid, vec in upd.items():
            srv.update_features(gid, vec)
        srv.flush()
        outs.append(srv.export_logits())
    np.testing.assert_allclose(outs[1], outs[0], atol=5e-6, rtol=1e-5)


# --------------------------------------------------------------------------
# 4. layer-count comm accounting (regression: hardcoded ``2 *`` factor)
# --------------------------------------------------------------------------

def test_three_layer_halo_accounting_and_parity():
    """A 3-layer SAGE pays THREE exchanges per full refresh — the counter
    must say so (the old code hardcoded 2) — and the stacked engine still
    matches the sequential reference's predictions layer-for-layer."""
    from repro.core import GPHyperParams
    from repro.engine import EngineConfig, SPMDEngine
    from repro.engine.sequential import SequentialReference
    from repro.train.optim import AdamW

    g, r, pg, model, cfg, eng, prm = _build(num_layers=3, halo_cache=True,
                                            halo_refresh_every=1)
    assert model.num_layers == 3
    micro, preds = eng.evaluate(prm, "val", per_partition_params=False)
    assert eng.last_halo_exchange_bytes == 3 * pg.halo_bytes_per_layer

    seq = SequentialReference(model, model.make_loss_fn(), AdamW(lr=1e-3),
                              pg, GPHyperParams(),
                              EngineConfig(mode="stacked",
                                           use_pallas_agg=False,
                                           dtype=jnp.float32,
                                           halo_cache=True,
                                           halo_refresh_every=1))
    mS, pS = seq.evaluate(prm, "val", per_partition_params=False)
    assert (np.asarray(preds) == np.asarray(pS)).all()
    assert seq.last_halo_exchange_bytes == 3 * pg.halo_bytes_per_layer


def test_three_layer_serving_roundtrip():
    """Serving built from a 3-layer checkpoint: h stores for every layer,
    and an update round keeps predictions consistent with a fresh export."""
    from repro.serve import GNNServingEngine, apply_updates_to_graph
    from repro.core import GPHyperParams
    from repro.engine import SPMDEngine
    from repro.graph import build_partitioned_graph
    from repro.train.optim import AdamW

    g, r, pg, model, cfg, eng, prm = _build(num_layers=3)
    srv = GNNServingEngine(model, prm, pg, eng.export_serving_state(prm))
    assert len(srv.h) == model.num_layers + 1   # h0..h2 + logits

    rng = np.random.default_rng(11)
    upd = {int(v): rng.normal(0, 1, g.feature_dim).astype(np.float32)
           for v in rng.choice(g.num_nodes, 3, replace=False)}
    for gid, vec in upd.items():
        srv.update_features(gid, vec)
    srv.flush()

    g2 = apply_updates_to_graph(g, upd, (), ())
    pg2 = build_partitioned_graph(g2, r.parts, 4)
    eng2 = SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3), pg2,
                      GPHyperParams(), cfg)
    fresh = eng2.export_serving_state(prm)
    want = np.zeros((g.num_nodes, model.num_classes), np.float32)
    for p in range(pg2.num_parts):
        n = int(pg2.n_own[p])
        want[np.asarray(pg2.global_ids[p])[:n]] = \
            np.asarray(fresh["logits"][p])[:n]
    np.testing.assert_allclose(srv.export_logits(), want, atol=2e-5,
                               rtol=1e-5)


# --------------------------------------------------------------------------
# 5. AOT cache-key stability (compile_count regression)
# --------------------------------------------------------------------------

def test_no_recompile_on_fresh_identically_sharded_inputs():
    """Fresh arrays with identical shape/dtype/sharding must hit the AOT
    cache — a re-lowering per step was the serving-latency bug."""
    _, _, _, model, _, eng, prm = _build()
    eng.evaluate(prm, "val", per_partition_params=False)
    n0 = eng.compile_count
    assert n0 >= 1
    for _ in range(3):
        fresh = jax.tree.map(
            lambda x: jnp.asarray(np.asarray(x), x.dtype), prm)
        eng.evaluate(fresh, "val", per_partition_params=False)
    assert eng.compile_count == n0, "identically-sharded inputs recompiled"


# --------------------------------------------------------------------------
# 6. hot-row query cache + planner adjacency compaction (PR-9 satellites)
# --------------------------------------------------------------------------

def test_hot_row_cache_hits_and_invalidation():
    """Repeat queries hit the LRU hot-row cache (no extra gathers), a flush
    that recomputes a row evicts exactly it, and answers always equal the
    logits store."""
    from repro.serve import GNNServingEngine

    g, r, pg, model, cfg, eng, prm = _build()
    srv = GNNServingEngine(model, prm, pg, eng.export_serving_state(prm))
    q = [0, 5, 9]
    a = srv.query(q)
    assert srv.stats["cache_misses"] == len(q)
    assert srv.stats["cache_hits"] == 0

    before = srv.stats["gather_calls"]
    b = srv.query(q)
    assert srv.stats["cache_hits"] == len(q)
    assert srv.stats["gather_calls"] == before, "cache hit still gathered"
    assert (a == b).all()

    rng = np.random.default_rng(0)
    srv.update_features(q[0], rng.normal(0, 1, g.feature_dim)
                        .astype(np.float32))
    c = srv.query(q)
    full = srv.export_logits()
    assert (c == full[np.asarray(q)]).all(), "cache served a stale row"
    assert srv.stats["cache_misses"] >= len(q) + 1   # q[0] re-gathered


def test_hot_row_cache_lru_capacity():
    from repro.serve import GNNServingEngine

    g, r, pg, model, cfg, eng, prm = _build()
    srv = GNNServingEngine(model, prm, pg, eng.export_serving_state(prm),
                           hot_cache_rows=2)
    srv.query([0, 5, 9, 42])
    assert len(srv._hot) == 2
    # whatever survived the LRU eviction serves as hits, byte-for-byte
    resident = list(srv._hot)
    before = srv.stats["cache_hits"]
    res = srv.query(resident)
    assert srv.stats["cache_hits"] == before + len(resident)
    full = srv.export_logits()
    assert (res == full[np.asarray(resident)]).all()


def test_planner_compaction_exact_adjacency():
    """With compact_after=1 every static-edge removal compacts its shard:
    the planner's out_rows then equal EXACTLY the adjacency implied by the
    live aggregation lists (no stale over-propagating out-edges), and the
    compaction count surfaces in serving stats."""
    from repro.serve import GNNServingEngine

    g, r, pg, model, cfg, eng, prm = _build()
    srv = GNNServingEngine(model, prm, pg, eng.export_serving_state(prm),
                           planner_compact_after=1)
    removed = []
    for v in range(g.num_nodes):
        for u in g.neighbors(v):
            if u != v:
                removed.append((int(u), int(v)))
                break
        if len(removed) >= 6:
            break
    assert len(removed) >= 2, "tiny graph has no removable edges?"
    for u, v in removed:
        assert srv.remove_edge(u, v)
    assert srv.planner.compactions >= 1
    srv.flush()
    assert srv.stats["planner_compactions"] == srv.planner.compactions

    for p in range(pg.num_parts):
        want: dict[int, set] = {}
        for w in range(int(srv.n_own[p])):
            for s in srv.nbr_loc[p][w]:
                want.setdefault(int(s), set()).add(w)
        n_rows = len(srv.planner._csc[p][0]) - 1
        for row in range(n_rows):
            got = set(map(int, srv.planner.out_rows(p, np.asarray([row]))))
            assert got == want.get(row, set()), (p, row)


def test_export_serving_state_cached_compile():
    _, _, _, model, _, eng, prm = _build()
    eng.export_serving_state(prm)
    n0 = eng.compile_count
    fresh = jax.tree.map(lambda x: jnp.asarray(np.asarray(x), x.dtype), prm)
    out = eng.export_serving_state(fresh)
    assert eng.compile_count == n0
    assert len(out["layers"]) == model.num_layers
