"""Property + engine tier for the two-tier feature store (DESIGN.md §12).

Locks the PR-10 tentpole's load-bearing invariants:

  · the hot/cold split gather is BITWISE equal to a direct full-feature
    gather — for arbitrary access patterns (duplicates, out-of-order),
    hot fractions including 0.0 and 1.0, and ragged partitions;
  · hot-set construction is a permutation (no row lost or duplicated);
  · the feat-store engine's eval is bitwise the all-resident engine's,
    and the feat_groups streamed eval is bitwise the sequential oracle's;
  · ``cold_h2d_bytes`` follows the closed-form ``cold_rows x D x itemsize``
    per staging, and ``hot_frac=1.0`` reports exactly the pre-PR-10
    counters (regression lock on the existing accounting).
"""
import functools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic random-sampling shim
    from _hypothesis_shim import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import partition_graph
from repro.engine import EngineConfig, SPMDEngine, SequentialReference
from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                         make_benchmark)
from repro.graph.featstore import (FeatureBudgetError, assemble_features,
                                   build_global_feat_store,
                                   build_partition_feat_store,
                                   check_feat_budget, feat_peak_bytes,
                                   hot_order, reconstruct_features)
from repro.train.optim import AdamW

P = 4


# a plain cached builder, not a pytest fixture: @given-decorated tests
# cannot take fixtures (the hypothesis shim presents a zero-arg signature)
@functools.lru_cache(maxsize=1)
def _case():
    g = make_benchmark(BENCHMARKS["tiny"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, P,
                        method="ew", seed=0)
    pg = build_partitioned_graph(g, r.parts, P)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes)
    loss_fn = model.make_loss_fn()
    opt = AdamW(lr=3e-3, grad_clip=5.0)
    params = model.init(0)
    return g, pg, model, loss_fn, opt, params


@pytest.fixture(scope="module")
def case():
    return _case()


def _engine(case, **kw):
    g, pg, model, loss_fn, opt, params = case
    return SPMDEngine(model, loss_fn, opt, pg,
                      config=EngineConfig(mode="stacked", use_pallas_agg=False,
                                          **kw))


# ---------------------------------------------------------------- properties

@settings(max_examples=20)
@given(st.floats(0.0, 1.0), st.sampled_from(["degree", "freq"]))
def test_partition_split_reconstructs_bitwise(hot_frac, policy):
    """Scattering hot + cold tiers into a zero plane reproduces the ragged
    partitioned feature stack bitwise (the module invariant), and each
    partition's tier rows partition range(own_cap)."""
    pg = _case()[1]
    fs = build_partition_feat_store(pg, hot_frac, policy, np.float32)
    ref = np.asarray(pg.features, np.float32)
    rec = reconstruct_features(fs, pg.max_nodes)
    assert rec.shape == ref.shape
    assert (rec == ref).all()
    own_cap = pg.own_cap
    for p in range(P):
        rows = np.concatenate([fs.rows_hot[p], fs.rows_cold[p]])
        assert np.array_equal(np.sort(rows), np.arange(own_cap))


@settings(max_examples=20)
@given(st.floats(0.0, 1.0), st.sampled_from(["degree", "freq"]))
def test_partition_assemble_on_trace_bitwise(hot_frac, policy):
    """The ON-TRACE assembly (what the engine's compiled calls run) is
    bitwise the resident shard plane, hot_frac 0.0 and 1.0 included."""
    pg = _case()[1]
    fs = build_partition_feat_store(pg, hot_frac, policy, np.float32)
    ref = jnp.asarray(pg.features, jnp.float32)
    for p in range(P):
        plane = assemble_features(
            jnp.asarray(fs.hot[p]), jnp.asarray(fs.rows_hot[p]),
            jnp.asarray(fs.cold[p]), jnp.asarray(fs.rows_cold[p]),
            pg.max_nodes)
        assert (np.asarray(plane) == np.asarray(ref[p])).all()


@settings(max_examples=25)
@given(st.floats(0.0, 1.0), st.sampled_from(["degree", "freq"]),
       st.lists(st.integers(0, 599), min_size=1, max_size=64),
       st.booleans())
def test_global_store_gather_bitwise(hot_frac, policy, idx, dup):
    """Batch gathers through remap into [hot | cold] equal a direct gather
    from the full feature table — with duplicate and out-of-order indices
    (exactly what fanout sampling produces)."""
    g = _case()[0]
    gfs = build_global_feat_store(g, hot_frac, policy, np.float32)
    idx = np.asarray(idx, np.int64)
    if dup:  # force duplicates + reversal on top of the drawn pattern
        idx = np.concatenate([idx, idx[::-1]])
    table = np.concatenate([gfs.hot, gfs.cold], axis=0)
    direct = np.asarray(g.features, np.float32)[idx]
    assert (table[gfs.remap[idx]] == direct).all()


@settings(max_examples=10)
@given(st.floats(0.0, 1.0), st.sampled_from(["degree", "freq"]))
def test_global_store_is_permutation(hot_frac, policy):
    g = _case()[0]
    gfs = build_global_feat_store(g, hot_frac, policy, np.float32)
    ids = np.concatenate([gfs.hot_ids, gfs.cold_ids])
    assert np.array_equal(np.sort(ids), np.arange(g.num_nodes))
    # remap is the inverse permutation split at Nh
    assert np.array_equal(np.sort(gfs.remap), np.arange(g.num_nodes))
    nh = gfs.hot.shape[0]
    assert (gfs.remap[gfs.hot_ids] == np.arange(nh)).all()


def test_hot_order_deterministic_stable():
    scores = np.array([3.0, 1.0, 3.0, 2.0, 1.0])
    order = hot_order(scores)
    # descending score, ties broken by index (stable)
    assert order.tolist() == [0, 2, 3, 1, 4]
    assert np.array_equal(order, hot_order(scores))


def test_bad_hot_frac_and_policy_raise(case):
    pg = case[1]
    with pytest.raises(ValueError, match="hot_frac"):
        build_partition_feat_store(pg, 1.5, "degree", np.float32)
    with pytest.raises(ValueError, match="hot_policy"):
        build_partition_feat_store(pg, 0.5, "nope", np.float32)


# ------------------------------------------------------------ budget guard

def test_feat_budget_error_is_value_error():
    assert issubclass(FeatureBudgetError, ValueError)
    check_feat_budget(0.0, 10**12)          # disabled: never raises
    check_feat_budget(1.0, 999_999)         # under budget
    with pytest.raises(FeatureBudgetError, match="feat_budget_mb"):
        check_feat_budget(1.0, 1_000_001)


def test_feat_peak_bytes_monotone():
    base = feat_peak_bytes(4, 1000, 64, 4)
    store = feat_peak_bytes(4, 1000, 64, 4, hot_rows=100, cold_rows=900)
    streamed = feat_peak_bytes(4, 1000, 64, 4, hot_rows=100, cold_rows=900,
                               groups=1)
    assert streamed < store
    assert streamed < base
    assert base == 4 * 1000 * 64 * 4


def test_engine_refuses_over_budget(case):
    with pytest.raises(FeatureBudgetError):
        _engine(case, feat_budget_mb=1e-3)
    _engine(case, feat_budget_mb=10.0)   # generous budget builds fine


def test_streaming_passes_budget_all_resident_fails(case):
    """The bigger-than-stack gate in miniature: a budget between the
    streamed peak and the all-resident footprint."""
    g, pg = case[0], case[1]
    base_peak = feat_peak_bytes(P, pg.max_nodes, g.feature_dim, 4)
    budget_mb = base_peak * 0.6 / 1e6
    with pytest.raises(FeatureBudgetError):
        _engine(case, feat_budget_mb=budget_mb)
    eng = _engine(case, feat_store=True, hot_frac=0.25, feat_groups=1,
                  feat_budget_mb=budget_mb)
    assert eng.mode == "stacked"


# ------------------------------------------------------- engine-level locks

def test_feat_store_eval_bitwise_all_resident(case):
    params = case[5]
    base = _engine(case)
    fs = _engine(case, feat_store=True, hot_frac=0.25)
    for split in ("val", "test"):
        m0, p0 = base.evaluate(params, split, per_partition_params=False)
        m1, p1 = fs.evaluate(params, split, per_partition_params=False)
        assert (np.asarray(m0) == np.asarray(m1)).all()
        assert (np.asarray(p0) == np.asarray(p1)).all()


def test_streamed_eval_bitwise_sequential(case):
    g, pg, model, loss_fn, opt, params = case
    st_eng = _engine(case, feat_store=True, hot_frac=0.25, feat_groups=2)
    seq = SequentialReference(model, loss_fn, opt, pg,
                              config=EngineConfig(mode="sequential"))
    m0, p0 = st_eng.evaluate(params, "test", per_partition_params=False)
    m1, p1 = seq.evaluate(params, "test", per_partition_params=False)
    assert (np.asarray(m0) == np.asarray(m1)).all()
    assert (np.asarray(p0) == np.asarray(p1)).all()


def test_cold_bytes_closed_form(case):
    """k plain evals stage exactly k * P*C*D*B cold bytes; the streamed
    eval pays the deliberate 2x (pass A + pass B); hot_frac=1.0 is 0."""
    params = case[5]
    eng = _engine(case, feat_store=True, hot_frac=0.25)
    C = eng._fs.cold.shape[1]
    D = eng._fs.cold.shape[2]
    per_eval = P * C * D * np.dtype(np.float32).itemsize
    assert eng._fs.cold.nbytes == per_eval
    for k in range(1, 4):
        eng.evaluate(params, "val", per_partition_params=False)
        assert eng.cold_h2d_bytes == k * per_eval

    st_eng = _engine(case, feat_store=True, hot_frac=0.25, feat_groups=2)
    st_eng.evaluate(params, "val", per_partition_params=False)
    assert st_eng.cold_h2d_bytes == 2 * per_eval

    full = _engine(case, feat_store=True, hot_frac=1.0)
    assert full._fs.cold.shape[1] == 0
    full.evaluate(params, "val", per_partition_params=False)
    assert full.cold_h2d_bytes == 0


def test_async_cold_bytes_closed_form(case):
    """Fused async epochs: phase-0 stages the sampler's Nc*D*B cold table
    plus the fused eval's P*C*D*B; phase-1's train scan stages only the
    sampler table, its separate val eval the engine tier."""
    from repro.core import broadcast_to_partitions
    from repro.core.sampler import build_device_epoch_sampler

    g, pg, model, loss_fn, opt, params = case
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, P,
                        method="ew", seed=0)
    host_train = [g.train_idx[r.parts[g.train_idx] == p] for p in range(P)]
    eng = _engine(case, feat_store=True, hot_frac=0.25)
    ds = build_device_epoch_sampler(g, host_train, P, batch_size=32,
                                    fanouts=(3, 3), feat_store=True,
                                    hot_frac=0.25)
    eng.set_device_sampler(ds)
    opt_state = opt.init(params)
    keys = jax.random.split(jax.random.PRNGKey(1), P)
    eng.phase0_epoch_async(params, opt_state, keys)
    expect_p0 = ds.cold_host.nbytes + eng._fs.cold.nbytes
    assert eng.cold_h2d_bytes == expect_p0

    pp = broadcast_to_partitions(params, P)
    po = jax.vmap(opt.init)(pp)
    bud = jnp.asarray(np.full(P, 2, np.int32))
    eng.phase1_epoch_async(pp, po, keys, bud, params)
    assert eng.cold_h2d_bytes == expect_p0 + ds.cold_host.nbytes \
        + eng._fs.cold.nbytes


# ------------------------------------------------------------ config guards

def test_config_guards(case):
    g, pg, model, loss_fn, opt, params = case
    with pytest.raises(ValueError, match="feat_store"):
        _engine(case, feat_groups=2)                 # groups need the store
    with pytest.raises(ValueError, match="feat_groups"):
        _engine(case, feat_store=True, feat_groups=9)
    with pytest.raises(ValueError, match="stacked"):
        SPMDEngine(model, loss_fn, opt, pg,
                   config=EngineConfig(mode="spmd", feat_store=True,
                                       feat_groups=2))
    with pytest.raises(ValueError, match="pick one"):
        _engine(case, feat_store=True, feat_groups=2, halo_cache=True)
    with pytest.raises(ValueError, match="all-resident oracle"):
        SequentialReference(model, loss_fn, opt, pg,
                            config=EngineConfig(mode="sequential",
                                                feat_store=True))
    eng = _engine(case, feat_store=True, hot_frac=0.25)
    with pytest.raises(ValueError, match="full-graph"):
        eng.phase0_fullgraph_epoch(params, opt.init(params))
    # streamed engines reject the fused async phase-0 (the streamed eval
    # cannot live inside one device program)
    from repro.core.sampler import build_device_epoch_sampler
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, P,
                        method="ew", seed=0)
    host_train = [g.train_idx[r.parts[g.train_idx] == p] for p in range(P)]
    ds_fs = build_device_epoch_sampler(g, host_train, P, batch_size=32,
                                       fanouts=(3, 3), feat_store=True)
    st_eng = _engine(case, feat_store=True, hot_frac=0.25, feat_groups=2)
    st_eng.set_device_sampler(ds_fs)
    with pytest.raises(ValueError, match="feat_groups"):
        st_eng.phase0_epoch_async(params, opt.init(params),
                                  jax.random.split(jax.random.PRNGKey(0), P))


def test_pipeline_config_guards():
    from repro.pipeline import EATConfig, run_eat_distgnn
    with pytest.raises(ValueError, match="full_graph_train"):
        run_eat_distgnn(EATConfig(dataset="tiny", feat_store=True,
                                  full_graph_train=True))
    with pytest.raises(ValueError, match="async"):
        run_eat_distgnn(EATConfig(dataset="tiny", feat_store=True,
                                  feat_groups=2, async_generalize=True))


def test_sampler_engine_agreement(case):
    from repro.core.sampler import build_device_epoch_sampler
    g = case[0]
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, P,
                        method="ew", seed=0)
    host_train = [g.train_idx[r.parts[g.train_idx] == p] for p in range(P)]
    ds_plain = build_device_epoch_sampler(g, host_train, P, batch_size=32,
                                          fanouts=(3, 3))
    ds_fs = build_device_epoch_sampler(g, host_train, P, batch_size=32,
                                       fanouts=(3, 3), feat_store=True)
    eng = _engine(case, feat_store=True, hot_frac=0.25)
    with pytest.raises(ValueError, match="feat-store mismatch"):
        eng.set_device_sampler(ds_plain)
    base = _engine(case)
    with pytest.raises(ValueError, match="feat-store mismatch"):
        base.set_device_sampler(ds_fs)
    # make_batch's cold argument must match how the sampler was built
    with pytest.raises(ValueError, match="feat-store mismatch"):
        nodes = jnp.zeros((32,), jnp.int32)
        valid = jnp.ones((32,), jnp.float32)
        ds_fs.make_batch(jax.random.PRNGKey(0), nodes, valid)


# ----------------------------------------------- pipeline counter regression

def test_pipeline_hot_frac_one_matches_pre_store_counters():
    """hot_frac=1.0 keeps every row resident: the run must report exactly
    the counters (and micro-F1) of a no-store run — the regression lock on
    the pre-PR-10 accounting."""
    from repro.pipeline import EATConfig, run_eat_distgnn
    kw = dict(dataset="tiny", num_parts=P, batch_size=32, hidden_dim=16,
              fanouts=(3, 3), max_epochs=2, phase0_fraction=1.0, seed=3,
              use_pallas_agg=False, engine_mode="stacked")
    r0 = run_eat_distgnn(EATConfig(**kw))
    r1 = run_eat_distgnn(EATConfig(**kw, feat_store=True, hot_frac=1.0))
    assert r1.host_to_device_bytes_phase0 == r0.host_to_device_bytes_phase0
    assert r1.host_to_device_bytes_phase1 == r0.host_to_device_bytes_phase1
    assert r1.cold_h2d_bytes == 0
    assert r0.cold_h2d_bytes == 0
    assert r1.f1.micro == r0.f1.micro
    # hot_frac=1.0 keeps every OWN row resident; the hot tier is (P, own_cap,
    # D) while the resident plane is (P, max_nodes, D) incl. zero halo slots,
    # so the footprint may only shrink, never grow
    assert 0 < r1.resident_feature_bytes <= r0.resident_feature_bytes
