"""Compressed-communication unit tests (the PR-9 tentpole's codecs).

1. Wire codecs (``quantize_rows`` / ``dequantize_rows``): hypothesis
   property sweep over row counts, widths, dynamic-range exponents, modes
   and input dtypes (f32 AND bf16) — deterministic payloads, all-zero rows
   round-trip to exact zeros (pad/trash hygiene), single-element rows, and
   the int8 worst-case round-trip error stays within the per-row
   ``amax / 127`` quantization-step bound.
2. Error feedback: over a repeated EF-quantized send of a fixed tensor the
   time-mean residual vanishes (the telescoping identity ``mean(deq) - x =
   -r_T / T``), a chi-squared-style statistic over normalized per-element
   mean residuals stays far below its degrees of freedom, and the EF
   cumulative error beats feedback-free requantization by a wide margin.
3. Gradient reducers: the stacked bucketed mean is BITWISE the plain
   ``sum/P`` (the property that lets compress=off share one oracle), and
   the stacked top-k reducer satisfies the EF conservation identity, ships
   exactly k entries per partition, and is deterministic.
4. Byte accounting: ``wire_row_bytes`` / ``grad_sync_wire_bytes`` formulas
   (dtype-truthful itemsize, no hardcoded fp32), the engine's
   ``halo_wire_bytes_per_layer`` == ``pg.halo_bytes_per_layer`` at
   compress=off on BOTH engines, and compressed eval reports the shrunken
   wire size.
5. Config validation: unknown modes, halo_compress × overlap_halo, and
   full-graph × top-k all raise.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic random-sampling shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.gp.trainer import (GRAD_COMPRESS_MODES, grad_sync_wire_bytes,
                                   grad_topk_size,
                                   make_bucketed_reduce_stacked,
                                   make_topk_reduce_stacked)
from repro.graph.distributed import (HALO_COMPRESS_MODES, dequantize_rows,
                                     quantize_rows, wire_row_bytes)


# --------------------------------------------------------------------------
# 1. codec property sweep
# --------------------------------------------------------------------------

@settings(max_examples=40)
@given(st.integers(1, 6), st.integers(1, 24), st.integers(-12, 12),
       st.sampled_from(["fp16", "int8"]), st.booleans())
def test_quantize_roundtrip_properties(n, d, scale_exp, mode, use_bf16):
    dtype = jnp.bfloat16 if use_bf16 else jnp.float32
    rng = np.random.default_rng((n * 7919 + d * 131 + scale_exp) & 0xFFFF)
    x_np = rng.normal(0.0, 1.0, (n, d)) * 2.0 ** scale_exp
    x_np[0] = 0.0                                   # all-zero row always in
    x = jnp.asarray(x_np, dtype)

    payload, scale = quantize_rows(x, mode)
    payload2, scale2 = quantize_rows(x, mode)
    assert (np.asarray(payload) == np.asarray(payload2)).all()
    if mode == "int8":
        assert payload.dtype == jnp.int8 and scale.dtype == jnp.float32
        assert (np.asarray(scale2) == np.asarray(scale)).all()
        assert float(np.asarray(scale).ravel()[0]) == 0.0   # zero-row scale
    else:
        assert payload.dtype == jnp.float16 and scale is None

    deq = np.asarray(dequantize_rows(payload, scale, mode, x.dtype),
                     np.float64)
    assert (deq[0] == 0.0).all(), "all-zero row must round-trip exactly"

    xf = np.asarray(x, np.float64)
    amax = np.abs(xf).max(axis=-1, keepdims=True)
    eps = float(jnp.finfo(dtype).eps)
    if mode == "int8":
        # one quantization step is amax/127; the round-trip error per
        # element is half a step plus the low-precision arithmetic slack
        # (x/scale and q*scale each round in the input dtype)
        limit = amax / 127.0 * (0.5 + 130.0 * eps) + 1e-30
    else:
        # fp16 downcast: half-ulp relative in the normal range, absolute
        # smallest-subnormal floor below it, plus input-dtype slack
        limit = np.maximum(np.abs(xf) * (2.0 ** -11 + eps), 2.0 ** -25)
    assert (np.abs(deq - xf) <= limit).all(), \
        (mode, dtype, float(np.abs(deq - xf).max()), float(limit.max()))


def test_quantize_single_element_rows():
    for dtype in (jnp.float32, jnp.bfloat16):
        x = jnp.asarray([[3.5], [0.0], [-2.0 ** -9]], dtype)
        q, s = quantize_rows(x, "int8")
        deq = np.asarray(dequantize_rows(q, s, "int8", dtype), np.float64)
        # d=1: the single element IS the row amax, so the round-trip error
        # collapses to pure dtype rounding (q lands on +-127 up to one ulp
        # of the division) — far inside the half-step bound
        xf = np.asarray(x, np.float64)
        eps = float(jnp.finfo(dtype).eps)
        assert (np.abs(deq - xf)
                <= np.abs(xf) * (1.0 / 127.0 + 4 * eps) + 1e-30).all()
        assert deq[1, 0] == 0.0


def test_quantize_unknown_mode_raises():
    x = jnp.ones((2, 3), jnp.float32)
    with pytest.raises(ValueError):
        quantize_rows(x, "int4")
    with pytest.raises(ValueError):
        dequantize_rows(x, None, "int4", jnp.float32)
    with pytest.raises(ValueError):
        wire_row_bytes(8, "int4")


# --------------------------------------------------------------------------
# 2. error feedback drives the mean residual to ~0
# --------------------------------------------------------------------------

def _ef_series(x, mode, steps):
    r = jnp.zeros_like(x)
    deqs, resids = [], []
    for _ in range(steps):
        y = x + r
        payload, scale = quantize_rows(y, mode)
        deq = dequantize_rows(payload, scale, mode, x.dtype)
        r = y - deq
        deqs.append(np.asarray(deq, np.float64))
        resids.append(np.asarray(r, np.float64))
    return np.stack(deqs), np.stack(resids)


@pytest.mark.parametrize("mode", ["fp16", "int8"])
def test_error_feedback_mean_residual_vanishes(mode):
    T = 64
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(0.0, 3.0, (4, 32)), jnp.float32)
    xf = np.asarray(x, np.float64)
    deqs, resids = _ef_series(x, mode, T)

    # telescoping identity: mean_t(deq_t) - x == -r_T / T (up to f32
    # accumulation), so the time-averaged transmission converges to x at
    # rate 1/T regardless of where the EF orbit settles
    amax = np.abs(xf).max(axis=-1, keepdims=True)
    step = (np.broadcast_to(amax / 127.0, xf.shape) if mode == "int8"
            else np.maximum(np.abs(xf) * 2.0 ** -10, 2.0 ** -24))
    slack = 64 * 1.2e-7 * amax
    err = deqs - xf                              # (T, n, d) transmit errors
    mu = err.mean(0)
    assert (np.abs(mu) <= step / T + slack).all()

    # chi-squared-style statistic over half-step-normalized mean errors:
    # with error feedback every element's time-mean error is ~1/T of its
    # quantization step, so the sum of squares sits orders of magnitude
    # inside the envelope of feedback-free requantization (which re-sends
    # the SAME error each step: z ~ O(1) per element)
    z_ef = mu / step
    stat_ef = float(np.sum(z_ef ** 2))
    assert stat_ef <= xf.size * (2.0 / T) ** 2, stat_ef

    payload, scale = quantize_rows(x, mode)
    deq1 = np.asarray(dequantize_rows(payload, scale, mode, x.dtype),
                      np.float64)
    stat_plain = float(np.sum(((deq1 - xf) / step) ** 2))
    assert stat_plain > 100 * stat_ef, (stat_plain, stat_ef)


# --------------------------------------------------------------------------
# 3. gradient reducers
# --------------------------------------------------------------------------

def _rand_grads(P, rng, dtype=np.float32):
    return {"w1": jnp.asarray(rng.normal(0, 1, (P, 13, 7)), dtype),
            "b1": jnp.asarray(rng.normal(0, 1, (P, 7)), dtype),
            "w2": jnp.asarray(rng.normal(0, 1, (P, 7, 3)), dtype)}


def test_bucketed_stacked_bitwise_equals_plain_mean():
    P = 4
    rng = np.random.default_rng(3)
    grads = _rand_grads(P, rng)
    # 64-byte buckets force many chunks with a ragged tail
    red = make_bucketed_reduce_stacked(P, 64)
    out = red(grads)
    ref = jax.tree.map(lambda g: jnp.sum(g, axis=0) / P, grads)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_topk_reduce_stacked_ef_conservation_and_sparsity():
    from jax.flatten_util import ravel_pytree

    P, frac = 4, 0.05
    rng = np.random.default_rng(5)
    grads = _rand_grads(P, rng)
    flat = jax.vmap(lambda g: ravel_pytree(g)[0])(grads)
    N = flat.shape[1]
    k = grad_topk_size(N, frac)
    res0 = jnp.asarray(rng.normal(0, 0.1, (P, N)), jnp.float32)

    red = make_topk_reduce_stacked(P, frac)
    avg, res1 = red(grads, res0)
    avg2, res1b = red(grads, res0)
    assert all((np.asarray(a) == np.asarray(b)).all()
               for a, b in zip(jax.tree_util.tree_leaves(avg),
                               jax.tree_util.tree_leaves(avg2)))
    assert (np.asarray(res1) == np.asarray(res1b)).all()

    # conservation: sent_p = (g_p + r_p) - r'_p has exactly k nonzeros and
    # P * avg == sum_p sent_p
    g_ef = np.asarray(flat) + np.asarray(res0)
    sent = g_ef - np.asarray(res1)
    assert ((np.abs(sent) > 0).sum(axis=1) <= k).all()
    assert ((np.abs(sent) > 0).sum(axis=1) >= 1).all()
    avg_flat, _ = ravel_pytree(avg)
    np.testing.assert_allclose(np.asarray(avg_flat) * P, sent.sum(0),
                               rtol=1e-6, atol=1e-6)

    # error feedback keeps what wasn't shipped: residual equals the unsent
    # remainder elementwise
    np.testing.assert_allclose(np.asarray(res1), g_ef - sent, rtol=1e-6,
                               atol=1e-6)


def test_grad_topk_size_bounds():
    assert grad_topk_size(1000, 0.01) == 10
    assert grad_topk_size(10, 0.001) == 1           # floor at one entry
    assert grad_topk_size(10, 9.9) == 10            # cap at param_count


# --------------------------------------------------------------------------
# 4. byte accounting (dtype-truthful, no hardcoded fp32)
# --------------------------------------------------------------------------

def test_wire_row_bytes_formula():
    assert wire_row_bytes(16, "none") == 64
    assert wire_row_bytes(16, "none", itemsize=8) == 128   # fp64 payload
    assert wire_row_bytes(16, "none", itemsize=2) == 32    # fp16 store
    assert wire_row_bytes(16, "fp16") == 32
    assert wire_row_bytes(16, "int8") == 20                # d + f32 scale
    assert wire_row_bytes(1, "int8") == 5


def test_grad_sync_wire_bytes_modes_and_ratios():
    B = 1000
    for P in (4, 8):
        none = grad_sync_wire_bytes("none", P, B)
        buck = grad_sync_wire_bytes("bucketed", P, B)
        assert none == P * (P - 1) * B * 4
        assert buck == 2 * (P - 1) * B * 4
        assert buck / none == 2 / P                 # 0.5 @ P=4, 0.25 @ P=8
    k = grad_topk_size(B, 0.01)
    assert grad_sync_wire_bytes("topk", 4, B, itemsize=4, topk_frac=0.01) \
        == 4 * 3 * k * 8
    assert grad_sync_wire_bytes("none", 4, B, itemsize=8) \
        == 2 * grad_sync_wire_bytes("none", 4, B, itemsize=4)
    assert grad_sync_wire_bytes("bucketed", 1, B) == 0
    with pytest.raises(ValueError):
        grad_sync_wire_bytes("stochastic", 4, B)


@pytest.fixture(scope="module")
def tiny_setup():
    from repro.core import GPHyperParams, partition_graph
    from repro.engine import EngineConfig, SPMDEngine, SequentialReference
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS["tiny"])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                        method="ew", seed=0)
    pg = build_partitioned_graph(g, r.parts, 4)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes)

    def mk(cls, **over):
        cfg = EngineConfig(mode="stacked", use_pallas_agg=False,
                           dtype=jnp.float32, **over)
        return cls(model, model.make_loss_fn(), AdamW(lr=1e-3), pg,
                   GPHyperParams(), cfg)

    return g, pg, model, mk, SPMDEngine, SequentialReference


def test_halo_wire_bytes_matches_pg_then_shrinks(tiny_setup):
    g, pg, model, mk, SPMDEngine, SequentialReference = tiny_setup
    d = int(pg.features.shape[-1])
    rows = int(np.asarray(pg.n_halo).sum())
    for cls in (SPMDEngine, SequentialReference):
        none = mk(cls)
        fp16 = mk(cls, halo_compress="fp16")
        int8 = mk(cls, halo_compress="int8")
        # compress=off reports EXACTLY the existing accounting (the lock
        # every pre-PR-9 byte assertion relies on)
        assert none.halo_wire_bytes_per_layer == pg.halo_bytes_per_layer
        assert fp16.halo_wire_bytes_per_layer == rows * wire_row_bytes(
            d, "fp16")
        assert int8.halo_wire_bytes_per_layer == rows * wire_row_bytes(
            d, "int8")
        assert (int8.halo_wire_bytes_per_layer
                < fp16.halo_wire_bytes_per_layer
                < none.halo_wire_bytes_per_layer)


def test_compressed_eval_reports_wire_bytes(tiny_setup):
    g, pg, model, mk, SPMDEngine, _ = tiny_setup
    eng = mk(SPMDEngine, halo_compress="int8")
    prm = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), model.init(0))
    eng.evaluate(prm, "val", per_partition_params=False)
    want = model.num_layers * eng.halo_wire_bytes_per_layer
    assert eng.last_halo_exchange_bytes == want
    assert want < model.num_layers * pg.halo_bytes_per_layer


# --------------------------------------------------------------------------
# 5. config validation
# --------------------------------------------------------------------------

def test_rejects_invalid_compression_configs(tiny_setup):
    g, pg, model, mk, SPMDEngine, SequentialReference = tiny_setup
    for cls in (SPMDEngine, SequentialReference):
        with pytest.raises(ValueError, match="halo_compress"):
            mk(cls, halo_compress="int4")
        with pytest.raises(ValueError, match="grad_compress"):
            mk(cls, grad_compress="stochastic")
        with pytest.raises(ValueError, match="overlap"):
            mk(cls, halo_compress="int8", overlap_halo=True)


def test_fullgraph_rejects_topk(tiny_setup):
    g, pg, model, mk, SPMDEngine, SequentialReference = tiny_setup
    from repro.train.optim import AdamW

    for cls in (SPMDEngine, SequentialReference):
        eng = mk(cls, grad_compress="topk")
        prm = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                           model.init(0))
        opt_state = AdamW(lr=1e-3).init(prm)
        with pytest.raises(ValueError, match="top-k"):
            eng.phase0_fullgraph_epoch(prm, opt_state, 1)


def test_mode_tuples_exported():
    assert HALO_COMPRESS_MODES == ("none", "fp16", "int8")
    assert GRAD_COMPRESS_MODES == ("none", "bucketed", "topk")
