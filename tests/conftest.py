import numpy as np
import pytest
import scipy.sparse as sp


@pytest.fixture(scope="session")
def homophilous_graph():
    """Small homophilous graph with imbalanced labels + correlated features."""
    rng = np.random.default_rng(7)
    n, k = 500, 5
    p = np.array([0.4, 0.25, 0.18, 0.12, 0.05])
    labels = rng.choice(k, n, p=p)
    rows, cols = [], []
    for i in range(n):
        for _ in range(6):
            if rng.random() < 0.8:
                cand = np.flatnonzero(labels == labels[i])
                j = int(rng.choice(cand))
            else:
                j = int(rng.integers(0, n))
            if j != i:
                rows.append(i)
                cols.append(j)
    a = sp.csr_matrix((np.ones(len(rows)), (rows, cols)), shape=(n, n))
    a = ((a + a.T) > 0).astype(np.float64).tocsr()
    a.setdiag(0)
    a.eliminate_zeros()
    feats = (np.eye(k)[labels] + rng.normal(0, 0.3, (n, k))).astype(np.float32)
    return a, feats, labels
