"""Launch-layer tests: spec sanitisation rules + a REAL (small-mesh)
lower/compile of every step kind in a subprocess with 8 host devices —
the same code path the production dry-run exercises at 256/512 chips."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest


def test_sanitize_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh_compat
    from repro.launch.steps import sanitize_spec
    make_mesh_compat((1,), ("model",))  # mesh construction is version-portable

    class FakeMesh:
        shape = {"data": 4, "model": 8, "pod": 2}

    m = FakeMesh()
    # divisible: kept
    assert sanitize_spec(P(None, "model"), (3, 64), m) == P(None, "model")
    # not divisible: dropped
    assert sanitize_spec(P(None, "model"), (3, 51865 % 100 + 3), m)[1] is None
    # tuple axes: partial drop from the right
    s = sanitize_spec(P(("pod", "data"), None), (4, 7), m)
    assert s[0] is None or s[0] == "pod"  # 8 doesn't divide 4 -> drop data
    s2 = sanitize_spec(P(("pod", "data"),), (8,), m)
    assert s2[0] == ("pod", "data")


from _jax_cache import CACHE_PRELUDE

# flaky-surface hardening: the cache prelude persists lowered/compiled
# artifacts under the repo's .jax_cache so repeated runs of this
# (compile-bound) test skip XLA
SMALL_MESH_SCRIPT = (
    'import os\n'
    'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"\n'
    + CACHE_PRELUDE
) + r"""
import json
import jax
from repro.configs import get_config, SHAPES, InputShape
from repro.launch.mesh import make_mesh_compat
from repro.launch.steps import build_step

def small_mesh(multi_pod=False):
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)

results = {}
cfg = get_config("llama3.2-1b").reduced()
shapes = {
    "train": InputShape("train", 64, 8, "train"),
    "prefill": InputShape("prefill", 64, 8, "prefill"),
    "decode": InputShape("decode", 64, 8, "decode"),
}
for mp in (False, True):
    mesh = small_mesh(mp)
    # the ambient mesh context lets with_sharding_constraint resolve bare
    # PartitionSpecs inside the model; `with mesh:` is the 0.4.x spelling of
    # the newer jax.set_mesh
    with mesh:
        for name, shape in shapes.items():
            built = build_step(cfg, shape, mesh)
            compiled = built.lower().compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):  # 0.4.x: one dict per computation
                cost = cost[0]
            results[f"{name}@{'2pod' if mp else '1pod'}"] = cost["flops"] > 0
        # phase-1 personalized step lowers too (the GP feature, distributed).
        # KNOWN LIMITATION: on the CPU backend, XLA's SPMD partitioner
        # aborts (SIGABRT after 'involuntary full rematerialization'
        # warnings, tracked as XLA b/433785288) when the vmapped per-replica
        # scan is partitioned across a THIRD mesh axis — so the personalize
        # compile is asserted on the single-pod mesh only.
        if not mp:
            built = build_step(cfg, shapes["train"], mesh, phase="personalize")
            compiled = built.lower().compile()
            results["personalize@1pod"] = True
print("RESULTS", json.dumps(results))
"""


@pytest.mark.slow
def test_small_mesh_all_step_kinds_compile():
    res = subprocess.run([sys.executable, "-c", SMALL_MESH_SCRIPT],
                         capture_output=True, text=True, timeout=1800,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULTS")][0]
    results = json.loads(line[len("RESULTS "):])
    assert len(results) == 7 and all(results.values()), results


def test_input_specs_all_archs_all_shapes():
    """input_specs builds ShapeDtypeStructs (no allocation) for all 40."""
    from repro.configs import ARCH_IDS, SHAPES, get_config, input_specs
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            variant = None
            cfg = get_config(arch)
            if shape.name == "long_500k" and not cfg.supports_long_context:
                cfg = get_config(arch, "swa")
            spec = input_specs(cfg, shape)
            assert isinstance(spec, dict) and spec
            if shape.kind == "decode":
                assert spec["token"].shape == (shape.global_batch, 1)
                leaves = [l for l in
                          __import__("jax").tree_util.tree_leaves(spec["caches"])]
                assert leaves, f"{arch} x {shape.name}: empty cache"
