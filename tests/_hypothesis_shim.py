"""Minimal stand-in for the parts of ``hypothesis`` this suite uses.

The real hypothesis (pinned in requirements-dev.txt) is preferred — it
shrinks counterexamples and explores adversarial corners.  This shim keeps
the property tests RUNNABLE in hermetic environments where the dependency is
absent: each ``@given`` test is executed over ``max_examples`` pseudo-random
draws from the declared strategies, seeded deterministically from the test
name so failures reproduce.

Only the strategy surface actually used by the suite is implemented:
``st.integers(lo, hi)``, ``st.floats(lo, hi)``, ``st.booleans()``,
``st.sampled_from(seq)`` and ``st.lists(elem, min_size=, max_size=)``.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0, **_: object) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(rng):
            k = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(k)]

        return _Strategy(draw)


strategies = _Strategies()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_: object):
    """Records max_examples on the wrapped test (deadline etc. ignored)."""

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                drawn = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as e:  # surface the failing example
                    raise AssertionError(
                        f"{fn.__name__} failed on shim example {i}: {drawn!r}"
                    ) from e

        # pytest must see a ZERO-arg signature (drawn args are not fixtures);
        # functools.wraps' __wrapped__ would expose the original one.
        del wrapper.__wrapped__
        return wrapper

    return deco
