"""Shared persistent-compile-cache prelude for subprocess test scripts.

The compile-bound subprocess tests (engine parity fp64/spmd, the launch
small-mesh compile) prepend this to their ``python -c`` scripts so lowered
XLA artifacts persist under the repo's ``.jax_cache/`` and reruns skip
compilation.  One copy here keeps the recipe in sync across modules.
"""
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CACHE_PRELUDE = (
    "import os, jax\n"
    f"jax.config.update('jax_compilation_cache_dir', "
    f"{os.path.join(REPO_ROOT, '.jax_cache')!r})\n"
    "jax.config.update('jax_persistent_cache_min_compile_time_secs', 0.5)\n"
)
