"""Statistical / property tier for the on-device CBS sampler.

The async personalization path replaces the host NumPy mini-epoch draw with
jax PRNG programs (core/sampler/cbs_device.py).  That machinery is
nondeterministic by design, so parity with the host sampler is proven
statistically rather than bit-for-bit:

  1. the jax Eq. 3 probability vector matches the NumPy reference to 1e-12
     (under x64) on seeds × {power-law, isolated-nodes, single-hub} graphs;
  2. a chi-squared test (n >= 50k draws, alpha = 1e-3) confirms the device
     categorical draw follows Eq. 3;
  3. the Gumbel top-k subset draw is a real without-replacement sample
     (distinct picks, exact size, zero-probability nodes never drawn);
  4. the async phase-1 path performs ZERO host mini-epoch draws — the
     call-counter check behind the "no host NumPy on the mini-epoch path"
     acceptance criterion — while staging the device draw;
  5. the phase-0 epoch draw (PR 5): chi-squared on 60k draws for the
     uniform path (end to end through ``draw_epoch``) AND the CBS-weighted
     path, plus permutation validity — each epoch visits each valid index
     at most once before the next key reshuffles;
  6. phase-0 host isolation: across async generalization epochs the host
     RNG draw counter stays at 0 and ``_EpochPrefetcher`` is never
     constructed.

All seeds are fixed: every assertion is deterministic.
"""
import numpy as np
import pytest
import scipy.stats

from repro.core.sampler import (cbs_probabilities, cbs_probabilities_device,
                                device_fanout, gumbel_subset)

# --------------------------------------------------------------------------
# adversarial graph profiles (the engine parity suite's degree shapes, plus
# imbalanced labels so the class-frequency division in Eq. 3 is exercised)
# --------------------------------------------------------------------------

KINDS = ["powerlaw", "isolated", "single_hub"]


def _graph(kind: str, seed: int, n: int = 300):
    import zlib

    rng = np.random.default_rng([seed, zlib.crc32(kind.encode())])
    if kind == "powerlaw":
        deg = np.minimum((1.0 / rng.power(2.0, n) - 1).astype(np.int64), 150)
        deg = np.maximum(deg, 0)
    elif kind == "isolated":
        deg = rng.integers(0, 6, n)
        deg[rng.random(n) < 0.5] = 0          # half the graph isolated
    elif kind == "single_hub":
        deg = rng.integers(0, 4, n)
        deg[int(rng.integers(0, n))] = 2000   # one hub dominating the mass
    else:
        raise ValueError(kind)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n, int(indptr[-1])).astype(np.int64)
    labels = rng.choice(5, n, p=[0.45, 0.25, 0.15, 0.10, 0.05])
    train_idx = np.sort(rng.choice(n, int(0.7 * n), replace=False))
    return indptr, indices, labels, train_idx


# --------------------------------------------------------------------------
# 1. Eq. 3 parity: jax == NumPy to 1e-12
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_device_probabilities_match_host_1e12(kind, seed):
    from jax.experimental import enable_x64

    indptr, indices, labels, train_idx = _graph(kind, seed)
    p_host = cbs_probabilities(indptr, indices, labels, train_idx)
    with enable_x64():
        p_dev = np.asarray(
            cbs_probabilities_device(indptr, indices, labels, train_idx))
    assert p_dev.shape == p_host.shape
    assert np.abs(p_dev - p_host).max() < 1e-12
    assert abs(p_dev.sum() - 1.0) < 1e-12


def test_device_probabilities_zero_support_uniform():
    """All-isolated graph: Eq. 3 mass is zero everywhere -> uniform fallback,
    same contract as the host reference."""
    n = 40
    indptr = np.zeros(n + 1, np.int64)
    indices = np.zeros(0, np.int64)
    labels = np.zeros(n, np.int64)
    train_idx = np.arange(n)
    p_host = cbs_probabilities(indptr, indices, labels, train_idx)
    p_dev = np.asarray(
        cbs_probabilities_device(indptr, indices, labels, train_idx))
    np.testing.assert_allclose(p_dev, p_host, atol=1e-6)
    np.testing.assert_allclose(p_dev, 1.0 / n, atol=1e-6)


# --------------------------------------------------------------------------
# 2. chi-squared: the device categorical draw follows Eq. 3
# --------------------------------------------------------------------------

N_DRAWS = 60_000
ALPHA = 1e-3


def _merged_chisquare(counts: np.ndarray, probs: np.ndarray):
    """Pearson chi-squared with standard small-expectation bin merging
    (every merged bin keeps expected count >= 5)."""
    n = counts.sum()
    exp = probs * n
    order = np.argsort(exp)
    obs_m, exp_m = [], []
    acc_o = acc_e = 0.0
    for i in order:
        acc_o += counts[i]
        acc_e += exp[i]
        if acc_e >= 5.0:
            obs_m.append(acc_o)
            exp_m.append(acc_e)
            acc_o = acc_e = 0.0
    if acc_e > 0:                      # fold the tail into the last bin
        obs_m[-1] += acc_o
        exp_m[-1] += acc_e
    return scipy.stats.chisquare(np.asarray(obs_m), np.asarray(exp_m))


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("seed", [0, 1])
def test_device_draw_follows_eq3(kind, seed):
    """The PRODUCTION draw (gumbel_subset, the Gumbel top-k behind
    draw_epoch) is chi-squared against Eq. 3: the first slot of the ranking
    is exactly a categorical(P) sample, so its frequencies over >=50k
    independent draws must match the probability vector."""
    import jax
    import jax.numpy as jnp

    indptr, indices, labels, train_idx = _graph(kind, seed)
    probs = cbs_probabilities(indptr, indices, labels, train_idx)
    with np.errstate(divide="ignore"):
        logp = jnp.asarray(np.log(probs), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(seed * 7919 + 13), N_DRAWS)
    first = jax.vmap(lambda k: gumbel_subset(k, logp, 1)[0])(keys)
    counts = np.bincount(np.asarray(first),
                         minlength=len(train_idx)).astype(np.float64)
    # zero-probability slots (isolated nodes) must never be drawn
    assert counts[probs == 0].sum() == 0
    res = _merged_chisquare(counts, probs)
    assert res.pvalue > ALPHA, (kind, seed, res)


# --------------------------------------------------------------------------
# 3. without-replacement subset properties (the mini-epoch draw)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
def test_gumbel_subset_is_without_replacement(kind):
    import jax
    import jax.numpy as jnp

    indptr, indices, labels, train_idx = _graph(kind, 3)
    probs = cbs_probabilities(indptr, indices, labels, train_idx)
    with np.errstate(divide="ignore"):
        logp = jnp.asarray(np.log(probs), jnp.float32)
    support = int((probs > 0).sum())
    k = min(50, support)
    for s in range(5):
        pick = np.asarray(gumbel_subset(jax.random.PRNGKey(s), logp, k))
        assert len(np.unique(pick)) == k          # distinct slots
        assert (probs[pick] > 0).all()            # never a zero-prob node


def test_gumbel_subset_oversamples_minority():
    """Inclusion frequency under the subset draw still tracks Eq. 3: the
    rarest class's mean inclusion rate beats the majority's (the
    class-balancing claim, now on device)."""
    import jax
    import jax.numpy as jnp

    indptr, indices, labels, train_idx = _graph("powerlaw", 4)
    probs = cbs_probabilities(indptr, indices, labels, train_idx)
    with np.errstate(divide="ignore"):
        logp = jnp.asarray(np.log(probs), jnp.float32)
    k = len(train_idx) // 4
    incl = np.zeros(len(train_idx))
    reps = 400
    base = jax.random.PRNGKey(42)
    picks = jax.vmap(lambda kk: gumbel_subset(kk, logp, k))(
        jax.random.split(base, reps))
    for row in np.asarray(picks):
        incl[row] += 1
    incl /= reps
    tl = labels[train_idx]
    pop = np.bincount(tl, minlength=5) / len(tl)
    rare, major = int(np.argmin(pop)), int(np.argmax(pop))
    assert incl[tl == rare].mean() > incl[tl == major].mean()


def test_device_fanout_matches_host_semantics():
    """Fanout picks land inside each node's CSR span; isolated nodes
    self-loop (NeighborSampler's contract)."""
    import jax
    import jax.numpy as jnp

    indptr, indices, labels, train_idx = _graph("isolated", 5)
    nodes = jnp.asarray(train_idx[:64].astype(np.int32))
    nbrs = np.asarray(device_fanout(
        jax.random.PRNGKey(0), nodes, jnp.asarray(indptr, jnp.int32),
        jnp.asarray(indices, jnp.int32), 7))
    deg = (indptr[1:] - indptr[:-1])[train_idx[:64]]
    for i, v in enumerate(train_idx[:64]):
        if deg[i] == 0:
            assert (nbrs[i] == v).all()
        else:
            legal = set(indices[indptr[v]: indptr[v + 1]].tolist())
            assert set(nbrs[i].tolist()) <= legal


def test_epoch_sampler_caps_mini_epoch_at_support():
    """A partition whose mini-epoch size exceeds its positive-probability
    support must cap there: the staged epoch never marks a zero-probability
    (isolated) node as a valid training example."""
    import jax
    import jax.numpy as jnp

    from repro.core.sampler import build_device_epoch_sampler

    class G:
        pass

    n = 120
    g = G()
    # 30 connected nodes, 90 isolated -> Eq. 3 support is tiny
    rng = np.random.default_rng(0)
    deg = np.zeros(n, np.int64)
    deg[:30] = rng.integers(1, 4, 30)
    g.indptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=g.indptr[1:])
    g.indices = rng.integers(0, 30, int(g.indptr[-1])).astype(np.int64)
    g.features = rng.normal(0, 1, (n, 8)).astype(np.float32)
    g.labels = rng.integers(0, 3, n)
    train = [np.arange(n), np.arange(20)]      # host 0: support << batch
    ds = build_device_epoch_sampler(g, train, 2, batch_size=64,
                                    subset_fraction=0.5, fanouts=(3, 3))
    for p in range(2):
        probs = np.exp(np.asarray(ds.logp[p], np.float64))
        support = int((np.asarray(ds.logp[p]) > -np.inf).sum())
        assert int(ds.k[p]) <= support
        nodes, valid = jax.tree.map(
            np.asarray,
            ds.draw_epoch(jax.random.PRNGKey(p), ds.logp[p],
                          ds.train_idx[p], ds.k[p]))
        picked = nodes[valid]
        assert len(picked) == int(ds.k[p])
        # every valid pick carries positive Eq. 3 probability (train sets are
        # arange here, so a node's slot in the padded row == its id)
        assert all(probs[int(v)] > 0 for v in picked)
        # valid examples stay PACKED in the leading slots: the partition's
        # natural_iters budgeted batches cover exactly its own mini-epoch
        flat = valid.reshape(-1)
        assert flat[: int(ds.k[p])].all() and not flat[int(ds.k[p]):].any()


# --------------------------------------------------------------------------
# 5. phase-0 epoch draw (the PR-5 generalization): uniform-path and
#    CBS-path statistics + the permutation-validity property
# --------------------------------------------------------------------------

def _phase0_sampler(class_balanced: bool, n: int = 160, seed: int = 6):
    """A DeviceEpochSampler staged the way the async phase-0 path stages it
    (build_device_epoch_sampler over a graph + per-host train sets)."""
    from repro.core.sampler import build_device_epoch_sampler

    class G:
        pass

    indptr, indices, labels, train_idx = _graph("powerlaw", seed, n)
    g = G()
    g.indptr, g.indices, g.labels = indptr, indices, labels
    g.features = np.random.default_rng(seed).normal(
        0, 1, (n, 8)).astype(np.float32)
    half = len(train_idx) // 2
    host_train = [train_idx[:half], train_idx[half:]]
    ds = build_device_epoch_sampler(
        g, host_train, 2, batch_size=32,
        subset_fraction=0.25 if class_balanced else 1.0,
        class_balanced=class_balanced, fanouts=(3, 3))
    return ds, host_train


def test_phase0_uniform_draw_is_uniform_chisquared():
    """The uniform (no-CBS) phase-0 path END TO END through the production
    ``draw_epoch``: the first batch slot of the drawn-and-shuffled epoch is
    a uniform categorical over the partition's train set — chi-squared on
    60k device draws."""
    import jax

    ds, host_train = _phase0_sampler(class_balanced=False)
    p = 0
    t = len(host_train[p])

    def first_slot(key):
        nodes, _ = ds.draw_epoch(key, ds.logp[p], ds.train_idx[p], ds.k[p])
        return nodes[0, 0]

    keys = jax.random.split(jax.random.PRNGKey(991), N_DRAWS)
    first = np.asarray(jax.vmap(first_slot)(keys))
    # every draw lands on a real train node of this partition
    assert set(first.tolist()) <= set(host_train[p].tolist())
    counts = np.zeros(t, np.float64)
    for i, v in enumerate(host_train[p]):
        counts[i] = (first == v).sum()
    res = _merged_chisquare(counts, np.full(t, 1.0 / t))
    assert res.pvalue > ALPHA, res


def test_phase0_cbs_draw_follows_eq3_chisquared():
    """The CBS-weighted phase-0 path: the first slot of the Gumbel top-k
    ranking over the sampler's STAGED per-partition log-Eq.3 row is exactly
    a categorical(Eq. 3) sample — chi-squared on 60k device draws against
    the staged probabilities (the shuffle on top is covered by the uniform
    end-to-end test and the permutation property below)."""
    import jax

    from repro.core.sampler import gumbel_subset

    ds, host_train = _phase0_sampler(class_balanced=True)
    p = 1
    logp = np.asarray(ds.logp[p], np.float64)
    probs = np.exp(logp)
    probs /= probs.sum()
    keys = jax.random.split(jax.random.PRNGKey(41), N_DRAWS)
    first = np.asarray(
        jax.vmap(lambda k: gumbel_subset(k, ds.logp[p], 1)[0])(keys))
    counts = np.bincount(first, minlength=len(probs)).astype(np.float64)
    assert counts[probs == 0].sum() == 0
    res = _merged_chisquare(counts, probs)
    assert res.pvalue > ALPHA, res


@pytest.mark.parametrize("class_balanced", [True, False])
def test_phase0_epoch_is_valid_permutation(class_balanced):
    """Permutation validity of the phase-0 epoch: within one epoch each
    valid index is visited AT MOST once (exactly k distinct nodes), the
    uniform path covers the full train set exactly once, and a fresh epoch
    key reshuffles (different batch order)."""
    import jax

    ds, host_train = _phase0_sampler(class_balanced=class_balanced)
    orders = []
    for p in range(2):
        for epoch in (0, 1, 2):
            key = jax.random.fold_in(jax.random.PRNGKey(17 + p), epoch)
            nodes, valid = jax.tree.map(
                np.asarray,
                ds.draw_epoch(key, ds.logp[p], ds.train_idx[p], ds.k[p]))
            picked = nodes.reshape(-1)[valid.reshape(-1)]
            assert len(picked) == int(ds.k[p])
            assert len(np.unique(picked)) == len(picked)   # no revisits
            assert set(picked.tolist()) <= set(host_train[p].tolist())
            if not class_balanced:
                # uniform epoch == one full pass over the local train set
                assert sorted(picked.tolist()) == sorted(
                    host_train[p].tolist())
            if p == 0:
                orders.append(tuple(picked.tolist()))
    # reshuffle across epochs: the three epoch orders are not all identical
    assert len(set(orders)) > 1


# --------------------------------------------------------------------------
# 4. the acceptance call-counter: async phase-1 never draws on host
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def async_run():
    from repro.core.sampler import cbs, cbs_device
    from repro.pipeline import EATConfig, run_eat_distgnn

    host_before = cbs.host_draw_count()
    dev_before = cbs_device.device_trace_count()
    cfg = EATConfig(dataset="tiny", num_parts=4, partition_method="ew",
                    use_cbs=True, use_gp=True, max_epochs=12, hidden_dim=32,
                    batch_size=64, fanouts=(3, 3), lr=3e-3, seed=0,
                    flatten_tol=0.08, async_personalize=True)
    result = run_eat_distgnn(cfg)
    return result, cbs_device.device_trace_count() - dev_before


def test_async_phase1_no_host_numpy_draw(async_run):
    result, dev_traces = async_run
    assert result.phase1_epochs > 0, "personalization never ran"
    assert result.host_draws_phase1 == 0, (
        f"{result.host_draws_phase1} host NumPy mini-epoch draws leaked "
        "onto the async phase-1 path")
    assert dev_traces > 0, "the device mini-epoch draw was never staged"


def test_async_phase1_still_learns(async_run):
    result, _ = async_run
    assert result.f1.micro > 0.30
    assert np.isfinite(result.loss_history).all()


# --------------------------------------------------------------------------
# 6. phase-0 host isolation: the fused generalization epoch never touches
#    the host RNG and never constructs the prefetcher
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def async_phase0_run():
    from repro import pipeline
    from repro.core.sampler import cbs, cbs_device
    from repro.pipeline import EATConfig, run_eat_distgnn

    class _ForbiddenPrefetcher:
        def __init__(self, *a, **k):
            raise AssertionError(
                "_EpochPrefetcher constructed on the fully-async path")

    host_before = cbs.host_draw_count()
    dev_before = cbs_device.device_trace_count()
    orig = pipeline._EpochPrefetcher
    pipeline._EpochPrefetcher = _ForbiddenPrefetcher
    try:
        cfg = EATConfig(dataset="tiny", num_parts=4, partition_method="ew",
                        use_cbs=True, use_gp=True, max_epochs=12,
                        hidden_dim=32, batch_size=64, fanouts=(3, 3),
                        lr=3e-3, seed=0, flatten_tol=0.08,
                        async_generalize=True, async_personalize=True)
        result = run_eat_distgnn(cfg)
    finally:
        pipeline._EpochPrefetcher = orig
    return (result, cbs.host_draw_count() - host_before,
            cbs_device.device_trace_count() - dev_before)


def test_async_phase0_no_host_numpy_draw(async_phase0_run):
    """Mirror of test_async_phase1_no_host_numpy_draw for generalization:
    across async phase-0 epochs the host RNG draw counter stays at 0, the
    device draw is demonstrably staged, and ``_EpochPrefetcher`` is never
    constructed (the fixture swaps in a constructor that raises)."""
    result, host_delta, dev_traces = async_phase0_run
    assert result.epochs_run > 0 and result.phase1_epochs > 0
    assert result.host_draws_phase0 == 0, (
        f"{result.host_draws_phase0} host NumPy epoch draws leaked onto "
        "the async phase-0 path")
    assert result.host_draws_phase1 == 0
    assert host_delta == 0, f"host RNG drew {host_delta} times"
    assert dev_traces > 0, "the device epoch draw was never staged"


def test_async_phase0_still_learns(async_phase0_run):
    result, _, _ = async_phase0_run
    assert result.f1.micro > 0.30
    assert np.isfinite(result.loss_history).all()
