import numpy as np
import pytest
import scipy.sparse as sp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic random-sampling shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.partition import assign_edge_weights, metis_kway, partition_graph
from repro.core.partition.api import METHODS


# ---------------------------------------------------------------- Alg. 1 ---

def test_edge_weights_positive_integer(homophilous_graph):
    a, feats, labels = homophilous_graph
    w = assign_edge_weights(a.indptr, a.indices, feats)
    assert w.dtype == np.int64
    assert (w >= 1).all()
    assert len(w) == a.nnz


def test_edge_weights_similar_features_heavier():
    """Two same-feature nodes must get a heavier edge than two orthogonal."""
    indptr = np.array([0, 2, 3, 4])
    indices = np.array([1, 2, 0, 0])   # node0 <- {1,2}; node1 <- 0; node2 <- 0
    feats = np.array([[1.0, 0.0], [1.0, 0.0], [0.0, 1.0]], np.float32)
    w = assign_edge_weights(indptr, indices, feats, c=1.0)
    w_same = w[0]      # edge (1 -> 0): identical features
    w_diff = w[1]      # edge (2 -> 0): orthogonal features
    assert w_same > w_diff


def test_edge_weights_low_degree_locality():
    """p = 1 - exp(-K/|N(v)|): low-degree destinations weigh in-edges higher."""
    # v=0 has 1 in-edge, v=1 has 4 in-edges; identical (orthogonal) features
    indptr = np.array([0, 1, 5])
    indices = np.array([1, 0, 0, 0, 0])
    feats = np.zeros((2, 4), np.float32)  # zero similarity everywhere
    w = assign_edge_weights(indptr, indices, feats, fanout_k=2)
    assert w[0] > w[1]


# ------------------------------------------------------------- partitioner --

@pytest.mark.parametrize("k", [2, 4, 8])
def test_metis_balance_and_cover(homophilous_graph, k):
    a, feats, labels = homophilous_graph
    parts = metis_kway(a, k, seed=0)
    assert parts.shape == (a.shape[0],)
    assert set(np.unique(parts)) <= set(range(k))
    sizes = np.bincount(parts, minlength=k)
    assert (sizes > 0).all()
    assert sizes.max() <= 1.06 * sizes.mean() + 1  # balance constraint


def test_metis_beats_random_cut(homophilous_graph):
    a, feats, labels = homophilous_graph
    rng = np.random.default_rng(1)
    parts_m = metis_kway(a, 4, seed=0)
    parts_r = rng.integers(0, 4, a.shape[0])
    src, dst = a.nonzero()
    cut_m = (parts_m[src] != parts_m[dst]).sum()
    cut_r = (parts_r[src] != parts_r[dst]).sum()
    assert cut_m < 0.7 * cut_r


@pytest.mark.parametrize("method", METHODS)
def test_partition_graph_all_methods(homophilous_graph, method):
    a, feats, labels = homophilous_graph
    r = partition_graph(a.indptr, a.indices, feats, labels, 4,
                        method=method, seed=0)
    assert len(r.parts) == a.shape[0]
    assert r.stats.num_parts == 4
    assert r.stats.avg_entropy >= 0


def test_ew_reduces_entropy_vs_random(homophilous_graph):
    """The paper's Table V claim, directionally: H(EW) < H(random)."""
    a, feats, labels = homophilous_graph
    r_ew = partition_graph(a.indptr, a.indices, feats, labels, 4,
                           method="ew", seed=0)
    r_rand = partition_graph(a.indptr, a.indices, feats, labels, 4,
                             method="random", seed=0)
    assert r_ew.stats.avg_entropy < r_rand.stats.avg_entropy


@given(st.integers(2, 5))
@settings(max_examples=8, deadline=None)
def test_metis_property_all_nodes_assigned(k):
    rng = np.random.default_rng(k)
    n = 120
    a = sp.random(n, n, density=0.05, random_state=int(k), format="csr")
    a = ((a + a.T) > 0).astype(np.float64).tocsr()
    a.setdiag(0)
    a.eliminate_zeros()
    parts = metis_kway(a, k, seed=k)
    assert parts.min() >= 0 and parts.max() < k
    assert len(parts) == n
