import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic random-sampling shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.entropy import label_entropy
from repro.core.sampler import CBSampler, cbs_probabilities


@pytest.fixture
def imbalanced(homophilous_graph):
    a, feats, labels = homophilous_graph
    train_idx = np.arange(len(labels))
    return a, labels, train_idx


def test_probabilities_normalized(imbalanced):
    a, labels, train_idx = imbalanced
    p = cbs_probabilities(a.indptr, a.indices, labels, train_idx)
    assert p.shape == train_idx.shape
    assert p.sum() == pytest.approx(1.0)
    assert (p >= 0).all()


def test_minority_oversampled(imbalanced):
    """CBS must raise the sampling frequency of the rarest class above its
    population share — the class-balancing claim."""
    a, labels, train_idx = imbalanced
    s = CBSampler(a.indptr, a.indices, labels, train_idx, batch_size=64, seed=0)
    dist = s.empirical_class_distribution(num_draws=20)
    pop = np.bincount(labels, minlength=5) / len(labels)
    rare = int(np.argmin(pop))
    assert dist[rare] > pop[rare] * 1.5


def test_sampled_entropy_higher_than_population(imbalanced):
    """Balanced sampling => label distribution entropy goes UP."""
    a, labels, train_idx = imbalanced
    s = CBSampler(a.indptr, a.indices, labels, train_idx, batch_size=64, seed=0)
    dist = s.empirical_class_distribution(num_draws=20)
    h_sampled = -(dist[dist > 0] * np.log(dist[dist > 0])).sum()
    assert h_sampled > label_entropy(labels)


def test_mini_epoch_smaller(imbalanced):
    """The 25% mini-epoch is what buys the paper its epoch-time speedup."""
    a, labels, train_idx = imbalanced
    s = CBSampler(a.indptr, a.indices, labels, train_idx,
                  batch_size=16, subset_fraction=0.25, seed=0)
    assert s.mini_epoch_size <= 0.25 * len(train_idx) + 16
    baseline = CBSampler(a.indptr, a.indices, labels, train_idx,
                         batch_size=16, subset_fraction=1.0,
                         class_balanced=False, seed=0)
    assert baseline.mini_epoch_size == len(train_idx)
    assert len(s.batches()) < len(baseline.batches())


def test_batches_cover_mini_epoch(imbalanced):
    a, labels, train_idx = imbalanced
    s = CBSampler(a.indptr, a.indices, labels, train_idx, batch_size=50, seed=0)
    batches = s.batches()
    total = sum(len(b) for b in batches)
    assert total == s.mini_epoch_size
    assert all(len(b) <= 50 for b in batches)


@given(st.integers(1, 1000))
@settings(max_examples=25, deadline=None)
def test_cbs_probabilities_properties(seed):
    """P(v) > 0 for every train node; rarest-class nodes beat the same-degree
    majority-class nodes."""
    rng = np.random.default_rng(seed)
    n = 60
    deg = rng.integers(1, 5, n)
    indptr = np.concatenate([[0], np.cumsum(deg)])
    indices = rng.integers(0, n, indptr[-1])
    labels = np.concatenate([np.zeros(50, int), np.ones(10, int)])
    rng.shuffle(labels)
    p = cbs_probabilities(indptr, indices, labels, np.arange(n))
    assert (p > 0).all()
    # mean probability of minority class exceeds majority
    assert p[labels == 1].mean() > p[labels == 0].mean()
