import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic random-sampling shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import partition_graph
from repro.graph import (BENCHMARKS, GraphSAGE, NeighborSampler,
                         build_partitioned_graph, make_benchmark)


@pytest.fixture(scope="module")
def tiny():
    return make_benchmark(BENCHMARKS["tiny"])


def test_benchmark_properties(tiny):
    g = tiny
    assert g.num_nodes == 600
    assert len(g.indptr) == g.num_nodes + 1
    assert g.indices.max() < g.num_nodes
    # splits are disjoint
    tr, va, te = set(g.train_idx), set(g.val_idx), set(g.test_idx)
    assert not (tr & va) and not (tr & te) and not (va & te)
    # labelled fraction respected
    assert (g.labels[g.train_idx] >= 0).all()


def test_benchmark_homophily(tiny):
    """Generated graphs must actually be homophilous (EW's precondition)."""
    g = tiny
    src = g.indices
    dst = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    same = (g.labels[src] == g.labels[dst]).mean()
    k = g.num_classes
    base = np.square(np.bincount(g.labels[g.labels >= 0]) /
                     (g.labels >= 0).sum()).sum()
    assert same > 2 * base   # far above random mixing


def test_benchmark_class_imbalance():
    g = make_benchmark(BENCHMARKS["products-s"])
    counts = np.bincount(g.labels[g.labels >= 0])
    assert counts.max() > 5 * max(1, counts.min())   # Zipf tail


def test_neighbor_sampler_shapes(tiny):
    s = NeighborSampler(tiny, fanouts=(7, 3), seed=0)
    blocks = s.sample(tiny.train_idx[:32])
    assert blocks.nbrs1.shape == (32, 7)
    assert blocks.nbrs2.shape == (32 * 7, 3)
    x_t, x_1, x_2 = blocks.feature_views(tiny.features)
    assert x_t.shape == (32, tiny.feature_dim)
    assert x_1.shape == (32, 7, tiny.feature_dim)
    assert x_2.shape == (32, 7, 3, tiny.feature_dim)


def test_neighbor_sampler_valid_neighbors(tiny):
    """Every sampled neighbour is a true in-neighbour (or a self loop for
    isolated nodes)."""
    s = NeighborSampler(tiny, fanouts=(5, 5), seed=1)
    nodes = tiny.train_idx[:20]
    blocks = s.sample(nodes)
    for i, v in enumerate(nodes):
        nbrs = set(tiny.neighbors(v).tolist()) or {int(v)}
        assert set(blocks.nbrs1[i].tolist()) <= nbrs | {int(v)}


def test_sage_full_vs_pallas_segment_agg(tiny):
    """GraphSAGE full-graph forward through the ONE aggregation op: the
    Pallas path (default) == the jnp reference path, and ``jax.grad``
    through both paths agrees — the callback-free apply_full is
    differentiable end-to-end."""
    g = tiny
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=16,
                      num_classes=g.num_classes)
    params = model.init(0)
    src = jnp.asarray(g.indices)
    dst = jnp.asarray(np.repeat(np.arange(g.num_nodes), np.diff(g.indptr)))
    feats = jnp.asarray(g.features)
    base = model.apply_full(params, feats, src, dst, g.num_nodes,
                            use_pallas=False)
    fused = model.apply_full(params, feats, src, dst, g.num_nodes)
    np.testing.assert_allclose(np.asarray(base), np.asarray(fused),
                               atol=1e-4, rtol=1e-4)

    def loss(params, use_pallas):
        out = model.apply_full(params, feats, src, dst, g.num_nodes,
                               use_pallas=use_pallas)
        return (out * out).mean()

    g_pal = jax.grad(lambda p: loss(p, True))(params)
    g_ref = jax.grad(lambda p: loss(p, False))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_pal),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


def test_partitioned_graph_invariants(tiny):
    g = tiny
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                        method="metis", seed=0)
    pg = build_partitioned_graph(g, r.parts, 4)
    # every node owned exactly once
    owned = np.concatenate([pg.global_ids[p, :pg.n_own[p]] for p in range(4)])
    assert sorted(owned.tolist()) == list(range(g.num_nodes))
    # halo slots reference real nodes of other partitions
    for p in range(4):
        halo = pg.global_ids[p, pg.n_own[p]: pg.n_own[p] + pg.n_halo[p]]
        assert (r.parts[halo] != p).all()
    # edge destinations are owned & local
    for p in range(4):
        real = pg.edge_mask[p] > 0
        assert (pg.edge_dst[p][real] < pg.n_own[p]).all()


def test_interior_boundary_split_invariants(tiny):
    """The [interior | boundary | halo | pad] layout (DESIGN.md §5):
    interior rows have NO halo in-neighbour, every boundary row has one,
    the destination-disjoint CSR shards exactly re-partition the combined
    edge list with per-row order preserved, and the static degree matches
    the combined edge mask."""
    g = tiny
    for method in ("ew", "random"):
        r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                            method=method, seed=0)
        pg = build_partitioned_graph(g, r.parts, 4)
        assert pg.own_cap == pg.n_own.max()
        for p in range(4):
            real = pg.edge_mask[p] > 0
            src, dst = pg.edge_src[p][real], pg.edge_dst[p][real]
            halo_src = src >= pg.n_own[p]
            # classification: boundary rows = exactly those with a halo src
            bnd_rows = np.unique(dst[halo_src])
            assert (bnd_rows >= pg.n_int[p]).all(), "interior row has halo src"
            expect_bnd = np.zeros(pg.max_nodes, bool)
            expect_bnd[bnd_rows] = True
            assert expect_bnd[pg.n_int[p]:pg.n_own[p]].all(), \
                "boundary row without halo src"
            # split shards re-partition the combined list, order preserved
            i_real = pg.int_mask[p] > 0
            b_real = pg.bnd_mask[p] > 0
            isrc, idst = pg.int_src[p][i_real], pg.int_dst[p][i_real]
            bsrc, bdst = pg.bnd_src[p][b_real], pg.bnd_dst[p][b_real]
            assert (idst < pg.n_int[p]).all() and (isrc < pg.n_own[p]).all()
            assert (bdst >= pg.n_int[p]).all() and (bdst < pg.n_own[p]).all()
            np.testing.assert_array_equal(np.concatenate([isrc, bsrc]), src)
            np.testing.assert_array_equal(np.concatenate([idst, bdst]), dst)
            # static degree == runtime mask degree, clamped
            counts = np.bincount(dst, minlength=pg.own_cap)[:pg.own_cap]
            np.testing.assert_array_equal(pg.deg[p], np.maximum(counts, 1))


def test_trash_row_is_explicit_and_unreferenced(tiny):
    """The trash-row convention is named state: ``trash_row`` is the last
    local row, real edges and real recv slots never reference it, and all
    padding does — so it stays all-zero through every layer."""
    g = tiny
    r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                        method="ew", seed=0)
    pg = build_partitioned_graph(g, r.parts, 4)
    assert pg.trash_row == pg.max_nodes - 1
    assert (pg.n_own + pg.n_halo <= pg.trash_row).all()
    for p in range(4):
        real = pg.edge_mask[p] > 0
        assert (pg.edge_src[p][real] != pg.trash_row).all()
        assert (pg.edge_dst[p][real] != pg.trash_row).all()
        assert (pg.edge_src[p][~real] == pg.trash_row).all()
        assert (pg.edge_dst[p][~real] == pg.trash_row).all()
        # features/labels on the trash row are zero / ignore-label
        assert np.abs(pg.features[p, pg.trash_row]).max() == 0.0
        assert pg.labels[p, pg.trash_row] == -1
    # recv_pos[p, q] aligns with send_mask[q, p]; real slots land in halo
    # space, pad slots land on the trash row
    recv_real = np.swapaxes(pg.send_mask, 0, 1) > 0
    assert (pg.recv_pos[recv_real] != pg.trash_row).all()
    assert (pg.recv_pos[~recv_real] == pg.trash_row).all()


def test_ew_reduces_halo_volume(tiny):
    """The paper's comm claim: EW cut < random cut => smaller halo."""
    g = tiny
    r_ew = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                           method="ew", seed=0)
    r_rnd = partition_graph(g.indptr, g.indices, g.features, g.labels, 4,
                            method="random", seed=0)
    pg_ew = build_partitioned_graph(g, r_ew.parts, 4)
    pg_rnd = build_partitioned_graph(g, r_rnd.parts, 4)
    assert pg_ew.halo_bytes_per_layer < pg_rnd.halo_bytes_per_layer


DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.graph import make_benchmark, BENCHMARKS, GraphSAGE, build_partitioned_graph, make_distributed_forward
from repro.core import partition_graph

g = make_benchmark(BENCHMARKS["tiny"])
model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=32, num_classes=g.num_classes)
params = model.init(0)
r = partition_graph(g.indptr, g.indices, g.features, g.labels, 4, method="ew", seed=0)
pg = build_partitioned_graph(g, r.parts, 4)
from repro.launch.mesh import make_mesh_compat
from repro.engine.compat import shard_map_compat
mesh = make_mesh_compat((4,), ("data",))
fwd = make_distributed_forward(model, {"max_nodes": pg.max_nodes}, axis_name="data")
shard = dict(features=pg.features, send_idx=pg.send_idx, send_mask=pg.send_mask,
             recv_pos=pg.recv_pos, edge_src=pg.edge_src, edge_dst=pg.edge_dst,
             edge_mask=pg.edge_mask)
specs = {k: P("data", *([None]*(v.ndim-1))) for k, v in shard.items()}
smfwd = jax.jit(shard_map_compat(
    lambda prm, sh: fwd(prm, jax.tree.map(lambda x: x[0], sh)),
    mesh, in_specs=(P(), specs), out_specs=P("data", None)))
dl = np.asarray(smfwd(params, shard)).reshape(4, pg.max_nodes, g.num_classes)
src = g.indices; dst = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
full = np.asarray(model.apply_full(params, jnp.asarray(g.features),
                                   jnp.asarray(src), jnp.asarray(dst), g.num_nodes))
err = 0.0
for p in range(4):
    for i in range(pg.n_own[p]):
        err = max(err, float(np.abs(dl[p, i] - full[pg.global_ids[p, i]]).max()))
assert err < 1e-4, err
print("OK", err)
"""


def test_distributed_forward_matches_centralized():
    """shard_map halo-exchange forward == centralized full-graph forward
    (run in a subprocess so the 4-device XLA flag doesn't leak)."""
    res = subprocess.run([sys.executable, "-c", DIST_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout

# ------------------------------------------------------- halo_refresh_plan --

def _plan_cycle(K, max_send, cv=True, start_age=0):
    """The chunk ranges one cache generation schedules: ages
    [start_age, start_age + K) with the full refresh at age % K == 0."""
    from repro.graph.distributed import halo_refresh_plan

    return [halo_refresh_plan(a, K, cv, max_send)
            for a in range(start_age, start_age + K)]


def test_refresh_plan_full_at_cycle_start():
    from repro.graph.distributed import halo_refresh_plan

    for K in (1, 2, 3, 7):
        for ms in (0, 1, 5, 64):
            for cv in (False, True):
                assert halo_refresh_plan(0, K, cv, ms) == (0, ms)
                assert halo_refresh_plan(3 * K, K, cv, ms) == (0, ms)


def test_refresh_plan_chunks_partition_slot_space():
    """CV cached epochs cut [0, max_send) into EXACTLY K-1 contiguous
    back-to-back chunks — no slot skipped, none re-sent within a cycle."""
    for K in (2, 3, 4, 5, 8):
        for ms in (0, 1, 2, K - 2, K - 1, K, 3 * K + 1, 257):
            if ms < 0:
                continue
            plans = _plan_cycle(K, ms)[1:]          # drop the full refresh
            assert plans[0][0] == 0
            assert plans[-1][1] == ms
            for (l0, h0), (l1, h1) in zip(plans, plans[1:]):
                assert h0 == l1                     # contiguous, gap-free
            assert all(lo <= hi for lo, hi in plans)
            assert sum(hi - lo for lo, hi in plans) == ms


def test_refresh_plan_small_max_send_covered_within_K():
    """max_send < K - 1: more chunks than slots, so some cached epochs ship
    nothing — but every slot is still refreshed within K epochs."""
    for K, ms in ((5, 2), (8, 3), (16, 1), (7, 0)):
        plans = _plan_cycle(K, ms)
        covered = set()
        for lo, hi in plans:
            covered.update(range(lo, hi))
        assert covered == set(range(ms))
        empties = sum(1 for lo, hi in plans[1:] if lo == hi)
        assert empties == (K - 1) - ms if ms < K - 1 else empties == 0


def test_refresh_plan_cv_off_ships_nothing_between_refreshes():
    from repro.graph.distributed import halo_refresh_plan

    for K in (2, 3, 9):
        for age in range(1, K):
            assert halo_refresh_plan(age, K, False, 40) == (0, 0)


@settings(max_examples=120)
@given(st.integers(1, 64), st.integers(0, 512), st.integers(0, 1000),
       st.booleans())
def test_refresh_plan_properties(K, max_send, age0, cv):
    """Adversarial (K, max_send) pairs: over ANY window of K consecutive
    ages the plan re-exchanges every slot at least once, ranges stay inside
    [0, max_send), and per-epoch payload never exceeds the full refresh."""
    from repro.graph.distributed import halo_refresh_plan

    covered = set()
    for age in range(age0, age0 + K):
        lo, hi = halo_refresh_plan(age, K, cv, max_send)
        assert 0 <= lo <= hi <= max_send
        covered.update(range(lo, hi))
    assert covered == set(range(max_send))   # staleness bound: <= K epochs
    if cv and K > 1 and max_send >= K - 1:
        # cached epochs pay ~1/(K-1) of the payload, never more than
        # ceil(max_send / (K-1))
        cap = -(-max_send // (K - 1))
        for age in range(age0, age0 + K):
            if age % K:
                lo, hi = halo_refresh_plan(age, K, cv, max_send)
                assert hi - lo <= cap
