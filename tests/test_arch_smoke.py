"""Per-architecture smoke tests: REDUCED variant of each assigned config
(<=2 super-block repeats, d_model<=512, <=4 experts) runs one forward/train
step and one prefill+decode step on CPU; asserts shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Transformer
from repro.train.optim import AdamW, apply_updates

RNG = np.random.default_rng(3)
B, S = 2, 64


def _batch(cfg, with_labels=True):
    s_text = S - cfg.prefix_tokens
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (B, s_text)), jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (B, s_text)), jnp.int32)
    if cfg.prefix_tokens:
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, cfg.prefix_tokens, cfg.d_model)), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    model = Transformer(cfg)
    params = model.init(0)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(model.train_loss)(p, b)
        updates, o = opt.update(grads, o, p)
        return apply_updates(p, updates), o, loss

    batch = _batch(cfg)
    params2, opt_state, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # the step actually moved the weights
    moved = jax.tree_util.tree_reduce(
        lambda acc, pair: acc, 0)
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = Transformer(cfg)
    params = model.init(0)
    batch = _batch(cfg, with_labels=False)
    logits, caches, cache_len = jax.jit(
        lambda p, b: model.prefill(p, b, cache_size=S + 8))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN prefill logits"
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, caches = jax.jit(model.decode_step)(params, tok, caches, cache_len)
    assert logits2.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any()), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ["starcoder2-7b", "llama3.2-1b"])
def test_rolling_decode_consistency(arch):
    """Rolling (mod-W) cache decode == full-cache decode with the same
    window, for contexts longer than the window."""
    from repro.configs import SWA_SERVE_WINDOW
    from dataclasses import replace
    cfg = get_config(arch).reduced()
    cfg = replace(cfg, sliding_window=16)
    model = Transformer(cfg)
    params = model.init(0)
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 40)),
                                   jnp.int32)}
    # rolling path: cache only W slots
    lg_roll, c_roll, clen = jax.jit(
        lambda p, b: model.prefill(p, b, cache_size=16))(params, batch)
    lg_full, c_full, _ = jax.jit(
        lambda p, b: model.prefill(p, b, cache_size=64))(params, batch)
    np.testing.assert_allclose(np.asarray(lg_roll), np.asarray(lg_full),
                               atol=2e-4, rtol=2e-4)
    tok = jnp.argmax(lg_roll, -1).astype(jnp.int32)[:, None]
    d_roll, _ = jax.jit(lambda p, t, c, l: model.decode_step(
        p, t, c, l, rolling=True))(params, tok, c_roll, clen)
    d_full, _ = jax.jit(lambda p, t, c, l: model.decode_step(
        p, t, c, l, rolling=False))(params, tok, c_full, clen)
    np.testing.assert_allclose(np.asarray(d_roll), np.asarray(d_full),
                               atol=2e-4, rtol=2e-4)


def test_prefill_decode_matches_train_forward():
    """Teacher-forcing consistency: decode logits after prefill equal the
    train-mode forward at the same position (llama reduced)."""
    cfg = get_config("llama3.2-1b").reduced()
    model = Transformer(cfg)
    params = model.init(0)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 33)), jnp.int32)
    # prefill on first 32, decode token 33
    lg_p, caches, clen = jax.jit(
        lambda p, b: model.prefill(p, b, cache_size=64))(
            params, {"tokens": toks[:, :32]})
    lg_d, _ = jax.jit(model.decode_step)(params, toks[:, 32:33], caches, clen)
    # train forward over the whole 33 tokens: logits at position 32
    from repro.models.layers import chunked_attention  # noqa: F401
    x = params["embed"][toks]
    # use prefill over 33 as the reference "full forward"
    lg_f, _, _ = jax.jit(lambda p, b: model.prefill(p, b))(params,
                                                           {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_f),
                               atol=3e-4, rtol=3e-4)
