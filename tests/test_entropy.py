import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic random-sampling shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.entropy import label_entropy, partition_entropies, partition_stats


def test_uniform_labels_max_entropy():
    labels = np.repeat(np.arange(8), 100)
    assert label_entropy(labels) == pytest.approx(np.log(8), abs=1e-9)


def test_single_class_zero_entropy():
    assert label_entropy(np.zeros(100, dtype=int)) == 0.0


def test_unlabelled_ignored():
    labels = np.array([0, 0, 1, 1, -1, -1, -1])
    assert label_entropy(labels) == pytest.approx(np.log(2))


def test_empty():
    assert label_entropy(np.array([], dtype=int)) == 0.0
    assert label_entropy(np.full(10, -1)) == 0.0


@given(st.lists(st.integers(0, 9), min_size=1, max_size=300))
@settings(max_examples=50, deadline=None)
def test_entropy_bounds(labels):
    """0 <= H <= log(num_classes) for any label multiset."""
    h = label_entropy(np.array(labels), num_classes=10)
    assert -1e-12 <= h <= np.log(10) + 1e-12


@given(st.integers(2, 6), st.integers(20, 200))
@settings(max_examples=30, deadline=None)
def test_partition_entropies_shape_and_bounds(num_parts, n):
    rng = np.random.default_rng(n)
    labels = rng.integers(0, 4, n)
    parts = rng.integers(0, num_parts, n)
    ents = partition_entropies(labels, parts, num_parts, 4)
    assert ents.shape == (num_parts,)
    assert (ents >= 0).all() and (ents <= np.log(4) + 1e-12).all()


def test_partition_stats_cut_counts():
    # path graph 0-1-2-3, split {0,1} {2,3}: cut edges = (1,2),(2,1) = 2
    indptr = np.array([0, 1, 3, 5, 6])
    indices = np.array([1, 0, 2, 1, 3, 2])
    labels = np.array([0, 0, 1, 1])
    parts = np.array([0, 0, 1, 1])
    s = partition_stats(indptr, indices, labels, parts, 2)
    assert s.edge_cut == 2
    assert s.entropies.tolist() == [0.0, 0.0]
    assert s.balance == 1.0
