import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic random-sampling shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.entropy import label_entropy, partition_entropies, partition_stats


def test_uniform_labels_max_entropy():
    labels = np.repeat(np.arange(8), 100)
    assert label_entropy(labels) == pytest.approx(np.log(8), abs=1e-9)


def test_single_class_zero_entropy():
    assert label_entropy(np.zeros(100, dtype=int)) == 0.0


def test_unlabelled_ignored():
    labels = np.array([0, 0, 1, 1, -1, -1, -1])
    assert label_entropy(labels) == pytest.approx(np.log(2))


def test_empty():
    assert label_entropy(np.array([], dtype=int)) == 0.0
    assert label_entropy(np.full(10, -1)) == 0.0


@given(st.lists(st.integers(0, 9), min_size=1, max_size=300))
@settings(max_examples=50, deadline=None)
def test_entropy_bounds(labels):
    """0 <= H <= log(num_classes) for any label multiset."""
    h = label_entropy(np.array(labels), num_classes=10)
    assert -1e-12 <= h <= np.log(10) + 1e-12


@given(st.integers(2, 6), st.integers(20, 200))
@settings(max_examples=30, deadline=None)
def test_partition_entropies_shape_and_bounds(num_parts, n):
    rng = np.random.default_rng(n)
    labels = rng.integers(0, 4, n)
    parts = rng.integers(0, num_parts, n)
    ents = partition_entropies(labels, parts, num_parts, 4)
    assert ents.shape == (num_parts,)
    assert (ents >= 0).all() and (ents <= np.log(4) + 1e-12).all()


def test_partition_stats_cut_counts():
    # path graph 0-1-2-3, split {0,1} {2,3}: cut edges = (1,2),(2,1) = 2
    indptr = np.array([0, 1, 3, 5, 6])
    indices = np.array([1, 0, 2, 1, 3, 2])
    labels = np.array([0, 0, 1, 1])
    parts = np.array([0, 0, 1, 1])
    s = partition_stats(indptr, indices, labels, parts, 2)
    assert s.edge_cut == 2
    assert s.entropies.tolist() == [0.0, 0.0]
    assert s.balance == 1.0


def test_partition_stats_weighted_by_labelled_counts():
    """Unlabelled mass must not skew the weighted aggregates: a partition
    that is mostly unlabelled (papers-like) contributes by its LABELLED
    count, so stats match a graph with the unlabelled nodes deleted."""
    # partition 0: 4 labelled nodes (classes 0,1), 96 unlabelled
    # partition 1: 40 labelled nodes (class 0 only), 0 unlabelled
    labels = np.concatenate([
        np.array([0, 0, 1, 1]), np.full(96, -1), np.zeros(40, dtype=int)])
    parts = np.concatenate([np.zeros(100, dtype=int), np.ones(40, dtype=int)])
    n = len(labels)
    indptr = np.arange(n + 1)          # ring: node i -> (i+1) % n
    indices = (np.arange(n) + 1) % n
    s = partition_stats(indptr, indices, labels, parts, 2, num_classes=2)
    assert s.sizes.tolist() == [100, 40]
    assert s.labelled_sizes.tolist() == [4, 40]
    assert s.entropies[0] == pytest.approx(np.log(2))
    assert s.entropies[1] == 0.0
    # total: 4 * log2 + 40 * 0 — NOT 100 * log2
    assert s.total_entropy == pytest.approx(4 * np.log(2))
    # variance weights: 4/44 and 40/44
    mean_h = s.entropies.mean()
    want_var = ((s.entropies - mean_h) ** 2 * np.array([4, 40]) / 44).sum()
    assert s.entropy_variance == pytest.approx(want_var)
    # dropping the unlabelled nodes entirely must give the same aggregates
    keep = labels >= 0
    lab2, parts2 = labels[keep], parts[keep]
    m = len(lab2)
    s2 = partition_stats(np.arange(m + 1), (np.arange(m) + 1) % m,
                         lab2, parts2, 2, num_classes=2)
    assert s2.total_entropy == pytest.approx(s.total_entropy)
    assert s2.entropy_variance == pytest.approx(s.entropy_variance)


def test_partition_stats_all_unlabelled_partition():
    """A fully-unlabelled partition has zero weight, not its node count."""
    labels = np.array([0, 1, -1, -1, -1])
    parts = np.array([0, 0, 1, 1, 1])
    indptr = np.arange(6)
    indices = (np.arange(5) + 1) % 5
    s = partition_stats(indptr, indices, labels, parts, 2, num_classes=2)
    assert s.labelled_sizes.tolist() == [2, 0]
    assert s.total_entropy == pytest.approx(2 * np.log(2))
