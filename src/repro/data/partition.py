"""Entropy-aware corpus sharding — the paper's EW partitioning applied to a
document corpus across data-parallel shards.

We build a kNN document-similarity graph (cosine over doc features), weight
its edges with Algorithm 1 (fanout K = the kNN degree), and run the same
weighted multilevel partitioner used for graphs.  Result: data-parallel
shards with LOW domain entropy — which the GP personalization phase then
exploits, giving per-shard domain-specialist replicas (the paper's federated
view, DESIGN.md §Arch-applicability)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import scipy.sparse as sp

from ..core.entropy import partition_entropies
from ..core.partition import partition_graph
from .corpus import DomainCorpus

__all__ = ["CorpusShards", "shard_corpus_by_entropy", "knn_graph"]


def knn_graph(features: np.ndarray, k: int = 10) -> sp.csr_matrix:
    """Symmetric kNN cosine-similarity graph (host-side, exact — corpora at
    this scale are small; swap in an ANN index for production)."""
    f = features / np.maximum(np.linalg.norm(features, axis=1, keepdims=True), 1e-12)
    sim = f @ f.T
    np.fill_diagonal(sim, -np.inf)
    n = len(f)
    idx = np.argpartition(-sim, kth=k, axis=1)[:, :k]
    rows = np.repeat(np.arange(n), k)
    cols = idx.reshape(-1)
    a = sp.csr_matrix((np.ones(n * k), (rows, cols)), shape=(n, n))
    a = ((a + a.T) > 0).astype(np.float64).tocsr()
    a.setdiag(0)
    a.eliminate_zeros()
    return a


@dataclass
class CorpusShards:
    num_shards: int
    assignment: np.ndarray          # (num_docs,) shard id
    shard_entropies: np.ndarray     # per-shard domain entropy
    method: str

    def docs_of(self, shard: int) -> np.ndarray:
        return np.flatnonzero(self.assignment == shard)


def shard_corpus_by_entropy(
    corpus: DomainCorpus, num_shards: int, *, method: str = "ew",
    knn: int = 10, seed: int = 0,
) -> CorpusShards:
    """method: 'ew' (entropy-aware), 'metis' (similarity graph, unweighted)
    or 'random' (the standard round-robin loader = the DistDGL analogue)."""
    if method == "random":
        rng = np.random.default_rng([seed, 0x10AD])
        assign = rng.permutation(corpus.num_docs) % num_shards
    else:
        g = knn_graph(corpus.features, k=knn)
        res = partition_graph(
            g.indptr, g.indices, corpus.features, corpus.domains, num_shards,
            method=method, fanout_k=knn, seed=seed,
        )
        assign = res.parts
    ents = partition_entropies(corpus.domains, assign, num_shards,
                               corpus.spec.num_domains)
    return CorpusShards(num_shards=num_shards, assignment=assign.astype(np.int64),
                        shard_entropies=ents, method=method)
