"""Synthetic domain-labelled token corpus for the LLM-side pipeline.

The paper's pathology — non-i.i.d. label distributions across compute hosts —
has a direct LLM analogue: *domain* skew across data-parallel shards.  We
generate documents from per-domain Markov token models (so domains are
statistically distinguishable) with a Zipf domain-size distribution (the
class imbalance of Fig. 1b) and per-document feature vectors (domain
prototype + noise — what Alg. 1's similarity taps into).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["CorpusSpec", "DomainCorpus"]


@dataclass(frozen=True)
class CorpusSpec:
    num_docs: int = 2048
    doc_len: int = 256
    vocab_size: int = 512
    num_domains: int = 8
    domain_zipf: float = 1.2
    feature_dim: int = 32
    feature_noise: float = 0.4
    seed: int = 0


class DomainCorpus:
    """tokens: (num_docs, doc_len) int32; domains: (num_docs,); features:
    (num_docs, feature_dim) for the EW doc-similarity graph."""

    def __init__(self, spec: CorpusSpec):
        self.spec = spec
        rng = np.random.default_rng([spec.seed, 0xD0C5])
        k = spec.num_domains
        ranks = np.arange(1, k + 1, dtype=np.float64)
        p = ranks ** (-spec.domain_zipf)
        self.domain_p = p / p.sum()
        self.domains = rng.choice(k, size=spec.num_docs, p=self.domain_p).astype(np.int64)

        # per-domain Markov chains over a shared vocab (peaked transitions)
        v = spec.vocab_size
        self._trans = np.empty((k, v, v), dtype=np.float32) if v <= 1024 else None
        tokens = np.empty((spec.num_docs, spec.doc_len), dtype=np.int32)
        chains = []
        for d in range(k):
            # sparse-ish row-stochastic transition with domain-specific bias
            logits = rng.normal(0, 1.0, (v, v)) + 3.0 * rng.normal(
                0, 1.0, (1, v))  # domain-wide token preference
            probs = np.exp(logits - logits.max(axis=1, keepdims=True))
            probs /= probs.sum(axis=1, keepdims=True)
            chains.append(probs)
        for i in range(spec.num_docs):
            chain = chains[self.domains[i]]
            t = rng.integers(0, v)
            for j in range(spec.doc_len):
                tokens[i, j] = t
                t = rng.choice(v, p=chain[t])
        self.tokens = tokens

        protos = rng.normal(0, 1, (k, spec.feature_dim))
        protos /= np.linalg.norm(protos, axis=1, keepdims=True)
        self.features = (protos[self.domains]
                         + rng.normal(0, spec.feature_noise,
                                      (spec.num_docs, spec.feature_dim))).astype(np.float32)

    @property
    def num_docs(self) -> int:
        return self.spec.num_docs

    def domain_entropy(self, idx: np.ndarray | None = None) -> float:
        from ..core.entropy import label_entropy
        d = self.domains if idx is None else self.domains[idx]
        return label_entropy(d, self.spec.num_domains)
