from .corpus import DomainCorpus, CorpusSpec
from .partition import shard_corpus_by_entropy, CorpusShards
from .pipeline import ShardedBatcher

__all__ = ["DomainCorpus", "CorpusSpec", "shard_corpus_by_entropy",
           "CorpusShards", "ShardedBatcher"]
