"""Sharded batch pipeline with CBS over domain labels.

Each data-parallel shard draws documents from its own corpus shard; with
``class_balanced=True`` the draw follows the paper's Eq. 3 with the kNN
degree playing the role of the adjacency column norm.  Batches stack to
(P, B_local, S) ready to feed a pjit'd train step sharded over the data axes.
"""
from __future__ import annotations

import numpy as np

from ..core.sampler.cbs import CBSampler
from .corpus import DomainCorpus
from .partition import CorpusShards, knn_graph

__all__ = ["ShardedBatcher"]


class ShardedBatcher:
    def __init__(self, corpus: DomainCorpus, shards: CorpusShards, *,
                 batch_per_shard: int, class_balanced: bool = True,
                 subset_fraction: float = 0.25, seed: int = 0):
        self.corpus = corpus
        self.shards = shards
        self.batch_per_shard = batch_per_shard
        g = knn_graph(corpus.features, k=10)
        self._samplers = [
            CBSampler(
                g.indptr, g.indices, corpus.domains, shards.docs_of(p),
                batch_size=batch_per_shard, subset_fraction=subset_fraction,
                class_balanced=class_balanced, seed=seed + p,
            )
            for p in range(shards.num_shards)
        ]

    def next_batch(self) -> dict[str, np.ndarray]:
        """(P, B, S) tokens/labels — next-token LM objective (labels are the
        shifted sequence; last position masked)."""
        p = self.shards.num_shards
        b, s = self.batch_per_shard, self.corpus.spec.doc_len
        tokens = np.empty((p, b, s), dtype=np.int32)
        domains = np.empty((p, b), dtype=np.int64)
        for i, sampler in enumerate(self._samplers):
            nodes = sampler.sample_mini_epoch()[:b]
            if len(nodes) < b:  # tiny shard: wrap around
                nodes = np.resize(nodes, b)
            tokens[i] = self.corpus.tokens[nodes]
            domains[i] = self.corpus.domains[nodes]
        labels = np.concatenate(
            [tokens[:, :, 1:], np.full((p, b, 1), -1, np.int32)], axis=2)
        return {"tokens": tokens, "labels": labels, "domains": domains}
