"""Partitioning front-end: the paper's schemes behind one call.

Methods:
  random       hash partitioning (P3-style control)
  metis        unweighted multilevel min-cut — the DistDGL baseline
  ew           Algorithm 1 edge weights + weighted multilevel min-cut
               (minimises total entropy → micro-F1; the paper's headline)
  ew_balanced  ew + entropy-*balancing* post-pass (minimises the variance of
               partition entropies — the artifact's macro-F1 variant, used
               together with CBS + Focal loss)
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np
import scipy.sparse as sp

from ..entropy import PartitionStats, partition_stats
from .edge_weights import assign_edge_weights
from .metis import metis_kway

__all__ = ["PartitionResult", "partition_graph"]

METHODS = ("random", "metis", "ew", "ew_balanced")


@dataclass
class PartitionResult:
    method: str
    num_parts: int
    parts: np.ndarray                 # (num_nodes,) partition id
    stats: PartitionStats
    weight_time_s: float              # Alg-1 edge-weight assignment time
    partition_time_s: float           # multilevel partitioner time
    edge_weights: np.ndarray | None   # aligned with CSR indices (EW only)

    @property
    def total_time_s(self) -> float:
        return self.weight_time_s + self.partition_time_s


def _csr(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray, n: int) -> sp.csr_matrix:
    return sp.csr_matrix((data, indices.copy(), indptr.copy()), shape=(n, n))


def _entropy_balance_refine(
    parts: np.ndarray,
    labels: np.ndarray,
    num_parts: int,
    max_moves_frac: float = 0.05,
    seed: int = 0,
) -> np.ndarray:
    """Greedy pass reducing Var(H(P_k)): move labelled nodes of over-
    represented classes out of the lowest-entropy partitions into the
    partition where that class is rarest.  Bounded move budget keeps the
    edge-cut degradation small (documented trade-off in the artifact)."""
    rng = np.random.default_rng(seed)
    parts = parts.copy()
    labelled = np.flatnonzero(labels >= 0)
    if labelled.size == 0:
        return parts
    num_classes = int(labels[labelled].max()) + 1
    budget = max(1, int(labelled.size * max_moves_frac))

    def class_counts() -> np.ndarray:
        cc = np.zeros((num_parts, num_classes))
        np.add.at(cc, (parts[labelled], labels[labelled]), 1.0)
        return cc

    def entropies(counts: np.ndarray) -> np.ndarray:
        dist = counts / np.maximum(counts.sum(axis=1, keepdims=True), 1.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            return -(np.where(dist > 0, dist * np.log(dist), 0.0)).sum(axis=1)

    cc = class_counts()
    for _ in range(budget):
        ent = entropies(cc)
        var = ent.var()
        lo = int(np.argmin(ent))
        # dominant class of the low-entropy partition
        c = int(np.argmax(cc[lo]))
        if cc[lo, c] <= 1:
            break
        # receiving partition: where class c is rarest
        hi = int(np.argmin(cc[:, c] + np.where(np.arange(num_parts) == lo, np.inf, 0)))
        cand = np.flatnonzero((parts == lo) & (labels == c))
        if cand.size == 0:
            break
        # accept the move only if it actually reduces Var(H(P_k))
        trial = cc.copy()
        trial[lo, c] -= 1
        trial[hi, c] += 1
        if entropies(trial).var() >= var:
            break
        v = int(rng.choice(cand))
        parts[v] = hi
        cc = trial
    return parts


def partition_graph(
    indptr: np.ndarray,
    indices: np.ndarray,
    features: np.ndarray,
    labels: np.ndarray,
    num_parts: int,
    *,
    method: str = "ew",
    fanout_k: int = 25,
    c: float = 1.0,
    imbalance: float = 0.05,
    seed: int = 0,
) -> PartitionResult:
    """Partition a CSR graph with one of the paper's schemes."""
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    n = len(indptr) - 1

    ew: np.ndarray | None = None
    t_w = 0.0
    t0 = time.perf_counter()
    if method in ("ew", "ew_balanced"):
        ew = assign_edge_weights(
            indptr, indices, features, fanout_k=fanout_k, c=c
        ).astype(np.float64)
        t_w = time.perf_counter() - t0
        data = ew
    else:
        data = np.ones(len(indices), dtype=np.float64)

    t0 = time.perf_counter()
    if method == "random":
        # mix the seed so user-side streams seeded with the same small int
        # (labels, features, ...) are not bit-correlated with the assignment
        rng = np.random.default_rng([seed, 0xC0FFEE])
        parts = rng.integers(0, num_parts, size=n).astype(np.int64)
    else:
        adj = _csr(np.asarray(indptr), np.asarray(indices), data, n)
        parts = metis_kway(adj, num_parts, imbalance=imbalance, seed=seed)
    if method == "ew_balanced":
        parts = _entropy_balance_refine(parts, np.asarray(labels), num_parts, seed=seed)
    t_p = time.perf_counter() - t0

    stats = partition_stats(
        np.asarray(indptr), np.asarray(indices), np.asarray(labels), parts,
        num_parts, edge_weights=ew,
    )
    return PartitionResult(
        method=method,
        num_parts=num_parts,
        parts=parts,
        stats=stats,
        weight_time_s=t_w,
        partition_time_s=t_p,
        edge_weights=None if ew is None else ew.astype(np.int64),
    )
