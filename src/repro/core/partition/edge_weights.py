"""Algorithm 1 — Edge-Weighted graph construction (the EW in EW+GP+CBS).

For every directed edge (u, v) in the CSR graph:

    similarity = <x_u, x_v>                    (dot of initial features)
    p          = 1 - exp(-K / |N(v)|)          (prob. u is among the K
                                                GraphSAGE-sampled neighbours)
    W_uv       = (c * similarity + p) * 100

Nodes with similar features (and hence, usually, labels) get heavy edges, so
a weighted min-cut partitioner keeps them together — lowering per-partition
label entropy.  Low-degree nodes keep their neighbourhood local (p ≈ 1),
cutting halo-exchange volume.

The paper's METIS backend needs positive integer weights; we clamp/round the
same way.  Complexity O(|E| · D), fully vectorised here.
"""
from __future__ import annotations

import numpy as np

__all__ = ["assign_edge_weights", "edge_endpoints"]


def edge_endpoints(indptr: np.ndarray, indices: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """CSR -> (src, dst) arrays. Row u holds the *in*-neighbourhood N(u)."""
    dst = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    src = indices
    return src, dst


def assign_edge_weights(
    indptr: np.ndarray,
    indices: np.ndarray,
    features: np.ndarray,
    *,
    fanout_k: int = 25,
    c: float = 1.0,
    normalize_features: bool = True,
    block: int = 1 << 20,
) -> np.ndarray:
    """Edge weights per Algorithm 1, aligned with the CSR ``indices`` array.

    ``fanout_k`` is the GraphSAGE neighbour-sample size K (paper uses 25).
    ``c`` trades feature similarity against locality; it is the paper's graph-
    dependent hyper-parameter.  ``normalize_features`` applies L2 row
    normalisation first, keeping the dot product in [-1, 1] so a single ``c``
    works across datasets (raw OGB features have wildly varying norms; the
    paper tunes ``c`` per graph instead).
    """
    feats = np.asarray(features, dtype=np.float64)
    if normalize_features:
        norms = np.linalg.norm(feats, axis=1, keepdims=True)
        feats = feats / np.maximum(norms, 1e-12)

    src, dst = edge_endpoints(indptr, indices)
    deg = np.diff(indptr).astype(np.float64)  # |N(v)| for destination v
    p = 1.0 - np.exp(-float(fanout_k) / np.maximum(deg, 1.0))

    weights = np.empty(len(src), dtype=np.float64)
    # blocked so the (E, D) gather never materialises for huge graphs
    for lo in range(0, len(src), block):
        hi = min(lo + block, len(src))
        sim = np.einsum(
            "ed,ed->e", feats[src[lo:hi]], feats[dst[lo:hi]], optimize=True
        )
        weights[lo:hi] = (c * sim + p[dst[lo:hi]]) * 100.0

    # METIS requires strictly positive integer weights.
    return np.maximum(np.rint(weights), 1.0).astype(np.int64)
