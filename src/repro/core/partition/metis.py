"""Multilevel k-way weighted graph partitioner (METIS-style, from scratch).

PyMETIS is not installable offline, so we implement the same multilevel
recipe the paper relies on [Karypis & Kumar, SIAM JSC 1998]:

  1. COARSEN   — repeated heavy-edge matching (HEM): collapse the heaviest
                 incident edge of each unmatched vertex; edge weights add up,
                 vertex weights add up.  Stops when the graph is small or
                 matching stalls.
  2. INITIAL   — greedy weighted region-growing from k spread-out seeds on
                 the coarsest graph (capacity-bounded), followed by
                 refinement there.
  3. UNCOARSEN — project the partition back level by level; after each
                 projection run balanced label-propagation refinement
                 (a vectorised Fiduccia–Mattheyses relative: move vertices to
                 the partition they are most heavily connected to, best gains
                 first, under a vertex-weight balance cap).

Minimising *weighted* edge-cut over Algorithm-1 weights is exactly the EW
objective; with unit weights this is the paper's "METIS" baseline.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp

__all__ = ["metis_kway"]


# --------------------------------------------------------------------------
# graph helpers
# --------------------------------------------------------------------------

def _symmetrize(adj: sp.csr_matrix) -> sp.csr_matrix:
    """Undirected weighted view: W + W^T, zero diagonal."""
    a = (adj + adj.T).tocsr()
    a.setdiag(0)
    a.eliminate_zeros()
    return a


def _heavy_edge_matching(adj: sp.csr_matrix, rng: np.random.Generator) -> np.ndarray:
    """Return match[i] = partner (or i itself).  Visit order random-ish by
    ascending degree (METIS visits low-degree first to protect their edges)."""
    n = adj.shape[0]
    indptr, indices, data = adj.indptr, adj.indices, adj.data
    deg = np.diff(indptr)
    order = np.argsort(deg + rng.random(n), kind="stable")
    match = np.full(n, -1, dtype=np.int64)
    for v in order:
        if match[v] != -1:
            continue
        lo, hi = indptr[v], indptr[v + 1]
        best, best_w = -1, -1.0
        for j in range(lo, hi):
            u = indices[j]
            if u != v and match[u] == -1 and data[j] > best_w:
                best, best_w = u, data[j]
        if best == -1:
            match[v] = v
        else:
            match[v] = best
            match[best] = v
    return match


def _coarsen(
    adj: sp.csr_matrix, vwgt: np.ndarray, rng: np.random.Generator
) -> tuple[sp.csr_matrix, np.ndarray, np.ndarray]:
    """One HEM coarsening step.  Returns (coarse_adj, coarse_vwgt, cmap)."""
    n = adj.shape[0]
    match = _heavy_edge_matching(adj, rng)
    # assign coarse ids: pair (v, match[v]) shares an id
    cmap = np.full(n, -1, dtype=np.int64)
    nxt = 0
    for v in range(n):
        if cmap[v] == -1:
            u = match[v]
            cmap[v] = nxt
            cmap[u] = nxt
            nxt += 1
    nc = nxt
    proj = sp.csr_matrix(
        (np.ones(n), (np.arange(n), cmap)), shape=(n, nc)
    )
    cadj = (proj.T @ adj @ proj).tocsr()
    cadj.setdiag(0)
    cadj.eliminate_zeros()
    cvwgt = np.zeros(nc, dtype=np.float64)
    np.add.at(cvwgt, cmap, vwgt)
    return cadj, cvwgt, cmap


# --------------------------------------------------------------------------
# initial partition on the coarsest graph
# --------------------------------------------------------------------------

def _spread_seeds(adj: sp.csr_matrix, k: int, rng: np.random.Generator) -> np.ndarray:
    """k seeds, BFS-far apart (first = max weighted degree, rest maximin)."""
    n = adj.shape[0]
    wdeg = np.asarray(adj.sum(axis=1)).ravel()
    seeds = [int(np.argmax(wdeg))]
    dist = _bfs_dist(adj, seeds[0])
    for _ in range(1, k):
        cand = int(np.argmax(np.where(np.isfinite(dist), dist, -1) + rng.random(n) * 0.5))
        seeds.append(cand)
        dist = np.minimum(dist, _bfs_dist(adj, cand))
    return np.array(seeds)


def _bfs_dist(adj: sp.csr_matrix, src: int) -> np.ndarray:
    n = adj.shape[0]
    dist = np.full(n, np.inf)
    dist[src] = 0
    frontier = np.array([src])
    d = 0
    indptr, indices = adj.indptr, adj.indices
    visited = np.zeros(n, dtype=bool)
    visited[src] = True
    while frontier.size:
        d += 1
        nxt = []
        for v in frontier:
            nbrs = indices[indptr[v] : indptr[v + 1]]
            new = nbrs[~visited[nbrs]]
            visited[new] = True
            dist[new] = d
            nxt.append(new)
        frontier = np.concatenate(nxt) if nxt else np.array([], dtype=np.int64)
    return dist


def _grow_initial(
    adj: sp.csr_matrix, vwgt: np.ndarray, k: int, cap: float, rng: np.random.Generator
) -> np.ndarray:
    """Greedy capacity-bounded region growing from spread seeds."""
    n = adj.shape[0]
    parts = np.full(n, -1, dtype=np.int64)
    load = np.zeros(k)
    seeds = _spread_seeds(adj, k, rng)
    for p, s in enumerate(seeds):
        if parts[s] == -1:
            parts[s] = p
            load[p] += vwgt[s]
    indptr, indices, data = adj.indptr, adj.indices, adj.data
    # frontier-driven growth: repeatedly attach the strongest-connected
    # unassigned vertex to the least-loaded eligible partition.
    for _ in range(n * 2):
        un = np.flatnonzero(parts == -1)
        if un.size == 0:
            break
        progressed = False
        # vectorised connection strengths of unassigned nodes to each part
        onehot = sp.csr_matrix(
            (np.ones(np.count_nonzero(parts >= 0)),
             (np.flatnonzero(parts >= 0), parts[parts >= 0])),
            shape=(n, k),
        )
        conn = adj[un] @ onehot  # (|un|, k)
        conn = np.asarray(conn.todense())
        order = np.argsort(-conn.max(axis=1))
        for idx in order:
            v = un[idx]
            prefs = np.argsort(-conn[idx])
            for p in prefs:
                if conn[idx, p] <= 0 and load.min() < cap:
                    p = int(np.argmin(load))  # isolated node: least loaded
                if load[p] + vwgt[v] <= cap or load[p] == load.min():
                    parts[v] = p
                    load[p] += vwgt[v]
                    progressed = True
                    break
        if not progressed:
            # stick leftovers on least-loaded parts
            for v in np.flatnonzero(parts == -1):
                p = int(np.argmin(load))
                parts[v] = p
                load[p] += vwgt[v]
            break
    return parts


# --------------------------------------------------------------------------
# refinement (vectorised balanced label propagation / FM-relative)
# --------------------------------------------------------------------------

def _refine(
    adj: sp.csr_matrix,
    vwgt: np.ndarray,
    parts: np.ndarray,
    k: int,
    cap: float,
    passes: int,
    moves_per_pass_frac: float = 0.15,
) -> np.ndarray:
    n = adj.shape[0]
    parts = parts.copy()
    for _ in range(passes):
        onehot = sp.csr_matrix((np.ones(n), (np.arange(n), parts)), shape=(n, k))
        conn = np.asarray((adj @ onehot).todense())  # weight to each part
        cur = conn[np.arange(n), parts]
        conn[np.arange(n), parts] = -np.inf
        best = conn.argmax(axis=1)
        gain = conn[np.arange(n), best] - cur
        cand = np.flatnonzero(gain > 0)
        if cand.size == 0:
            break
        order = cand[np.argsort(-gain[cand])]
        load = np.zeros(k)
        np.add.at(load, parts, vwgt)
        budget = max(1, int(n * moves_per_pass_frac))
        moved = 0
        for v in order:
            if moved >= budget:
                break
            p_new, p_old = int(best[v]), int(parts[v])
            if load[p_new] + vwgt[v] <= cap:
                parts[v] = p_new
                load[p_new] += vwgt[v]
                load[p_old] -= vwgt[v]
                moved += 1
        if moved == 0:
            break
    return parts


def _rebalance(parts: np.ndarray, vwgt: np.ndarray, k: int, cap: float,
               adj: sp.csr_matrix) -> np.ndarray:
    """Hard balance fix-up: spill lowest-connectivity vertices of overweight
    partitions into the lightest ones."""
    n = len(parts)
    parts = parts.copy()
    load = np.zeros(k)
    np.add.at(load, parts, vwgt)
    onehot = sp.csr_matrix((np.ones(n), (np.arange(n), parts)), shape=(n, k))
    conn = np.asarray((adj @ onehot).todense())
    for p in range(k):
        while load[p] > cap:
            members = np.flatnonzero(parts == p)
            # evict member with least internal connectivity
            v = members[np.argmin(conn[members, p])]
            q = int(np.argmin(load))
            if q == p:
                break
            parts[v] = q
            load[p] -= vwgt[v]
            load[q] += vwgt[v]
    return parts


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def metis_kway(
    adj: sp.spmatrix,
    num_parts: int,
    *,
    vertex_weights: np.ndarray | None = None,
    imbalance: float = 0.05,
    coarsen_to: int | None = None,
    refine_passes: int = 6,
    seed: int = 0,
) -> np.ndarray:
    """Multilevel k-way partition of a (weighted) graph.

    ``adj`` — (n, n) sparse adjacency; weights are the Algorithm-1 edge
    weights for EW or ones for the unweighted METIS baseline.  Returns an
    int64 array of partition ids with vertex-weight balance
    ``max(load) <= (1+imbalance) * mean(load)`` (best effort, guaranteed by a
    final rebalance pass).
    """
    rng = np.random.default_rng(seed)
    adj = _symmetrize(sp.csr_matrix(adj, dtype=np.float64))
    n = adj.shape[0]
    if num_parts <= 1:
        return np.zeros(n, dtype=np.int64)
    vwgt = (
        np.ones(n, dtype=np.float64)
        if vertex_weights is None
        else np.asarray(vertex_weights, dtype=np.float64)
    )
    if coarsen_to is None:
        coarsen_to = max(128 * num_parts, 2048)

    # ---- coarsening phase
    levels: list[tuple[sp.csr_matrix, np.ndarray, np.ndarray]] = []
    cur_adj, cur_vwgt = adj, vwgt
    while cur_adj.shape[0] > coarsen_to:
        cadj, cvwgt, cmap = _coarsen(cur_adj, cur_vwgt, rng)
        if cadj.shape[0] > 0.95 * cur_adj.shape[0]:  # matching stalled
            break
        levels.append((cur_adj, cur_vwgt, cmap))
        cur_adj, cur_vwgt = cadj, cvwgt

    # ---- initial partition at the coarsest level
    total = vwgt.sum()
    cap_final = (1.0 + imbalance) * total / num_parts
    cap_coarse = (1.0 + max(imbalance, 0.10)) * total / num_parts
    parts = _grow_initial(cur_adj, cur_vwgt, num_parts, cap_coarse, rng)
    parts = _refine(cur_adj, cur_vwgt, parts, num_parts, cap_coarse, refine_passes)

    # ---- uncoarsen + refine
    for fadj, fvwgt, cmap in reversed(levels):
        parts = parts[cmap]
        parts = _refine(fadj, fvwgt, parts, num_parts, cap_final, refine_passes)

    parts = _rebalance(parts, vwgt, num_parts, cap_final, adj)
    return parts.astype(np.int64)
