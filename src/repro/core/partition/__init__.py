from .api import PartitionResult, partition_graph
from .edge_weights import assign_edge_weights
from .metis import metis_kway

__all__ = ["partition_graph", "PartitionResult", "assign_edge_weights", "metis_kway"]
