# The paper's primary contribution: entropy-aware partitioning (EW),
# class-balanced sampling (CBS), and generalize-then-personalize training
# (GP) as composable, model-agnostic framework features.
from .entropy import PartitionStats, label_entropy, partition_entropies, partition_stats
from .partition import PartitionResult, assign_edge_weights, metis_kway, partition_graph
from .sampler import CBSampler, cbs_probabilities
from .gp import (
    EarlyStopper,
    GPController,
    GPHyperParams,
    GPScheduleConfig,
    broadcast_to_partitions,
    loss_flattened,
    make_fullgraph_loss_fn,
    make_generalize_step,
    make_personalize_partition_step,
    make_personalize_step,
)

__all__ = [
    "label_entropy", "partition_entropies", "partition_stats", "PartitionStats",
    "partition_graph", "PartitionResult", "assign_edge_weights", "metis_kway",
    "CBSampler", "cbs_probabilities",
    "GPController", "GPScheduleConfig", "GPHyperParams", "EarlyStopper",
    "loss_flattened", "make_fullgraph_loss_fn", "make_generalize_step",
    "make_personalize_partition_step",
    "make_personalize_step",
    "broadcast_to_partitions",
]
