"""Label-entropy metrics for graph/corpus partitions.

The paper's central observable (Fig. 1a, Table V): the Shannon entropy of the
label distribution inside each partition.  Lower per-partition entropy means
the partition is label-homogeneous, which the paper shows correlates with a
higher local micro-F1 after personalization.

All functions are NumPy host-side utilities: partitioning is a preprocessing
step (as in the paper, where METIS runs on one host before training starts).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

__all__ = [
    "label_entropy",
    "partition_entropies",
    "PartitionStats",
    "partition_stats",
]


def label_entropy(labels: np.ndarray, num_classes: int | None = None) -> float:
    """Shannon entropy (nats) of the empirical label distribution.

    ``labels`` may contain -1 for unlabelled nodes; they are ignored, matching
    the paper's treatment of OGBN-Papers (~98% unlabelled).
    """
    labels = np.asarray(labels)
    labels = labels[labels >= 0]
    if labels.size == 0:
        return 0.0
    if num_classes is None:
        num_classes = int(labels.max()) + 1
    counts = np.bincount(labels, minlength=num_classes).astype(np.float64)
    p = counts / counts.sum()
    nz = p > 0
    return float(-(p[nz] * np.log(p[nz])).sum())


def partition_entropies(
    labels: np.ndarray, parts: np.ndarray, num_parts: int, num_classes: int | None = None
) -> np.ndarray:
    """Entropy of each partition's label distribution. Shape (num_parts,)."""
    labels = np.asarray(labels)
    parts = np.asarray(parts)
    if num_classes is None:
        valid = labels[labels >= 0]
        num_classes = int(valid.max()) + 1 if valid.size else 1
    out = np.zeros(num_parts, dtype=np.float64)
    for k in range(num_parts):
        out[k] = label_entropy(labels[parts == k], num_classes)
    return out


@dataclass(frozen=True)
class PartitionStats:
    """Summary statistics the paper reports about a partitioning."""

    num_parts: int
    sizes: np.ndarray               # nodes per partition
    labelled_sizes: np.ndarray      # LABELLED nodes per partition — the mass
                                    # the entropies describe (labels < 0 are
                                    # invisible to label_entropy)
    entropies: np.ndarray           # per-partition label entropy (nats)
    avg_entropy: float              # H(P) as in Table V (mean over partitions)
    total_entropy: float            # labelled-count-weighted sum (EW objective)
    entropy_variance: float         # the macro-F1 variant balances this
    edge_cut: int                   # raw #cut edges
    weighted_edge_cut: float        # sum of weights of cut edges
    balance: float                  # max(sizes) / mean(sizes)

    def row(self) -> str:
        return (
            f"parts={self.num_parts} H(P)={self.avg_entropy:.4f} "
            f"totH={self.total_entropy:.1f} varH={self.entropy_variance:.4f} "
            f"cut={self.edge_cut} wcut={self.weighted_edge_cut:.1f} "
            f"balance={self.balance:.3f}"
        )


def partition_stats(
    indptr: np.ndarray,
    indices: np.ndarray,
    labels: np.ndarray,
    parts: np.ndarray,
    num_parts: int,
    edge_weights: np.ndarray | None = None,
    num_classes: int | None = None,
) -> PartitionStats:
    """Full partition-quality report over a CSR graph."""
    parts = np.asarray(parts)
    labels = np.asarray(labels)
    sizes = np.bincount(parts, minlength=num_parts)
    # each partition's entropy is computed over its LABELLED nodes only
    # (label_entropy drops labels < 0), so the weighted aggregates must use
    # the same mass — full sizes would let unlabelled nodes (~98% on
    # papers-like graphs) skew the EW objective
    lab_sizes = np.bincount(parts[labels >= 0], minlength=num_parts)
    ents = partition_entropies(labels, parts, num_parts, num_classes)

    # cut edges: CSR row u -> indices[indptr[u]:indptr[u+1]]
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    cut_mask = parts[src] != parts[indices]
    edge_cut = int(cut_mask.sum())
    if edge_weights is None:
        wcut = float(edge_cut)
    else:
        wcut = float(np.asarray(edge_weights)[cut_mask].sum())

    weights = lab_sizes / max(1, lab_sizes.sum())
    total_entropy = float((ents * lab_sizes).sum())
    return PartitionStats(
        num_parts=num_parts,
        sizes=sizes,
        labelled_sizes=lab_sizes,
        entropies=ents,
        avg_entropy=float(ents.mean()),
        total_entropy=total_entropy,
        entropy_variance=float(((ents - ents.mean()) ** 2 * weights).sum()),
        edge_cut=edge_cut,
        weighted_edge_cut=wcut,
        balance=float(sizes.max() / max(1.0, sizes.mean())),
    )
