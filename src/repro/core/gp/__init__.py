from .schedule import EarlyStopper, GPController, GPScheduleConfig, loss_flattened
from .trainer import (
    GPHyperParams,
    make_fullgraph_loss_fn,
    make_generalize_step,
    make_personalize_partition_step,
    make_personalize_step,
    broadcast_to_partitions,
)

__all__ = [
    "EarlyStopper", "GPController", "GPScheduleConfig", "loss_flattened",
    "GPHyperParams", "make_fullgraph_loss_fn", "make_generalize_step",
    "make_personalize_partition_step",
    "make_personalize_step",
    "broadcast_to_partitions",
]
