"""GP phase scheduling + early stopping (paper §III-C).

Phase-0 (generalization) runs until the loss curve "starts to flatten"
(Fig. 3's magenta line) or its own early stop fires on the *average*
validation micro-F1 across partitions — all hosts switch together.

Phase-1 (personalization) runs per-host: each partition's *own* validation
micro-F1 drives its early stop independently, and each keeps its own best
model.  Under SPMD this is a boolean `active` vector gating updates.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["loss_flattened", "EarlyStopper", "GPScheduleConfig", "GPController"]


def loss_flattened(history: list[float] | np.ndarray, window: int = 5, tol: float = 0.02) -> bool:
    """True when the mean relative improvement over the last ``window``
    epochs drops below ``tol`` — the paper's personalization trigger."""
    h = np.asarray(history, dtype=np.float64)
    if len(h) < window + 1:
        return False
    recent = h[-(window + 1):]
    prev, cur = recent[:-1], recent[1:]
    rel = (prev - cur) / np.maximum(np.abs(prev), 1e-12)
    return bool(rel.mean() < tol)


@dataclass
class EarlyStopper:
    """Maximising early-stopper with patience, tracking the best epoch."""

    patience: int = 5
    min_delta: float = 0.0
    best: float = -np.inf
    best_epoch: int = -1
    bad_epochs: int = 0
    stopped: bool = False

    def update(self, value: float, epoch: int) -> bool:
        """Feed one validation score; returns True if this is a new best."""
        if self.stopped:
            return False
        if value > self.best + self.min_delta:
            self.best = value
            self.best_epoch = epoch
            self.bad_epochs = 0
            return True
        self.bad_epochs += 1
        if self.bad_epochs > self.patience:
            self.stopped = True
        return False

    def state_dict(self) -> dict:
        """JSON-safe snapshot (-inf survives the json round trip)."""
        return {"patience": self.patience, "min_delta": self.min_delta,
                "best": self.best, "best_epoch": self.best_epoch,
                "bad_epochs": self.bad_epochs, "stopped": self.stopped}

    def load_state_dict(self, d: dict) -> None:
        self.patience = int(d["patience"])
        self.min_delta = float(d["min_delta"])
        self.best = float(d["best"])
        self.best_epoch = int(d["best_epoch"])
        self.bad_epochs = int(d["bad_epochs"])
        self.stopped = bool(d["stopped"])


@dataclass
class GPScheduleConfig:
    max_epochs: int = 100
    flatten_window: int = 5
    flatten_tol: float = 0.02
    phase0_patience: int = 8
    phase1_patience: int = 5
    min_phase0_epochs: int = 3
    # optional hard split: fraction of max_epochs spent generalizing
    # (the paper's "parameter controls the proportion"); None = loss-driven
    phase0_fraction: float | None = None


@dataclass
class GPController:
    """Host-side state machine driving the two phases for N partitions."""

    num_partitions: int
    config: GPScheduleConfig = field(default_factory=GPScheduleConfig)
    phase: int = 0
    epoch: int = 0
    loss_history: list[float] = field(default_factory=list)
    phase0_stopper: EarlyStopper = field(init=False)
    phase1_stoppers: list[EarlyStopper] = field(init=False)
    personalize_start_epoch: int = -1

    def __post_init__(self) -> None:
        self.phase0_stopper = EarlyStopper(patience=self.config.phase0_patience)
        self.phase1_stoppers = [
            EarlyStopper(patience=self.config.phase1_patience)
            for _ in range(self.num_partitions)
        ]

    # -- phase-0 -----------------------------------------------------------
    def record_phase0(self, mean_loss: float, mean_val_micro_f1: float) -> bool:
        """Record one generalization epoch.  Returns True when this epoch's
        global model is the best so far (caller snapshots W^G)."""
        assert self.phase == 0
        self.loss_history.append(float(mean_loss))
        is_best = self.phase0_stopper.update(float(mean_val_micro_f1), self.epoch)
        self.epoch += 1
        return is_best

    def should_personalize(self) -> bool:
        if self.phase != 0 or self.epoch < self.config.min_phase0_epochs:
            return False
        if self.config.phase0_fraction is not None:
            return self.epoch >= int(self.config.phase0_fraction * self.config.max_epochs)
        return (
            loss_flattened(self.loss_history, self.config.flatten_window, self.config.flatten_tol)
            or self.phase0_stopper.stopped
        )

    def start_personalization(self) -> None:
        assert self.phase == 0
        self.phase = 1
        self.personalize_start_epoch = self.epoch

    # -- phase-1 -----------------------------------------------------------
    def record_phase1(self, per_partition_val_micro_f1: np.ndarray) -> np.ndarray:
        """Record one personalization epoch.  Returns a bool array marking
        partitions whose current model is their new best (caller snapshots
        those personal models)."""
        assert self.phase == 1
        scores = np.asarray(per_partition_val_micro_f1, dtype=np.float64)
        is_best = np.zeros(self.num_partitions, dtype=bool)
        for i, stopper in enumerate(self.phase1_stoppers):
            is_best[i] = stopper.update(float(scores[i]), self.epoch)
        self.epoch += 1
        return is_best

    @property
    def active_partitions(self) -> np.ndarray:
        """Bool mask of partitions still training in phase-1 ('async' stop)."""
        return np.array([not s.stopped for s in self.phase1_stoppers])

    def phase1_budgets(self, natural_iters, taper: bool = False) -> np.ndarray:
        """Per-partition iteration budgets for the next fused phase-1 step —
        the API the engine's masked variable-length scan consumes.

        ``natural_iters`` is each partition's own mini-epoch batch count (a
        scalar broadcasts).  A partition whose early stop fired gets budget
        0 (its params/opt state ride through the step bitwise untouched);
        with ``taper=True`` a partition that is burning patience (its own
        validation micro-F1 stalling) linearly sheds iterations first, so
        the fused step's trip count — max over budgets — shrinks as hosts
        approach their stop instead of falling off a cliff.
        """
        nat = np.broadcast_to(
            np.asarray(natural_iters, dtype=np.int64),
            (self.num_partitions,)).astype(np.int64).copy()
        if taper:
            for i, s in enumerate(self.phase1_stoppers):
                # nat == 0 marks an empty train set — never promote it to 1
                if not s.stopped and s.bad_epochs > 0 and nat[i] > 0:
                    frac = 1.0 - s.bad_epochs / (2.0 * (s.patience + 1))
                    nat[i] = max(1, int(round(nat[i] * frac)))
        return np.where(self.active_partitions, nat, 0).astype(np.int32)

    @property
    def done(self) -> bool:
        if self.epoch >= self.config.max_epochs:
            return True
        if self.phase == 1:
            return not self.active_partitions.any()
        return False

    # -- resume serialization ---------------------------------------------
    def state_dict(self) -> dict:
        """Full controller state as JSON-safe scalars/lists — everything the
        epoch loop's control flow depends on (RunCheckpointer host state)."""
        return {
            "phase": self.phase,
            "epoch": self.epoch,
            "loss_history": list(self.loss_history),
            "personalize_start_epoch": self.personalize_start_epoch,
            "phase0_stopper": self.phase0_stopper.state_dict(),
            "phase1_stoppers": [s.state_dict() for s in self.phase1_stoppers],
        }

    def load_state_dict(self, d: dict) -> None:
        if len(d["phase1_stoppers"]) != self.num_partitions:
            raise ValueError(
                f"controller state for {len(d['phase1_stoppers'])} partitions "
                f"cannot restore into {self.num_partitions}")
        self.phase = int(d["phase"])
        self.epoch = int(d["epoch"])
        self.loss_history = [float(x) for x in d["loss_history"]]
        self.personalize_start_epoch = int(d["personalize_start_epoch"])
        self.phase0_stopper.load_state_dict(d["phase0_stopper"])
        for s, sd in zip(self.phase1_stoppers, d["phase1_stoppers"]):
            s.load_state_dict(sd)
