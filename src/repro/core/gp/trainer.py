"""GP train-step builders — the paper's two synchronisation regimes as jit-
able step functions, agnostic to the model (GraphSAGE or any zoo transformer).

Phase-0 "generalize": classic data-parallel SGD — local grads, `lax.pmean`
over the data axes, identical update everywhere.  One logical copy of W^G.

Phase-1 "personalize": NO cross-partition gradient traffic.  Parameters gain
a leading ``partitions`` axis (sharded over the data axes on the production
mesh); every partition descends its own loss plus the Eq. 4 proximal pull
toward the frozen W^G.  A boolean ``active`` vector freezes partitions whose
early stop fired — the SPMD rendering of the paper's "each host stops
independently" (communication-asynchrony is what the paper actually exploits;
see DESIGN.md §2).

Both builders work:
  · single-device (axis_names=()) — unit tests, centralized baseline;
  · inside shard_map over the production mesh (axis_names=("pod","data")).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from ...train.losses import prox_penalty
from ...train.optim import OptState, apply_updates

__all__ = [
    "GPHyperParams",
    "make_generalize_step",
    "make_fullgraph_loss_fn",
    "make_personalize_partition_step",
    "make_personalize_step",
    "broadcast_to_partitions",
]

PyTree = Any
# loss_fn(params, batch) -> scalar loss
LossFn = Callable[[PyTree, Any], jnp.ndarray]


@dataclass(frozen=True)
class GPHyperParams:
    lambda_prox: float = 0.01      # Eq. 4 λ
    use_prox: bool = True


def make_generalize_step(
    loss_fn: LossFn,
    optimizer,
    axis_names: Sequence[str] = (),
) -> Callable:
    """Phase-0 step: (params, opt_state, batch) -> (params, opt_state, loss).

    With ``axis_names`` non-empty the step must run inside shard_map/pmap
    over those mesh axes; grads and loss are pmean'd across them, keeping
    every replica's W^G bit-identical (the paper's synchronous phase).
    """

    def step(params: PyTree, opt_state: OptState, batch: Any):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        for ax in axis_names:
            grads = jax.lax.pmean(grads, ax)
            loss = jax.lax.pmean(loss, ax)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return step


def make_fullgraph_loss_fn(fwd: Callable, loss: str = "ce",
                           focal_gamma: float = 2.0) -> LossFn:
    """Phase-0 loss over a FULL-GRAPH batch instead of a sampled minibatch.

    ``fwd(params, shard) -> (rows, C)`` is a distributed forward (halo
    exchange + the differentiable blocked aggregation op); the batch is the
    partition's graph shard itself: ``{"shard", "labels", "train_mask"}``.
    The returned ``loss_fn(params, batch)`` plugs into the exact same
    machinery as the sampled loss (:func:`make_generalize_step`, the
    engines' phase-0 scans), which is what makes full-graph training a MODE
    of the existing pipeline rather than a separate trainer: gradients flow
    through the halo exchange's own VJP into remote partitions' embeddings
    and through the aggregation op's custom VJP (the transpose-blocked
    kernel) into local ones.
    """
    from ...train.losses import cross_entropy_loss, focal_loss

    def loss_fn(params: PyTree, batch: Any) -> jnp.ndarray:
        logits = fwd(params, batch["shard"])
        if loss == "focal":
            return focal_loss(logits, batch["labels"], gamma=focal_gamma,
                              mask=batch["train_mask"])
        return cross_entropy_loss(logits, batch["labels"],
                                  mask=batch["train_mask"])

    return loss_fn


def make_personalize_partition_step(
    loss_fn: LossFn,
    optimizer,
    hp: GPHyperParams = GPHyperParams(),
) -> Callable:
    """SINGLE-partition phase-1 step — the scalar core that
    :func:`make_personalize_step` vmaps over partitions.

    Exposed separately so (a) the SPMD engine's ``shard_map`` path can run it
    one-partition-per-device without a redundant inner vmap, and (b) the
    sequential reference driver (the parity oracle in
    ``tests/test_engine_parity.py``) executes the IDENTICAL math in a Python
    loop.  Signature: (params, opt_state, batch, global_params, active)
    -> (params, opt_state, loss), no leading partitions axis anywhere.
    """

    def one_partition(params, opt_state, batch, global_params, active):
        def total_loss(p):
            base = loss_fn(p, batch)
            if hp.use_prox:
                g = jax.lax.stop_gradient(global_params)
                base = base + hp.lambda_prox * prox_penalty(p, g)
            return base

        loss, grads = jax.value_and_grad(total_loss)(params)
        updates, new_opt_state = optimizer.update(grads, opt_state, params)
        # select, don't multiply-by-gate: an inactive partition's params must
        # come back BITWISE unchanged (p + 0.0 flips the sign of -0.0), which
        # is what lets a zero-budget fused step be a true no-op
        new_params = jax.tree.map(
            lambda p, u: jnp.where(active, p + u, p), params, updates
        )
        sel = lambda new, old: jnp.where(active, new, old)
        kept_opt_state = jax.tree.map(sel, new_opt_state, opt_state)
        return new_params, kept_opt_state, loss

    return one_partition


def make_personalize_step(
    loss_fn: LossFn,
    optimizer,
    hp: GPHyperParams = GPHyperParams(),
) -> Callable:
    """Phase-1 step over per-partition params.

    Signature: (params_p, opt_state_p, batch_p, global_params, active_p)
             -> (params_p, opt_state_p, loss_p)

    All ``*_p`` arguments carry a leading ``partitions`` axis; the step is
    vmapped over it, so under pjit the partition axis shards over the data
    mesh axes and each shard group trains its own replica with ZERO
    cross-partition collectives — the paper's communication saving.

    ``active_p`` (bool per partition) masks both the parameter update and the
    optimizer-state advance once that partition early-stops.
    """
    one_partition = make_personalize_partition_step(loss_fn, optimizer, hp)

    # every per-partition arg (params, opt state incl. step counter, batch,
    # active flag) carries a leading partition axis; init the opt state with
    # jax.vmap(optimizer.init)(params_p) to get the batched step counter
    vstep = jax.vmap(one_partition, in_axes=(0, 0, 0, None, 0))

    def step(params_p, opt_state_p, batch_p, global_params, active_p):
        return vstep(params_p, opt_state_p, batch_p, global_params, active_p)

    return step


def broadcast_to_partitions(params: PyTree, num_partitions: int) -> PyTree:
    """W^G -> stacked per-partition W^P initialisation (phase transition)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (num_partitions,) + p.shape), params
    )
