"""GP train-step builders — the paper's two synchronisation regimes as jit-
able step functions, agnostic to the model (GraphSAGE or any zoo transformer).

Phase-0 "generalize": classic data-parallel SGD — local grads, `lax.pmean`
over the data axes, identical update everywhere.  One logical copy of W^G.

Phase-1 "personalize": NO cross-partition gradient traffic.  Parameters gain
a leading ``partitions`` axis (sharded over the data axes on the production
mesh); every partition descends its own loss plus the Eq. 4 proximal pull
toward the frozen W^G.  A boolean ``active`` vector freezes partitions whose
early stop fired — the SPMD rendering of the paper's "each host stops
independently" (communication-asynchrony is what the paper actually exploits;
see DESIGN.md §2).

Both builders work:
  · single-device (axis_names=()) — unit tests, centralized baseline;
  · inside shard_map over the production mesh (axis_names=("pod","data")).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from ...train.losses import prox_penalty
from ...train.optim import OptState, apply_updates

__all__ = [
    "GPHyperParams",
    "GRAD_COMPRESS_MODES",
    "make_generalize_step",
    "make_fullgraph_loss_fn",
    "make_personalize_partition_step",
    "make_personalize_step",
    "broadcast_to_partitions",
    "grad_topk_size",
    "grad_sync_wire_bytes",
    "make_bucketed_reduce_stacked",
    "make_bucketed_reduce_shard",
    "make_topk_reduce_stacked",
    "make_topk_reduce_shard",
]

PyTree = Any
# loss_fn(params, batch) -> scalar loss
LossFn = Callable[[PyTree, Any], jnp.ndarray]


@dataclass(frozen=True)
class GPHyperParams:
    lambda_prox: float = 0.01      # Eq. 4 λ
    use_prox: bool = True


def make_generalize_step(
    loss_fn: LossFn,
    optimizer,
    axis_names: Sequence[str] = (),
) -> Callable:
    """Phase-0 step: (params, opt_state, batch) -> (params, opt_state, loss).

    With ``axis_names`` non-empty the step must run inside shard_map/pmap
    over those mesh axes; grads and loss are pmean'd across them, keeping
    every replica's W^G bit-identical (the paper's synchronous phase).
    """

    def step(params: PyTree, opt_state: OptState, batch: Any):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        for ax in axis_names:
            grads = jax.lax.pmean(grads, ax)
            loss = jax.lax.pmean(loss, ax)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return step


def make_fullgraph_loss_fn(fwd: Callable, loss: str = "ce",
                           focal_gamma: float = 2.0) -> LossFn:
    """Phase-0 loss over a FULL-GRAPH batch instead of a sampled minibatch.

    ``fwd(params, shard) -> (rows, C)`` is a distributed forward (halo
    exchange + the differentiable blocked aggregation op); the batch is the
    partition's graph shard itself: ``{"shard", "labels", "train_mask"}``.
    The returned ``loss_fn(params, batch)`` plugs into the exact same
    machinery as the sampled loss (:func:`make_generalize_step`, the
    engines' phase-0 scans), which is what makes full-graph training a MODE
    of the existing pipeline rather than a separate trainer: gradients flow
    through the halo exchange's own VJP into remote partitions' embeddings
    and through the aggregation op's custom VJP (the transpose-blocked
    kernel) into local ones.
    """
    from ...train.losses import cross_entropy_loss, focal_loss

    def loss_fn(params: PyTree, batch: Any) -> jnp.ndarray:
        logits = fwd(params, batch["shard"])
        if loss == "focal":
            return focal_loss(logits, batch["labels"], gamma=focal_gamma,
                              mask=batch["train_mask"])
        return cross_entropy_loss(logits, batch["labels"],
                                  mask=batch["train_mask"])

    return loss_fn


# ---------------------------------------------------------------------------
# compressed phase-0 gradient reduction (DESIGN.md §11)
#
# Two spellings of the cross-partition gradient mean behind the same engine
# surface.  Every builder comes in a STACKED form (operates on (P, ...)
# gradients outside any collective context — the single-device engine mode
# and the sequential oracle's jitted apply) and a SHARD form (per-shard
# gradients inside vmap(axis_name=...) or shard_map, using real
# collectives).  The stacked and shard forms compute bitwise-identical
# results: the shard top-k spells its reduction all_gather + stack-axis
# sum — pure data movement followed by the oracle's exact deterministic
# reduction — and the bucketed psum's platform reduction matches the
# stack-sum bit-for-bit (the same property the engine's existing pmean
# parity tests lock).
# ---------------------------------------------------------------------------

GRAD_COMPRESS_MODES = ("none", "bucketed", "topk")


def grad_topk_size(param_count: int, frac: float) -> int:
    """Entries each partition ships per top-k sync (>= 1, <= param_count)."""
    return max(1, min(int(param_count), int(param_count * frac)))


def grad_sync_wire_bytes(mode: str, num_parts: int, param_count: int,
                         itemsize: int = 4, topk_frac: float = 0.01) -> int:
    """Bytes ONE phase-0 gradient synchronisation puts on the wire, summed
    over every partition (the per-step cost the pipeline accounts):

      none      the all_gather spelling ships each partition's full gradient
                to every peer: ``P * (P-1) * B``.
      bucketed  ring all-reduce (reduce-scatter + all-gather over static
                buckets): each rank moves ``2 * (P-1)/P * B``, fleet total
                ``2 * (P-1) * B`` — ``2/P`` of the all_gather spelling.
      topk      each partition all_gathers only its k largest entries as
                (value, int32 index) pairs: ``P * (P-1) * k * (itemsize+4)``.

    ``B = param_count * itemsize`` derives from the PAYLOAD dtype's itemsize
    (no hardcoded fp32 assumption).
    """
    P = int(num_parts)
    if P <= 1:
        return 0
    B = int(param_count) * int(itemsize)
    if mode == "none":
        return P * (P - 1) * B
    if mode == "bucketed":
        return 2 * (P - 1) * B
    if mode == "topk":
        k = grad_topk_size(param_count, topk_frac)
        return P * (P - 1) * k * (int(itemsize) + 4)
    raise ValueError(f"unknown grad compression mode {mode!r} "
                     f"(expected one of {GRAD_COMPRESS_MODES})")


def _flat_stacked(grads_stacked):
    """(P, ...) gradient pytree -> ((P, N) flat matrix, unravel for one
    partition's pytree)."""
    from jax.flatten_util import ravel_pytree

    g0 = jax.tree.map(lambda g: g[0], grads_stacked)
    _, unravel = ravel_pytree(g0)
    flat = jax.vmap(lambda g: ravel_pytree(g)[0])(grads_stacked)
    return flat, unravel


def _bucket_slices(n: int, bucket_bytes: int, itemsize: int):
    be = max(1, int(bucket_bytes) // max(1, int(itemsize)))
    return [(lo, min(lo + be, n)) for lo in range(0, n, be)]


def make_bucketed_reduce_stacked(num_parts: int, bucket_bytes: int):
    """Bucketed mean over stacked (P, ...) gradients.  Elementwise this IS
    the plain ``sum(axis=0) / P`` (bucketing a per-element reduction changes
    nothing), so the stacked bucketed mode stays bitwise with mode none —
    the property that lets one oracle serve both spellings."""

    def reduce(grads_stacked):
        flat, unravel = _flat_stacked(grads_stacked)
        chunks = [jnp.sum(flat[:, lo:hi], axis=0)
                  for lo, hi in _bucket_slices(flat.shape[1], bucket_bytes,
                                               flat.dtype.itemsize)]
        total = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks)
        return unravel(total / num_parts)

    return reduce


def make_bucketed_reduce_shard(num_parts: int, axis_name: str,
                               bucket_bytes: int):
    """Per-shard bucketed all-reduce: ravel once, one ``psum`` per static
    bucket slice (the ring-all-reduce spelling XLA can schedule bucket by
    bucket), divide, unravel."""
    from jax.flatten_util import ravel_pytree

    def reduce(grads):
        flat, unravel = ravel_pytree(grads)
        chunks = [jax.lax.psum(flat[lo:hi], axis_name)
                  for lo, hi in _bucket_slices(flat.shape[0], bucket_bytes,
                                               flat.dtype.itemsize)]
        total = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks)
        return unravel(total / num_parts)

    return reduce


def _topk_sent(g_ef, k: int):
    """Keep the k largest-|.| entries of a flat vector, zero elsewhere."""
    _, idx = jax.lax.top_k(jnp.abs(g_ef), k)
    return jnp.zeros_like(g_ef).at[idx].set(g_ef[idx])


def make_topk_reduce_stacked(num_parts: int, topk_frac: float):
    """Top-k sparsified mean with error feedback over stacked (P, ...)
    gradients.  ``residual`` is the carried (P, N) per-partition
    quantization error; returns ``(mean grads pytree, new residual)``.
    k is static (from the flat length at trace time) and ``lax.top_k`` is
    deterministic, so the compressed step is bit-reproducible."""

    def reduce(grads_stacked, residual):
        flat, unravel = _flat_stacked(grads_stacked)
        k = grad_topk_size(flat.shape[1], topk_frac)
        g_ef = flat + residual.astype(flat.dtype)
        sent = jax.vmap(lambda v: _topk_sent(v, k))(g_ef)
        new_res = (g_ef - sent).astype(residual.dtype)
        total = jnp.sum(sent, axis=0) / num_parts
        return unravel(total), new_res

    return reduce


def make_topk_reduce_shard(num_parts: int, axis_name: str, topk_frac: float):
    """Per-shard top-k reduce: each partition ships only its k
    error-compensated largest entries; the reduction is spelled
    ``all_gather`` + stack-axis sum so the result is bitwise the stacked /
    sequential reduction.  ``residual`` is this partition's (N,) error
    state; returns ``(mean grads pytree, new residual)``."""
    from jax.flatten_util import ravel_pytree

    def reduce(grads, residual):
        flat, unravel = ravel_pytree(grads)
        k = grad_topk_size(flat.shape[0], topk_frac)
        g_ef = flat + residual.astype(flat.dtype)
        sent = _topk_sent(g_ef, k)
        new_res = (g_ef - sent).astype(residual.dtype)
        all_sent = jax.lax.all_gather(sent, axis_name)      # (P, N)
        total = jnp.sum(all_sent, axis=0) / num_parts
        return unravel(total), new_res

    return reduce


def make_personalize_partition_step(
    loss_fn: LossFn,
    optimizer,
    hp: GPHyperParams = GPHyperParams(),
) -> Callable:
    """SINGLE-partition phase-1 step — the scalar core that
    :func:`make_personalize_step` vmaps over partitions.

    Exposed separately so (a) the SPMD engine's ``shard_map`` path can run it
    one-partition-per-device without a redundant inner vmap, and (b) the
    sequential reference driver (the parity oracle in
    ``tests/test_engine_parity.py``) executes the IDENTICAL math in a Python
    loop.  Signature: (params, opt_state, batch, global_params, active)
    -> (params, opt_state, loss), no leading partitions axis anywhere.
    """

    def one_partition(params, opt_state, batch, global_params, active):
        def total_loss(p):
            base = loss_fn(p, batch)
            if hp.use_prox:
                g = jax.lax.stop_gradient(global_params)
                base = base + hp.lambda_prox * prox_penalty(p, g)
            return base

        loss, grads = jax.value_and_grad(total_loss)(params)
        updates, new_opt_state = optimizer.update(grads, opt_state, params)
        # select, don't multiply-by-gate: an inactive partition's params must
        # come back BITWISE unchanged (p + 0.0 flips the sign of -0.0), which
        # is what lets a zero-budget fused step be a true no-op
        new_params = jax.tree.map(
            lambda p, u: jnp.where(active, p + u, p), params, updates
        )
        sel = lambda new, old: jnp.where(active, new, old)
        kept_opt_state = jax.tree.map(sel, new_opt_state, opt_state)
        return new_params, kept_opt_state, loss

    return one_partition


def make_personalize_step(
    loss_fn: LossFn,
    optimizer,
    hp: GPHyperParams = GPHyperParams(),
) -> Callable:
    """Phase-1 step over per-partition params.

    Signature: (params_p, opt_state_p, batch_p, global_params, active_p)
             -> (params_p, opt_state_p, loss_p)

    All ``*_p`` arguments carry a leading ``partitions`` axis; the step is
    vmapped over it, so under pjit the partition axis shards over the data
    mesh axes and each shard group trains its own replica with ZERO
    cross-partition collectives — the paper's communication saving.

    ``active_p`` (bool per partition) masks both the parameter update and the
    optimizer-state advance once that partition early-stops.
    """
    one_partition = make_personalize_partition_step(loss_fn, optimizer, hp)

    # every per-partition arg (params, opt state incl. step counter, batch,
    # active flag) carries a leading partition axis; init the opt state with
    # jax.vmap(optimizer.init)(params_p) to get the batched step counter
    vstep = jax.vmap(one_partition, in_axes=(0, 0, 0, None, 0))

    def step(params_p, opt_state_p, batch_p, global_params, active_p):
        return vstep(params_p, opt_state_p, batch_p, global_params, active_p)

    return step


def broadcast_to_partitions(params: PyTree, num_partitions: int) -> PyTree:
    """W^G -> stacked per-partition W^P initialisation (phase transition)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (num_partitions,) + p.shape), params
    )
