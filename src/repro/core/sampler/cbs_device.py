"""Device-side epoch sampling — Eq. 3 probabilities and the epoch draw as
jax ops, for BOTH training phases.

``core/sampler/cbs.py`` keeps the paper-faithful host NumPy sampler
(DistDGL's CPU workers); this module ports the SAME math to jax PRNG so the
whole epoch — subset resample, batch shuffle, fanout neighbour sampling,
feature gather — stages onto the fused epoch trace.  That removes the host
round-trip through ``stack_epoch_batches`` that otherwise bounds every
epoch (the CPU-sampling bottleneck FastSample and DistDGL's hybrid design
identify as the dominant cost).  One :class:`DeviceEpochSampler` serves
both phases (DESIGN.md §4, §7): phase-1's CBS mini-epoch is the
``class_balanced=True`` configuration; phase-0's generalization draw is the
same program — the CBS-weighted Eq. 3 mini-epoch when CBS is on, or, with
``class_balanced=False``, a uniform shuffle of the full local train set
(equal log-probabilities make the Gumbel top-k ranking a uniform
permutation — exactly ``CBSampler``'s plain-epoch contract).

Pieces:

  · :func:`cbs_probabilities_device` — Eq. 3 over ``train_idx`` in pure jnp,
    matching the NumPy reference to ~1e-12 under x64 (statistically tested
    in ``tests/test_cbs_device.py``).
  · :func:`gumbel_subset` — weighted WITHOUT-replacement subset draw via the
    Gumbel top-k trick (the first k slots of the Gumbel-perturbed ranking
    are a sequential weighted sample, exactly the host
    ``CBSampler.sample_mini_epoch`` distribution).
  · :func:`device_fanout` — uniform with-replacement fanout sampling over
    the global CSR (the jax twin of ``NeighborSampler._sample_neighbors``,
    modular pick + self-loop for isolated nodes).
  · :class:`DeviceEpochSampler` — stacked per-partition state (padded train
    sets, log Eq. 3 vectors, the global CSR + features) plus the on-trace
    per-partition epoch program the engine vmaps / shard_maps over.

A trace-time counter (:func:`device_trace_count`) mirrors the Pallas
kernel counter so tests can assert the draw is actually staged on device,
and :func:`repro.core.sampler.cbs.host_draw_count` proves the host path is
NOT hit on the async mini-epoch path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "cbs_probabilities_device",
    "eq3_column_norms",
    "gumbel_subset",
    "device_fanout",
    "DeviceEpochSampler",
    "build_device_epoch_sampler",
    "device_trace_count",
    "reset_device_trace_count",
]

_DEVICE_TRACES = 0


def device_trace_count() -> int:
    """How many times the on-device mini-epoch draw has been STAGED (traced).

    Like ``kernels.segment_agg.pallas_call_count``: increments at trace time,
    so a compiled-and-cached epoch step counts once, and a host-side fallback
    counts zero."""
    return _DEVICE_TRACES


def reset_device_trace_count() -> None:
    global _DEVICE_TRACES
    _DEVICE_TRACES = 0


def eq3_column_norms(indptr, indices) -> jnp.ndarray:
    """``||Â(:,v)||² = d_v · Σ_{u∈N(v)} 1/d_u`` for every node — the
    graph-level (train-set-independent) half of Eq. 3, computed once and
    shared across partitions."""
    indptr = jnp.asarray(indptr, jnp.int64 if jax.config.jax_enable_x64 else jnp.int32)
    indices = jnp.asarray(indices)
    n = indptr.shape[0] - 1
    counts = jnp.diff(indptr)
    deg = jnp.maximum(counts.astype(jnp.float64 if jax.config.jax_enable_x64
                                    else jnp.float32), 1.0)
    d_isqrt = 1.0 / jnp.sqrt(deg)
    d_sqrt = jnp.sqrt(deg)
    src = indices
    # dst[e] = owning row of CSR slot e (the jnp spelling of np.repeat)
    dst = jnp.searchsorted(indptr, jnp.arange(src.shape[0]), side="right") - 1
    col_sq = jnp.zeros(n, deg.dtype).at[dst].add(d_isqrt[src] ** 2)
    return col_sq * d_sqrt**2


def cbs_probabilities_device(indptr, indices, labels, train_idx,
                             col_sq=None) -> jnp.ndarray:
    """Eq. 3 sampling probabilities over ``train_idx`` in pure jnp.

    Same construction as :func:`repro.core.sampler.cbs.cbs_probabilities`:
    ``P(v) ∝ ||Â(:,v)||² / CF(class[v])``.  Runs eagerly at setup time
    (class count is data-dependent); the repeated per-epoch work is the draw,
    not this.  Pass a precomputed :func:`eq3_column_norms` as ``col_sq`` to
    amortise the O(E) graph pass across partitions.  Under
    ``jax_enable_x64`` it matches the NumPy float64 reference to ~1e-12
    (asserted statistically in tests/test_cbs_device.py).
    """
    if col_sq is None:
        col_sq = eq3_column_norms(indptr, indices)
    labels = jnp.asarray(labels)
    train_idx = jnp.asarray(train_idx)
    train_labels = labels[train_idx]
    num_classes = (int(train_labels.max()) + 1) if train_labels.size else 1
    cf = jnp.zeros(num_classes, col_sq.dtype).at[train_labels].add(1.0)
    p = col_sq[train_idx] / jnp.maximum(cf[train_labels], 1.0)
    s = p.sum()
    uniform = jnp.full(train_idx.shape[0], 1.0 / max(1, train_idx.shape[0]),
                       col_sq.dtype)
    return jnp.where(s > 0, p / jnp.where(s > 0, s, 1.0), uniform)


def gumbel_subset(key, logp: jnp.ndarray, subset_size: int) -> jnp.ndarray:
    """Positions of a weighted WITHOUT-replacement draw of ``subset_size``
    slots from ``exp(logp)`` (Gumbel top-k).  ``-inf`` entries (padding /
    zero-probability nodes) sort last and are never picked while real support
    remains."""
    g = jax.random.gumbel(key, logp.shape, jnp.float32)
    order = jnp.argsort(-(logp.astype(jnp.float32) + g))
    return order[:subset_size]


def device_fanout(key, nodes: jnp.ndarray, indptr: jnp.ndarray,
                  indices: jnp.ndarray, fanout: int) -> jnp.ndarray:
    """Uniform with-replacement neighbour fanout over the global CSR —
    the on-trace twin of ``NeighborSampler._sample_neighbors`` (modular pick
    into each node's CSR span; isolated nodes self-loop)."""
    deg = indptr[nodes + 1] - indptr[nodes]
    r = jax.random.randint(key, nodes.shape + (fanout,), 0,
                           jnp.iinfo(jnp.int32).max, dtype=jnp.int32)
    offs = indptr[nodes][:, None] + r % jnp.maximum(deg, 1)[:, None]
    nbrs = indices[offs]
    return jnp.where((deg > 0)[:, None], nbrs, nodes[:, None])


@dataclass(frozen=True)
class DeviceEpochSampler:
    """Stacked per-partition sampler state living on device.

    The engine vmaps (stacked mode) or shard_maps (mesh mode) the per-
    partition methods over the leading ``P`` axis of ``train_idx`` /
    ``logp`` / ``k``; the global CSR, features and labels are replicated
    (cross-partition neighbour fetch is allowed exactly like the host
    sampler / DistDGL's remote fetch).  The same instance drives phase-1's
    async mini-epochs AND phase-0's fused generalization epochs — a fresh
    PRNG key per epoch reshuffles, and within one epoch each valid train
    index is visited at most once (the without-replacement Gumbel top-k,
    statistically asserted in tests/test_cbs_device.py).
    """

    indptr: Any          # (N+1,) int32
    indices: Any         # (E,)  int32
    features: Any        # (N, D); None under the two-tier feature store
    labels: Any          # (N,)  int32
    train_idx: Any       # (P, T) int32 global ids, 0-padded
    logp: Any            # (P, T) log Eq.3 probability, -inf on padding
    k: Any               # (P,)  per-partition mini-epoch size
    subset_size: int     # K = max_p k_p (static)
    batch_size: int
    num_batches: int     # I = ceil(K / B) (static)
    fanouts: tuple
    natural_iters: Any = None   # host np (P,): ceil(k_p / B) — budget input
    # two-tier feature store (DESIGN.md §12): batches gather through remap
    # into the concatenated [hot | staged cold] table instead of a fully
    # resident (N, D) features array
    hot_feats: Any = None       # (Nh, D) device-resident hot rows
    remap: Any = None           # (N,) int32 global id -> [hot | cold] slot
    cold_host: Any = None       # (Nc, D) numpy, host-resident staging source

    # -------------------------------------------------- on-trace programs
    def draw_epoch(self, key, logp_row, train_row, k_row):
        """ONE partition's epoch batch indices: Gumbel top-k subset (a
        uniform permutation when the log-probabilities are flat — the
        phase-0 plain-epoch draw), uniform shuffle, fixed-shape ``(I, B)``
        chunks + validity mask."""
        global _DEVICE_TRACES
        _DEVICE_TRACES += 1
        kg, kp = jax.random.split(key)
        pick = gumbel_subset(kg, logp_row, self.subset_size)
        nodes = train_row[pick]                              # (K,)
        valid = jnp.arange(self.subset_size) < k_row
        # uniform shuffle WITHIN the valid prefix only: a partition whose
        # mini-epoch k_row is below the fleet-wide K keeps its real nodes
        # packed in the leading slots, so its natural_iters budgeted batches
        # cover exactly its own mini-epoch (scattering them over all K slots
        # would leave most of the draw untrained under a small budget)
        r = jax.random.uniform(kp, (self.subset_size,))
        order = jnp.argsort(jnp.where(valid, r, r + 2.0))
        nodes, valid = nodes[order], valid[order]
        pad = self.num_batches * self.batch_size - self.subset_size
        nodes = jnp.pad(nodes, (0, pad))
        valid = jnp.pad(valid, (0, pad))
        return (nodes.reshape(self.num_batches, self.batch_size),
                valid.reshape(self.num_batches, self.batch_size))

    def make_batch(self, key, nodes, valid, cold=None) -> dict:
        """Materialise one training batch on-trace: fanout blocks + feature
        gather — the jax twin of the pipeline's host ``make_batch``.

        Under the feature store the caller stages the cold rows (``cold``,
        the traced ``cold_host`` buffer) and the gather runs through
        ``remap`` into ``[hot | cold]`` space — bitwise identical to the
        all-resident ``features[idx]`` gather (the table is a permutation
        of the feature rows and the cast to the hot dtype is exact).
        """
        if (cold is None) != (self.cold_host is None):
            raise ValueError(
                "feat-store mismatch: pass cold= exactly when the sampler "
                "was built with feat_store=True")
        if cold is None:
            feats = self.features
        else:
            feats = jnp.concatenate(
                [self.hot_feats, cold.astype(self.hot_feats.dtype)], axis=0)
        gather = (lambda ix: feats[ix]) if cold is None else \
                 (lambda ix: feats[self.remap[ix]])
        f1, f2 = self.fanouts
        k1, k2 = jax.random.split(key)
        nbrs1 = device_fanout(k1, nodes, self.indptr, self.indices, f1)
        nbrs2 = device_fanout(k2, nbrs1.reshape(-1), self.indptr,
                              self.indices, f2)
        b = nodes.shape[0]
        d = feats.shape[-1]
        x_t = gather(nodes)
        x_1 = gather(nbrs1)
        x_2 = gather(nbrs2).reshape(b, f1, f2, d)
        labels = jnp.where(valid, self.labels[nodes], -1)
        return {"x_t": x_t, "x_1": x_1, "x_2": x_2, "labels": labels,
                "mask": valid.astype(feats.dtype)}


def build_device_epoch_sampler(graph, host_train, num_parts: int, *,
                               batch_size: int, subset_fraction: float = 0.25,
                               class_balanced: bool = True,
                               fanouts: tuple = (10, 10),
                               dtype=jnp.float32,
                               feat_store: bool = False,
                               hot_frac: float = 0.5,
                               hot_policy: str = "degree") -> DeviceEpochSampler:
    """Stage a :class:`DeviceEpochSampler` from a CSRGraph + per-host train
    sets.  Mini-epoch sizes mirror ``CBSampler.mini_epoch_size`` exactly, so
    budget accounting (``natural_iters``) matches the host sampler's batch
    counts; with ``class_balanced=False`` every partition's epoch is the
    full local train set drawn as a uniform permutation (the phase-0
    baseline draw).

    With ``feat_store=True`` the replicated (N, D) features array is NOT
    staged; instead the top ``hot_frac`` fraction of rows by ``hot_policy``
    score live on device (``hot_feats``) and the rest stay in host numpy
    (``cold_host``) for the engine to ship per compiled epoch call — the
    sampler's gathers run through ``remap`` into ``[hot | cold]`` space.
    """
    t_max = max(1, max(len(t) for t in host_train))
    train_pad = np.zeros((num_parts, t_max), np.int32)
    logp = np.full((num_parts, t_max), -np.inf, np.float32)
    ks = np.zeros(num_parts, np.int32)
    # the O(E) graph pass of Eq. 3 is train-set-independent: do it once
    col_sq = (eq3_column_norms(graph.indptr, graph.indices)
              if class_balanced else None)
    for p in range(num_parts):
        t = np.asarray(host_train[p])
        if len(t) == 0:
            continue
        train_pad[p, : len(t)] = t
        if class_balanced:
            probs = np.asarray(cbs_probabilities_device(
                graph.indptr, graph.indices, graph.labels, t,
                col_sq=col_sq))
            size = max(batch_size, int(len(t) * subset_fraction))
        else:
            probs = np.full(len(t), 1.0 / len(t))
            size = len(t)
        with np.errstate(divide="ignore"):
            logp[p, : len(t)] = np.log(probs)
        # a without-replacement draw cannot exceed the positive-probability
        # support: cap the mini-epoch there (the host sampler's replace=True
        # overflow fallback would duplicate nodes instead; capping keeps the
        # device contract that zero-probability nodes are never trained on)
        support = int((probs > 0).sum())
        ks[p] = min(size, len(t), max(support, 0))
    subset_size = int(ks.max()) if ks.max() > 0 else batch_size
    num_batches = max(1, -(-subset_size // batch_size))
    natural = np.maximum(1, -(-ks // batch_size)).astype(np.int32)
    natural[ks == 0] = 0
    if feat_store:
        from ...graph.featstore import build_global_feat_store

        gfs = build_global_feat_store(graph, hot_frac, hot_policy,
                                      np.dtype(dtype))
        feat_kw = dict(features=None,
                       hot_feats=jnp.asarray(gfs.hot, dtype),
                       remap=jnp.asarray(gfs.remap),
                       cold_host=gfs.cold)
    else:
        feat_kw = dict(features=jnp.asarray(graph.features, dtype))
    return DeviceEpochSampler(
        indptr=jnp.asarray(graph.indptr, jnp.int32),
        indices=jnp.asarray(graph.indices, jnp.int32),
        labels=jnp.asarray(graph.labels, jnp.int32),
        train_idx=jnp.asarray(train_pad),
        logp=jnp.asarray(logp),
        k=jnp.asarray(ks),
        subset_size=subset_size,
        batch_size=batch_size,
        num_batches=num_batches,
        fanouts=tuple(fanouts),
        natural_iters=natural,
        **feat_kw,
    )
