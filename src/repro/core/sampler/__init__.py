from .cbs import CBSampler, cbs_probabilities

__all__ = ["CBSampler", "cbs_probabilities"]
