from .cbs import (CBSampler, cbs_probabilities, host_draw_count,
                  reset_host_draw_count)
from .cbs_device import (DeviceEpochSampler, build_device_epoch_sampler,
                         cbs_probabilities_device, device_fanout,
                         device_trace_count, gumbel_subset,
                         reset_device_trace_count)

__all__ = [
    "CBSampler", "cbs_probabilities", "host_draw_count",
    "reset_host_draw_count",
    "DeviceEpochSampler", "build_device_epoch_sampler",
    "cbs_probabilities_device", "device_fanout", "device_trace_count",
    "gumbel_subset", "reset_device_trace_count",
]
