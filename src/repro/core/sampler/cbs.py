"""CBS — Class-Balanced Sampler (paper §III-B, Eq. 3).

Per training node v:

    P(v) = ||Â(:, v)||² / CF(class[v])        Â = D^{-1/2} A D^{1/2}

i.e. the squared column norm of the normalised adjacency (a degree-flavoured
importance, inherited from the PC-GNN "pick" sampler) divided by the class
frequency — minority classes are sampled with much higher probability.

A *mini-epoch* trains on a fraction (default 25%) of the local training set,
resampled from P every mini-epoch; batches are drawn uniformly within the
mini-epoch subset.  Mini-epochs are what give the paper its 2–3× epoch-time
reduction: majority-class examples are simply visited less often.

Everything here is host-side NumPy (the sampler feeds index arrays into the
device step), mirroring DistDGL where sampling lives on CPU workers.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import scipy.sparse as sp

__all__ = ["cbs_probabilities", "CBSampler", "host_draw_count",
           "reset_host_draw_count"]

_HOST_DRAWS = 0


def host_draw_count() -> int:
    """How many host-side NumPy mini-epoch draws have run.  The async
    personalization path must leave this untouched (the device sampler owns
    the mini-epoch draw there) — tests/test_cbs_device.py asserts it."""
    return _HOST_DRAWS


def reset_host_draw_count() -> None:
    global _HOST_DRAWS
    _HOST_DRAWS = 0


def cbs_probabilities(
    indptr: np.ndarray,
    indices: np.ndarray,
    labels: np.ndarray,
    train_idx: np.ndarray,
) -> np.ndarray:
    """Eq. 3 sampling probabilities over ``train_idx`` (sums to 1)."""
    n = len(indptr) - 1
    deg = np.maximum(np.diff(indptr).astype(np.float64), 1.0)
    d_isqrt = 1.0 / np.sqrt(deg)
    d_sqrt = np.sqrt(deg)
    # Â = D^{-1/2} A D^{1/2}; column v of Â has entries d_u^{-1/2} * d_v^{1/2}
    # over in-edges (u, v).  ||Â(:,v)||² = d_v * Σ_{u∈N(v)} 1/d_u.
    src = indices
    dst = np.repeat(np.arange(n), np.diff(indptr))
    col_sq = np.zeros(n)
    np.add.at(col_sq, dst, (d_isqrt[src] ** 2))
    col_sq *= d_sqrt**2

    labels = np.asarray(labels)
    train_idx = np.asarray(train_idx)
    train_labels = labels[train_idx]
    num_classes = int(train_labels.max()) + 1 if train_labels.size else 1
    cf = np.bincount(train_labels, minlength=num_classes).astype(np.float64)
    p = col_sq[train_idx] / np.maximum(cf[train_labels], 1.0)
    s = p.sum()
    if s <= 0:
        return np.full(len(train_idx), 1.0 / max(1, len(train_idx)))
    return p / s


@dataclass
class CBSampler:
    """Mini-epoch batch stream for one compute host (= one partition).

    ``subset_fraction=1.0`` with ``class_balanced=False`` degrades to the
    plain DistDGL epoch sampler (the paper's baseline), so ablations share
    one code path.
    """

    indptr: np.ndarray
    indices: np.ndarray
    labels: np.ndarray
    train_idx: np.ndarray
    batch_size: int = 1024
    subset_fraction: float = 0.25
    class_balanced: bool = True
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)
    _probs: np.ndarray = field(init=False, repr=False)

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        self.train_idx = np.asarray(self.train_idx)
        if self.class_balanced:
            self._probs = cbs_probabilities(
                self.indptr, self.indices, self.labels, self.train_idx
            )
        else:
            self._probs = np.full(len(self.train_idx), 1.0 / max(1, len(self.train_idx)))

    @property
    def mini_epoch_size(self) -> int:
        if not self.class_balanced:
            return len(self.train_idx)
        return max(self.batch_size, int(len(self.train_idx) * self.subset_fraction))

    def sample_mini_epoch(self) -> np.ndarray:
        """Draw the mini-epoch node SUBSET — a weighted draw without
        replacement over Eq. 3 (the paper samples a subset; duplicates would
        inflate variance)."""
        global _HOST_DRAWS
        _HOST_DRAWS += 1
        k = min(self.mini_epoch_size, len(self.train_idx))
        if k == len(self.train_idx) and not self.class_balanced:
            return self._rng.permutation(self.train_idx)
        support = int((self._probs > 0).sum())
        replace = k > support
        picks = self._rng.choice(
            len(self.train_idx), size=k, replace=replace, p=self._probs
        )
        return self.train_idx[picks]

    def batches(self) -> "list[np.ndarray]":
        """Random batches covering one mini-epoch (last ragged batch kept)."""
        nodes = self.sample_mini_epoch()
        self._rng.shuffle(nodes)
        return [
            nodes[i : i + self.batch_size] for i in range(0, len(nodes), self.batch_size)
        ]

    def empirical_class_distribution(self, num_draws: int = 10) -> np.ndarray:
        """Diagnostic: label distribution CBS actually feeds the trainer."""
        labs = np.concatenate(
            [self.labels[self.sample_mini_epoch()] for _ in range(num_draws)]
        )
        labs = labs[labs >= 0]
        num_classes = int(self.labels[self.labels >= 0].max()) + 1
        counts = np.bincount(labs, minlength=num_classes).astype(np.float64)
        return counts / max(1.0, counts.sum())
