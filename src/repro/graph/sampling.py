"""Fixed-shape GraphSAGE neighbour sampling (paper fanout (25, 25)).

DistDGL samples neighbourhoods on CPU workers and ships blocks to trainers;
we do the same: NumPy sampling here, fixed-shape index blocks into the jitted
model.  Sampling WITH replacement gives static shapes (a TPU requirement —
this is part of the GPU->TPU adaptation documented in DESIGN.md §2):

    targets      (B,)
    nbrs1        (B, F1)          neighbours of targets
    nbrs2        (B*F1, F2)       neighbours of nbrs1

Isolated nodes self-loop, matching DGL's `add_self_loop` fallback.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .csr import CSRGraph

__all__ = ["SampledBlocks", "NeighborSampler"]


@dataclass
class SampledBlocks:
    """One minibatch of sampled computation blocks (all global node ids)."""

    targets: np.ndarray            # (B,)
    nbrs1: np.ndarray              # (B, F1)
    nbrs2: np.ndarray              # (B*F1, F2)

    def feature_views(self, features: np.ndarray):
        """Gather features: x_t (B,D), x_1 (B,F1,D), x_2 (B,F1,F2,D)."""
        b, f1 = self.nbrs1.shape
        f2 = self.nbrs2.shape[1]
        x_t = features[self.targets]
        x_1 = features[self.nbrs1.reshape(-1)].reshape(b, f1, -1)
        x_2 = features[self.nbrs2.reshape(-1)].reshape(b, f1, f2, -1)
        return x_t, x_1, x_2


class NeighborSampler:
    """Uniform-with-replacement fanout sampler over a CSR graph."""

    def __init__(self, graph: CSRGraph, fanouts: tuple[int, int] = (25, 25), seed: int = 0):
        self.graph = graph
        self.fanouts = fanouts
        self._rng = np.random.default_rng([seed, 0xAB1E])

    def _sample_neighbors(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        g = self.graph
        deg = g.indptr[nodes + 1] - g.indptr[nodes]
        out = np.empty((len(nodes), fanout), dtype=np.int64)
        r = self._rng.integers(0, 1 << 62, size=(len(nodes), fanout))
        has = deg > 0
        # vectorised modular pick into each node's CSR span
        offs = (r[has] % deg[has, None]) + g.indptr[nodes[has], None]
        out[has] = g.indices[offs]
        out[~has] = nodes[~has, None]  # isolated -> self loop
        return out

    def sample(self, targets: np.ndarray) -> SampledBlocks:
        targets = np.asarray(targets, dtype=np.int64)
        f1, f2 = self.fanouts
        nbrs1 = self._sample_neighbors(targets, f1)
        nbrs2 = self._sample_neighbors(nbrs1.reshape(-1), f2)
        return SampledBlocks(targets=targets, nbrs1=nbrs1, nbrs2=nbrs2)
