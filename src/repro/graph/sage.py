"""GraphSAGE (Hamilton et al. 2017) in pure JAX — the paper's model (§II).

Eq. 1–2 with the mean aggregator:

    h_N(v) = mean(h_u, u in sampled N(v))
    h_v    = sigma(W · concat(h_N(v), h_v))

Two apply paths, ONE aggregation op:
  · ``apply_sampled`` — fixed-shape minibatch blocks from NeighborSampler
    (the DistDGL training path, 2 layers as the paper fixes).
  · ``apply_full``    — full-graph forward over edge lists (evaluation,
    centralized baseline AND full-graph training; this is the compute
    hot-spot the Pallas ``segment_agg`` kernel accelerates).

Both route Eq. 1's neighbour mean through :meth:`GraphSAGE.neighbor_mean`:
irregular CSR aggregation goes to the differentiable blocked Pallas op
``kernels.ops.segment_mean_op`` (custom VJP — ``jax.grad`` stages the
transpose kernel, DESIGN.md §6), while the sampled path's fixed-fanout
blocks are the regular degenerate case where the one-hot × matmul collapses
to a dense ``mean(axis)``.  The old per-call-site ``segment_agg=`` callback
plumbing is gone.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SAGEParams", "GraphSAGE"]


class SAGELayer(NamedTuple):
    w_self: jnp.ndarray   # (d_in, d_out)
    w_neigh: jnp.ndarray  # (d_in, d_out)
    b: jnp.ndarray        # (d_out,)


class SAGEParams(NamedTuple):
    """Stack of SAGE layers (any depth >= 1), one pytree.

    ``layer1``/``layer2`` are views kept for the fixed-two-layer call
    sites (the sampled training path and its tests): first and LAST
    layer respectively, which coincides with the old fields at depth 2.
    """

    layers: tuple[SAGELayer, ...]

    @property
    def layer1(self) -> SAGELayer:
        return self.layers[0]

    @property
    def layer2(self) -> SAGELayer:
        return self.layers[-1]


def _glorot(rng: np.random.Generator, shape: tuple[int, ...]) -> jnp.ndarray:
    fan_in, fan_out = shape[0], shape[-1]
    scale = np.sqrt(6.0 / (fan_in + fan_out))
    return jnp.asarray(rng.uniform(-scale, scale, size=shape), dtype=jnp.float32)


@dataclass(frozen=True)
class GraphSAGE:
    """Config + functional apply (params are explicit pytrees)."""

    feature_dim: int
    hidden_dim: int
    num_classes: int
    num_layers: int = 2
    l2_normalize: bool = False
    dropout: float = 0.0  # applied to inputs of each layer when training

    @property
    def layer_dims(self) -> tuple[int, ...]:
        """Per-layer (input, ..., output) widths: (D, H, ..., H, C)."""
        return ((self.feature_dim,)
                + (self.hidden_dim,) * (self.num_layers - 1)
                + (self.num_classes,))

    @property
    def layer_input_dims(self) -> tuple[int, ...]:
        """Width of the embedding each layer's halo exchange ships."""
        return self.layer_dims[:-1]

    # ---------------------------------------------------------------- init
    def init(self, seed: int = 0) -> SAGEParams:
        if self.num_layers < 1:
            raise ValueError(f"num_layers must be >= 1, got {self.num_layers}")
        rng = np.random.default_rng([seed, 0x5A6E])
        dims = self.layer_dims

        def layer(d_in: int, d_out: int) -> SAGELayer:
            return SAGELayer(
                w_self=_glorot(rng, (d_in, d_out)),
                w_neigh=_glorot(rng, (d_in, d_out)),
                b=jnp.zeros((d_out,), jnp.float32),
            )

        return SAGEParams(layers=tuple(
            layer(dims[i], dims[i + 1]) for i in range(self.num_layers)))

    # ------------------------------------------------------------- helpers
    def _layer(self, lp: SAGELayer, h_self: jnp.ndarray, h_neigh: jnp.ndarray,
               activate: bool) -> jnp.ndarray:
        out = h_self @ lp.w_self + h_neigh @ lp.w_neigh + lp.b
        if activate:
            out = jax.nn.relu(out)
            if self.l2_normalize:
                out = out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-9)
        return out

    def _maybe_dropout(self, x: jnp.ndarray, key) -> jnp.ndarray:
        if self.dropout <= 0.0 or key is None:
            return x
        keep = 1.0 - self.dropout
        mask = jax.random.bernoulli(key, keep, x.shape)
        return jnp.where(mask, x / keep, 0.0)

    # --------------------------------------------------- the aggregation op
    @staticmethod
    def neighbor_mean(x: jnp.ndarray, *, axis: int | None = None,
                      blocks: dict | None = None, num_rows: int | None = None,
                      row_base=0, interpret: bool = True) -> jnp.ndarray:
        """Eq. 1's neighbour mean — the model's single aggregation entry.

        ``blocks`` (from ``kernels.ops.build_vjp_blocks``) selects the
        irregular CSR path: the differentiable blocked Pallas op
        ``segment_mean_op`` (forward AND backward on the MXU).  ``axis``
        selects the sampled path's fixed-fanout blocks — the regular
        degenerate case (every row has exactly ``fanout`` neighbours, so the
        one-hot × matmul collapses to a dense mean along that axis).
        """
        if blocks is not None:
            from ..kernels.ops import segment_mean_op
            return segment_mean_op(x, blocks, num_rows=num_rows,
                                   row_base=row_base, interpret=interpret)
        return x.mean(axis=axis)

    # ------------------------------------------------------- sampled apply
    def apply_sampled(
        self,
        params: SAGEParams,
        x_t: jnp.ndarray,   # (B, D) target features
        x_1: jnp.ndarray,   # (B, F1, D) their sampled neighbours
        x_2: jnp.ndarray,   # (B, F1, F2, D) second-hop samples
        dropout_key=None,
    ) -> jnp.ndarray:
        """Two-layer sampled forward -> (B, num_classes) logits."""
        if self.num_layers != 2:
            raise ValueError(
                "apply_sampled is the paper's fixed two-layer fanout path; "
                f"got num_layers={self.num_layers}")
        k1 = k2 = None
        if dropout_key is not None:
            k1, k2 = jax.random.split(dropout_key)
        x_t = self._maybe_dropout(x_t, k1)

        # layer 1 for targets: aggregate their 1-hop samples
        h1_t = self._layer(params.layer1, x_t,
                           self.neighbor_mean(x_1, axis=1), activate=True)
        # layer 1 for 1-hop nodes: aggregate the 2-hop samples
        h1_1 = self._layer(params.layer1, x_1,
                           self.neighbor_mean(x_2, axis=2), activate=True)
        h1_1 = self._maybe_dropout(h1_1, k2)
        # layer 2 for targets
        logits = self._layer(params.layer2, h1_t,
                             self.neighbor_mean(h1_1, axis=1), activate=False)
        return logits

    # ---------------------------------------------------------- full apply
    def apply_full(
        self,
        params: SAGEParams,
        features: jnp.ndarray,     # (N, D)
        edge_src: jnp.ndarray,     # (E,) message sources
        edge_dst: jnp.ndarray,     # (E,) message destinations
        num_nodes: int,
        *,
        blocks: dict | None = None,   # prebuilt ops.build_vjp_blocks arrays
        use_pallas: bool = True,
        interpret: bool = True,
    ) -> jnp.ndarray:
        """Full-graph n-layer forward -> (N, num_classes) logits.

        Differentiable end-to-end: the Pallas path (default) goes through
        the custom-VJP ``segment_mean_op``, the ``use_pallas=False`` path
        through the canonical jnp reference ``kernels.ref.segment_agg_ref``
        — the same two backends every other forward consumes.  ``blocks``
        may be passed prebuilt; otherwise it is built host-side from the
        edge lists, which requires them CONCRETE — under ``jit`` with traced
        edges the call transparently falls back to the (equally
        differentiable) jnp reference, preserving the pre-blocks jit
        contract.
        """
        if use_pallas and blocks is None and any(
                isinstance(e, jax.core.Tracer) for e in (edge_src, edge_dst)):
            use_pallas = False
        if use_pallas:
            if blocks is None:
                from ..kernels.ops import build_vjp_blocks
                blocks = build_vjp_blocks(np.asarray(edge_src),
                                          np.asarray(edge_dst),
                                          num_rows=num_nodes,
                                          num_src_rows=num_nodes)
            mean_agg = lambda h: self.neighbor_mean(
                h, blocks=blocks, num_rows=num_nodes, interpret=interpret)
        else:
            from ..kernels.ref import segment_agg_ref
            mean_agg = lambda h: segment_agg_ref(
                h, edge_src, edge_dst, num_nodes, mean=True)

        h = features
        last = len(params.layers) - 1
        for i, lp in enumerate(params.layers):
            h = self._layer(lp, h, mean_agg(h), activate=i < last)
        return h

    # ------------------------------------------------------------ loss fns
    def make_loss_fn(self, loss="ce", focal_gamma: float = 2.0):
        """loss_fn(params, batch) for the GP trainer.  batch = dict with
        x_t, x_1, x_2, labels (and optional mask for padded batches)."""
        from ..train.losses import cross_entropy_loss, focal_loss

        def loss_fn(params: SAGEParams, batch: dict[str, Any]) -> jnp.ndarray:
            logits = self.apply_sampled(params, batch["x_t"], batch["x_1"], batch["x_2"])
            mask = batch.get("mask")
            if loss == "focal":
                return focal_loss(logits, batch["labels"], gamma=focal_gamma, mask=mask)
            return cross_entropy_loss(logits, batch["labels"], mask=mask)

        return loss_fn
