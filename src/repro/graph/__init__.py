from .csr import CSRGraph
from .synthetic import SyntheticSpec, make_benchmark, BENCHMARKS
from .sampling import NeighborSampler, SampledBlocks
from .sage import GraphSAGE, SAGEParams
from .distributed import (PartitionedGraph, build_partitioned_graph,
                          make_distributed_forward, make_overlap_forward,
                          make_pallas_mean_agg, make_pallas_split_agg,
                          make_ref_mean_agg, make_ref_split_agg)
from .featstore import (FeatureBudgetError, GlobalFeatStore,
                        PartitionFeatStore, build_global_feat_store,
                        build_partition_feat_store, feat_peak_bytes)

__all__ = [
    "CSRGraph", "SyntheticSpec", "make_benchmark", "BENCHMARKS",
    "NeighborSampler", "SampledBlocks", "GraphSAGE", "SAGEParams",
    "PartitionedGraph", "build_partitioned_graph", "make_distributed_forward",
    "make_overlap_forward", "make_pallas_mean_agg", "make_pallas_split_agg",
    "make_ref_mean_agg", "make_ref_split_agg",
    "FeatureBudgetError", "GlobalFeatStore", "PartitionFeatStore",
    "build_global_feat_store", "build_partition_feat_store",
    "feat_peak_bytes",
]
