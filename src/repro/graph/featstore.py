"""Two-tier feature store: hot rows resident on device, cold rows staged
from a pinned host store per compiled call (DESIGN.md §12).

Features dominate graph memory — the DistDGLv2 hybrid CPU/GPU design keeps
only a high-traffic subset resident and fetches the rest on demand.  Here
the split is STATIC and score-ordered: each partition's ``own_cap`` local
feature rows are ranked by a hot-set policy and the top ``hot_frac``
fraction stays on device while the remainder lives in host numpy, shipped
as a compiled-call argument whenever a trace needs the full feature plane.

The load-bearing invariant is *bitwise reconstruction*: scattering the hot
rows and the staged cold rows into a zero ``(max_nodes, D)`` plane
reproduces ``PartitionedGraph.features[p]`` exactly —

  * ``rows_hot`` and ``rows_cold`` PARTITION ``range(own_cap)`` (every
    owned-capacity row is in exactly one tier; asserted property tier in
    tests/test_featstore.py),
  * every row at index >= ``n_own[p]`` of ``pg.features[p]`` is zero by
    construction (halo rows arrive via exchange, pads are pads), so the
    zero base plane is already correct there, and
  * both tiers are cast to the target dtype with the SAME numpy cast the
    all-resident engine applies to the whole stack (f32 -> f64 widening is
    exact, so cast-then-gather == gather-then-cast bitwise).

Because downstream forwards only ever read the assembled ``features``
plane, the halo cache, wire compression and the overlap forward compose
with the store untouched.

Hot-set policies:

  degree   rank by clamped in-degree (``pg.deg``) — high-degree rows are
           read by the most aggregations per epoch;
  freq     degree plus a dominating boost for training-set membership —
           rows the sampled phase-0/1 batch gathers hit every epoch.

Ties break by local row index (stable argsort), so the split is a pure
function of the graph and the policy — deterministic across runs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

__all__ = ["HOT_POLICIES", "FeatureBudgetError", "GlobalFeatStore",
           "PartitionFeatStore", "assemble_features",
           "build_global_feat_store", "build_partition_feat_store",
           "check_feat_budget", "feat_peak_bytes", "hot_order",
           "reconstruct_features"]

HOT_POLICIES = ("degree", "freq")

# dominates any clamped in-degree, so under the "freq" policy every
# training row outranks every non-training row while degree still orders
# rows within each class
_FREQ_BOOST = 1e9


class FeatureBudgetError(ValueError):
    """Raised when a configuration's peak device feature bytes exceed the
    declared ``feat_budget_mb`` — the engine refuses to build rather than
    OOM mid-epoch.  A ``ValueError`` so existing config-validation handling
    catches it."""


def hot_order(scores) -> np.ndarray:
    """Row indices in descending score order, ties broken by row index
    (stable sort on the negated scores) — the one ranking primitive both
    store builders share."""
    return np.argsort(-np.asarray(scores, np.float64), kind="stable")


def _hot_count(hot_frac: float, n: int) -> int:
    if not 0.0 <= hot_frac <= 1.0:
        raise ValueError(f"hot_frac must be in [0, 1], got {hot_frac}")
    return min(max(int(round(hot_frac * n)), 0), n)


def _scores(policy: str, deg: np.ndarray, is_train: np.ndarray) -> np.ndarray:
    if policy not in HOT_POLICIES:
        raise ValueError(f"unknown hot_policy {policy!r} "
                         f"(expected one of {HOT_POLICIES})")
    scores = np.asarray(deg, np.float64)
    if policy == "freq":
        scores = scores + _FREQ_BOOST * np.asarray(is_train, np.float64)
    return scores


# ---------------------------------------------------------------------------
# partition-local store (the engine's stacked feature plane)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PartitionFeatStore:
    """Score-split owned feature rows of a :class:`PartitionedGraph`.

    ``hot`` (P, H, D) is the device-resident tier, ``cold`` (P, C, D) the
    pinned host staging buffer (H + C == own_cap); ``rows_hot``/``rows_cold``
    are the local row ids each tier scatters back into.  All arrays are
    target-dtype numpy — the caller moves ``hot`` on device once and ships
    ``cold`` per compiled call.
    """

    hot: np.ndarray        # (P, H, D) target dtype
    rows_hot: np.ndarray   # (P, H) int32 local row ids
    cold: np.ndarray       # (P, C, D) target dtype, host-resident
    rows_cold: np.ndarray  # (P, C) int32


def build_partition_feat_store(pg, hot_frac: float, policy: str,
                               dtype) -> PartitionFeatStore:
    """Split each partition's ``own_cap`` feature rows into hot/cold tiers.

    ``H = round(hot_frac * own_cap)`` is shared across partitions (the hot
    tier must stack into one (P, H, D) array); ragged real row counts are
    handled by the padding rows, which are all-zero and score lowest under
    both policies' real signals.
    """
    dtype = np.dtype(dtype)
    P, own_cap = pg.deg.shape
    d = pg.features.shape[-1]
    H = _hot_count(hot_frac, own_cap)
    C = own_cap - H
    feats = np.asarray(pg.features, dtype)
    hot = np.empty((P, H, d), dtype)
    cold = np.empty((P, C, d), dtype)
    rows_hot = np.empty((P, H), np.int32)
    rows_cold = np.empty((P, C), np.int32)
    for p in range(P):
        order = hot_order(_scores(policy, pg.deg[p],
                                  pg.train_mask[p, :own_cap]))
        rows_hot[p] = order[:H]
        rows_cold[p] = order[H:]
        hot[p] = feats[p, rows_hot[p]]
        cold[p] = feats[p, rows_cold[p]]
    return PartitionFeatStore(hot=hot, rows_hot=rows_hot,
                              cold=cold, rows_cold=rows_cold)


def assemble_features(hot, rows_hot, cold, rows_cold, max_nodes: int):
    """On-trace reassembly of one partition's full feature plane:
    ``zeros((max_nodes, D)) ∪ hot ∪ cold`` — bitwise equal to the
    all-resident ``shard["features"]`` (see the module invariant).  Works
    for empty tiers (``hot_frac`` 0.0 and 1.0): a zero-length scatter is a
    no-op."""
    d = hot.shape[-1]
    base = jnp.zeros((max_nodes, d), hot.dtype)
    return base.at[rows_hot].set(hot).at[rows_cold].set(
        cold.astype(hot.dtype))


def reconstruct_features(fs: PartitionFeatStore, max_nodes: int) -> np.ndarray:
    """Host-side inverse of the split: the full (P, max_nodes, D) stack in
    the store's dtype — what the serving export hands to the export forward
    in place of the resident stack."""
    P, _, d = fs.hot.shape
    out = np.zeros((P, max_nodes, d), fs.hot.dtype)
    for p in range(P):
        out[p, fs.rows_hot[p]] = fs.hot[p]
        out[p, fs.rows_cold[p]] = fs.cold[p]
    return out


# ---------------------------------------------------------------------------
# global store (the DeviceEpochSampler's gather table)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GlobalFeatStore:
    """Score-split GLOBAL feature rows for the on-device epoch sampler.

    Batches gather through ``remap`` into the concatenated ``[hot | cold]``
    table: ``concat(hot, cold)[remap[i]] == features[i]`` bitwise for every
    global node id i (``remap`` is a permutation of ``range(N)`` split at
    ``Nh``).
    """

    hot: np.ndarray       # (Nh, D) target dtype, device-bound
    remap: np.ndarray     # (N,) int32 global id -> [hot | cold] slot
    cold: np.ndarray      # (Nc, D) target dtype, host-resident
    hot_ids: np.ndarray   # (Nh,) global ids in score order
    cold_ids: np.ndarray  # (Nc,)


def build_global_feat_store(graph, hot_frac: float, policy: str,
                            dtype) -> GlobalFeatStore:
    dtype = np.dtype(dtype)
    n = graph.num_nodes
    feats = np.asarray(graph.features, dtype)
    deg = np.maximum(np.diff(np.asarray(graph.indptr)), 1)
    is_train = np.zeros(n, bool)
    is_train[np.asarray(graph.train_idx)] = True
    order = hot_order(_scores(policy, deg, is_train))
    nh = _hot_count(hot_frac, n)
    hot_ids = order[:nh]
    cold_ids = order[nh:]
    remap = np.empty(n, np.int32)
    remap[hot_ids] = np.arange(nh, dtype=np.int32)
    remap[cold_ids] = nh + np.arange(n - nh, dtype=np.int32)
    return GlobalFeatStore(hot=feats[hot_ids], remap=remap,
                           cold=feats[cold_ids],
                           hot_ids=hot_ids, cold_ids=cold_ids)


# ---------------------------------------------------------------------------
# feature-memory budget (the bigger-than-device gate)
# ---------------------------------------------------------------------------

def feat_peak_bytes(num_parts: int, max_nodes: int, feat_dim: int,
                    itemsize: int, *, hot_rows: int | None = None,
                    cold_rows: int = 0, groups: int = 0) -> int:
    """Closed-form PEAK device feature bytes of a configuration.

    All-resident (``hot_rows is None``): the stacked plane itself,
    ``P * maxN * D * B``.

    Feat-store: the resident hot tier plus the worst transient — staged
    cold rows and the assembled plane of every partition a single compiled
    call materializes at once.  ``groups == 0`` (no streaming) assembles
    all P partitions inside one eval program; ``groups == G`` streams the
    eval over G-partition groups, so only G cold buffers + G assembled
    planes exist at a time:

        P*H*D*B  +  G'*C*D*B  +  G'*maxN*D*B      with G' = G or P
    """
    b = int(itemsize)
    if hot_rows is None:
        return num_parts * max_nodes * feat_dim * b
    g = groups if groups else num_parts
    return (num_parts * hot_rows * feat_dim * b
            + g * cold_rows * feat_dim * b
            + g * max_nodes * feat_dim * b)


def check_feat_budget(budget_mb: float, peak_bytes: int,
                      context: str = "") -> None:
    """Refuse-to-build guard: raise :class:`FeatureBudgetError` when the
    configuration's peak feature bytes exceed ``budget_mb`` (<= 0 disables
    the check)."""
    if budget_mb <= 0:
        return
    budget = budget_mb * 1e6
    if peak_bytes > budget:
        raise FeatureBudgetError(
            f"peak device feature bytes {peak_bytes} exceed "
            f"feat_budget_mb={budget_mb:g} ({int(budget)} bytes)"
            + (f" [{context}]" if context else "")
            + "; enable feat_store / lower hot_frac / set feat_groups "
              "to stream the eval over partition groups")
