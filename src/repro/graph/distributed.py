"""Distributed graph storage + halo exchange — DistDGL's communication
pattern rendered as TPU-native SPMD collectives.

Each partition owns a contiguous local index space (DESIGN.md §5):

    [0, n_int)                interior owned nodes: every in-neighbour is
                              local, so their aggregation needs NO halo data
    [n_int, n_own)            boundary owned nodes: >= 1 in-neighbour lives
                              on another partition
    [n_own, n_own + n_halo)   halo slots (1-hop remote in-neighbours, recv'd)
    [n_local, maxN)           padding, with ONE trash row at ``trash_row``
                              (== maxN - 1) that is guaranteed all-zero and
                              never referenced by a real edge

Per layer, boundary embeddings are exchanged with either a single
``jax.lax.all_to_all`` or a chunked ``ppermute`` ring over the data axis,
using *precomputed, padded* send lists (DistDGL's dynamic RPC → static
collective; DESIGN.md §2).  The bytes on the wire are exactly
``2 · Σ_p halo_p · D · dtype`` per forward — i.e. proportional to the
edge-cut that EW partitioning minimises.

The interior/boundary split exists so the exchange can OVERLAP compute
(:func:`make_overlap_forward`): interior rows aggregate — and the self-term
matmul runs — while the halo exchange is in flight; only the boundary rows'
aggregation waits for the landed halo embeddings.  Local edges are therefore
classified into two destination-disjoint CSR shards (interior-dst vs
boundary-dst) whose per-row edge order matches the combined edge list, so
the split aggregation is bit-for-bit identical to the synchronous one on
owned rows.

Everything is padded to identical shapes across partitions so the whole
structure stacks into (P, ...) arrays sharded over the data axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph
from .sage import GraphSAGE, SAGEParams

__all__ = ["PartitionedGraph", "build_partitioned_graph", "make_distributed_forward",
           "make_overlap_forward", "make_cached_forward", "make_export_forward",
           "halo_refresh_plan", "RecomputePlanner",
           "HALO_COMPRESS_MODES", "quantize_rows", "dequantize_rows",
           "wire_row_bytes",
           "make_ref_mean_agg", "make_pallas_mean_agg",
           "make_ref_split_agg", "make_pallas_split_agg"]


@dataclass
class PartitionedGraph:
    """Stacked, padded per-partition arrays (leading axis = partition)."""

    num_parts: int
    n_own: np.ndarray          # (P,) owned-node counts
    n_int: np.ndarray          # (P,) interior counts (first n_int owned rows)
    n_halo: np.ndarray         # (P,) halo counts
    max_nodes: int             # padded local size (incl. trash row)
    own_cap: int               # max(n_own): static owned-row cap
    features: np.ndarray       # (P, maxN, D)   halo+pad rows zero
    labels: np.ndarray         # (P, maxN)      -1 on non-owned
    edge_src: np.ndarray       # (P, maxE) local ids  (pad -> trash row)
    edge_dst: np.ndarray       # (P, maxE) local ids  (pad -> trash row)
    edge_mask: np.ndarray      # (P, maxE) float32
    int_src: np.ndarray        # (P, maxEi) interior-dst edges (owned src only)
    int_dst: np.ndarray        # (P, maxEi) dst in [0, n_int)  (pad -> own_cap)
    int_mask: np.ndarray       # (P, maxEi) float32
    bnd_src: np.ndarray        # (P, maxEb) boundary-dst edges (owned+halo src)
    bnd_dst: np.ndarray        # (P, maxEb) dst in [n_int, n_own) (pad -> own_cap)
    bnd_mask: np.ndarray       # (P, maxEb) float32
    deg: np.ndarray            # (P, own_cap) float32 in-degree, clamped >= 1
    send_idx: np.ndarray       # (P, P, maxS) local owned ids to send to q
    send_mask: np.ndarray      # (P, P, maxS)
    recv_pos: np.ndarray       # (P, P, maxS) local halo slot for recv from q
    global_ids: np.ndarray     # (P, maxN) global node id (-1 pad)
    train_mask: np.ndarray     # (P, maxN) bool, owned train nodes
    val_mask: np.ndarray       # (P, maxN)
    test_mask: np.ndarray      # (P, maxN)

    @property
    def trash_row(self) -> int:
        """The one sacrificial local row (== max_nodes - 1).  Padding in the
        combined edge arrays and in ``recv_pos`` points here; the forward
        keeps it all-zero at every layer, and :func:`build_partitioned_graph`
        asserts no real edge or real recv slot ever references it."""
        return self.max_nodes - 1

    @property
    def n_boundary(self) -> np.ndarray:
        return self.n_own - self.n_int

    @property
    def halo_bytes_per_layer(self) -> int:
        d = self.features.shape[-1]
        return int(self.n_halo.sum()) * d * self.features.dtype.itemsize

    def halo_slot_bytes(self, lo: int, hi: int) -> int:
        """Real (unpadded) payload of exchanging send slots ``[lo, hi)`` of
        every partition pair, per layer — the refreshed-row bytes a cached
        forward puts on the wire.  ``halo_slot_bytes(0, maxS)`` equals
        :attr:`halo_bytes_per_layer` (every real slot lives in some pair's
        slot range, and Σ_q n_halo[q] counts each exactly once)."""
        d = self.features.shape[-1]
        real = int(self.send_mask[:, :, lo:hi].sum())
        return real * d * self.features.dtype.itemsize

    @property
    def padded_wire_bytes_per_exchange(self) -> int:
        """Bytes the padded static collective actually moves per layer
        (all pair slots padded to maxS), vs the real payload of
        :attr:`halo_bytes_per_layer`."""
        d = self.features.shape[-1]
        return int(np.prod(self.send_idx.shape)) * d * self.features.dtype.itemsize

    def summary(self) -> str:
        return (
            f"P={self.num_parts} own={self.n_own.tolist()} "
            f"int={self.n_int.tolist()} halo={self.n_halo.tolist()} "
            f"maxN={self.max_nodes} ownCap={self.own_cap} "
            f"maxE={self.edge_src.shape[1]} "
            f"maxEi={self.int_src.shape[1]} maxEb={self.bnd_src.shape[1]} "
            f"halo_bytes/layer={self.halo_bytes_per_layer}"
        )


def build_partitioned_graph(
    graph: CSRGraph, parts: np.ndarray, num_parts: int
) -> PartitionedGraph:
    parts = np.asarray(parts)
    n = graph.num_nodes
    P = num_parts
    owned0 = [np.flatnonzero(parts == p) for p in range(P)]

    # per-partition edge lists (grouped per owned dst), 1-hop halo, and the
    # interior/boundary classification: a node is BOUNDARY iff any of its
    # in-neighbours lives on another partition
    owned, halos, local_edges, n_int = [], [], [], np.zeros(P, np.int64)
    for p in range(P):
        own = owned0[p]
        src_all, dst_all = [], []
        for v in own:
            nbrs = graph.neighbors(v)
            src_all.append(nbrs)
            dst_all.append(np.full(len(nbrs), v))
        src = np.concatenate(src_all) if src_all else np.zeros(0, np.int64)
        dst = np.concatenate(dst_all) if dst_all else np.zeros(0, np.int64)
        remote = parts[src] != p
        halos.append(np.unique(src[remote]))
        is_bnd = np.zeros(n, dtype=bool)
        is_bnd[dst[remote]] = True
        interior = own[~is_bnd[own]]
        boundary = own[is_bnd[own]]
        owned.append(np.concatenate([interior, boundary]))
        n_int[p] = len(interior)
        local_edges.append((src, dst))

    n_own = np.array([len(o) for o in owned])
    n_halo = np.array([len(h) for h in halos])
    max_nodes = int((n_own + n_halo).max()) + 1          # +1 trash row
    own_cap = int(n_own.max())
    max_edges = max(1, int(max(len(e[0]) for e in local_edges)))

    d = graph.feature_dim
    feats = np.zeros((P, max_nodes, d), dtype=np.float32)
    labels = np.full((P, max_nodes), -1, dtype=np.int64)
    gids = np.full((P, max_nodes), -1, dtype=np.int64)
    trash = max_nodes - 1
    e_src = np.full((P, max_edges), trash, dtype=np.int32)
    e_dst = np.full((P, max_edges), trash, dtype=np.int32)
    e_msk = np.zeros((P, max_edges), dtype=np.float32)
    deg = np.ones((P, own_cap), dtype=np.float32)
    tr_m = np.zeros((P, max_nodes), dtype=bool)
    va_m = np.zeros((P, max_nodes), dtype=bool)
    te_m = np.zeros((P, max_nodes), dtype=bool)

    # global -> (partition, local id); locals follow the [interior | boundary]
    # owned order so boundary rows are the contiguous range [n_int, n_own)
    g2l = np.full(n, -1, dtype=np.int64)
    for p in range(P):
        g2l[owned[p]] = np.arange(n_own[p])

    halo_l = []            # (P,) global id -> halo slot, as a dense map
    for p in range(P):
        hmap = np.full(n, trash, dtype=np.int64)
        hmap[halos[p]] = n_own[p] + np.arange(n_halo[p])
        halo_l.append(hmap)

    tr, va, te = set(graph.train_idx), set(graph.val_idx), set(graph.test_idx)
    split_src, split_dst = [], []   # per-partition local edges, dst-major
    for p in range(P):
        own = owned[p]
        feats[p, : n_own[p]] = graph.features[own]
        labels[p, : n_own[p]] = graph.labels[own]
        gids[p, : n_own[p]] = own
        if len(halos[p]):
            # halo features start zero; they arrive via exchange
            gids[p, n_own[p] : n_own[p] + n_halo[p]] = halos[p]
        for j, v in enumerate(own):
            tr_m[p, j] = int(v) in tr
            va_m[p, j] = int(v) in va
            te_m[p, j] = int(v) in te

        # re-emit edges dst-major in the NEW local order (interior rows
        # first), keeping each destination's in-neighbour order — that order
        # is what makes split and combined aggregation bit-identical per row
        src, dst = local_edges[p]
        loc_src0 = np.where(parts[src] == p, g2l[src], halo_l[p][src]).astype(np.int64)
        loc_dst0 = g2l[dst]
        order = np.argsort(loc_dst0, kind="stable")
        loc_src = loc_src0[order].astype(np.int32)
        loc_dst = loc_dst0[order].astype(np.int32)
        e_src[p, : len(src)] = loc_src
        e_dst[p, : len(dst)] = loc_dst
        e_msk[p, : len(src)] = 1.0
        split_src.append(loc_src)
        split_dst.append(loc_dst)
        counts = np.bincount(loc_dst, minlength=own_cap)[:own_cap]
        deg[p] = np.maximum(counts, 1).astype(np.float32)

    # destination-disjoint CSR shards: dst-major order puts all interior-dst
    # edges (dst < n_int) ahead of the boundary-dst edges
    n_int_edges = [int(np.searchsorted(split_dst[p], n_int[p]))
                   for p in range(P)]
    max_ei = max(1, max(n_int_edges))
    max_eb = max(1, max(len(split_dst[p]) - n_int_edges[p] for p in range(P)))
    # split pads: src -> trash row (guaranteed zero, so no mask multiply is
    # needed on the hot path), dst -> the sacrificial segment row ``own_cap``
    i_src = np.full((P, max_ei), trash, dtype=np.int32)
    i_dst = np.full((P, max_ei), own_cap, dtype=np.int32)
    i_msk = np.zeros((P, max_ei), dtype=np.float32)
    b_src = np.full((P, max_eb), trash, dtype=np.int32)
    b_dst = np.full((P, max_eb), own_cap, dtype=np.int32)
    b_msk = np.zeros((P, max_eb), dtype=np.float32)
    for p in range(P):
        k = n_int_edges[p]
        i_src[p, :k] = split_src[p][:k]
        i_dst[p, :k] = split_dst[p][:k]
        i_msk[p, :k] = 1.0
        kb = len(split_src[p]) - k
        b_src[p, :kb] = split_src[p][k:]
        b_dst[p, :kb] = split_dst[p][k:]
        b_msk[p, :kb] = 1.0

    # send lists: p sends owned node g to q whenever g is in q's halo
    send_lists = [[[] for _ in range(P)] for _ in range(P)]
    recv_lists = [[[] for _ in range(P)] for _ in range(P)]
    for q in range(P):
        for g in halos[q]:
            p = int(parts[g])
            send_lists[p][q].append(int(g2l[g]))
            recv_lists[q][p].append(int(halo_l[q][g]))
    max_s = max(1, max(len(send_lists[p][q]) for p in range(P) for q in range(P)))
    s_idx = np.zeros((P, P, max_s), dtype=np.int32)
    s_msk = np.zeros((P, P, max_s), dtype=np.float32)
    r_pos = np.full((P, P, max_s), trash, dtype=np.int32)  # pad -> trash
    for p in range(P):
        for q in range(P):
            ks = len(send_lists[p][q])
            if ks:
                s_idx[p, q, :ks] = send_lists[p][q]
                s_msk[p, q, :ks] = 1.0
            kr = len(recv_lists[p][q])  # aligned with send_lists[q][p]
            if kr:
                r_pos[p, q, :kr] = recv_lists[p][q]

    # trash-row hygiene (the invariant the fast path relies on): no REAL
    # edge endpoint and no REAL recv slot may reference the trash row, so it
    # stays all-zero through every layer
    assert not (e_src[e_msk > 0] == trash).any(), "real edge src hit trash row"
    assert not (e_dst[e_msk > 0] == trash).any(), "real edge dst hit trash row"
    assert not (i_src[i_msk > 0] == trash).any()
    assert not (b_src[b_msk > 0] == trash).any()
    # recv_pos[p, q] aligns with send_lists[q][p], i.e. with s_msk[q, p]
    assert not (r_pos[np.swapaxes(s_msk, 0, 1) > 0] == trash).any(), \
        "real recv slot hit trash row"

    return PartitionedGraph(
        num_parts=P, n_own=n_own, n_int=n_int, n_halo=n_halo,
        max_nodes=max_nodes, own_cap=own_cap,
        features=feats, labels=labels, edge_src=e_src, edge_dst=e_dst,
        edge_mask=e_msk, int_src=i_src, int_dst=i_dst, int_mask=i_msk,
        bnd_src=b_src, bnd_dst=b_dst, bnd_mask=b_msk, deg=deg,
        send_idx=s_idx, send_mask=s_msk, recv_pos=r_pos,
        global_ids=gids, train_mask=tr_m, val_mask=va_m, test_mask=te_m,
    )


# ---------------------------------------------------------------------------
# halo exchange collectives
# ---------------------------------------------------------------------------

def _exchange(sent, axis_name: str, ring_chunks: int = 0):
    """Move ``sent[q]`` (this partition's rows for q) to partition q; returns
    ``recv`` with ``recv[q]`` = the rows q sent here.

    ``ring_chunks == 0``: one ``all_to_all``.  ``ring_chunks >= 1``: a P-1
    step ``ppermute`` ring where each step's payload is split into that many
    chunks, each an independent collective — on a real mesh chunk c+1's send
    overlaps chunk c's landing/compute (DESIGN.md §5).  Both deliver
    bit-identical buffers; only the schedule differs.
    """
    if ring_chunks <= 0:
        return jax.lax.all_to_all(sent, axis_name, split_axis=0,
                                  concat_axis=0, tiled=False)
    P, S = sent.shape[0], sent.shape[1]
    p = jax.lax.axis_index(axis_name)
    nc = max(1, min(ring_chunks, S))
    bounds = [round(c * S / nc) for c in range(nc + 1)]
    # self block never carries payload (a node is never its own halo), but
    # keeping it makes recv layout identical to the all_to_all's
    recv = jnp.zeros_like(sent)
    recv = jax.lax.dynamic_update_index_in_dim(
        recv, jax.lax.dynamic_index_in_dim(sent, p, axis=0, keepdims=False),
        p, axis=0)
    for s in range(1, P):
        perm = [(i, (i + s) % P) for i in range(P)]
        blk = jax.lax.dynamic_index_in_dim(sent, (p + s) % P, axis=0,
                                           keepdims=False)
        got = [jax.lax.ppermute(blk[lo:hi], axis_name, perm)
               for lo, hi in zip(bounds[:-1], bounds[1:])]
        recv = jax.lax.dynamic_update_index_in_dim(
            recv, got[0] if len(got) == 1 else jnp.concatenate(got),
            (p - s) % P, axis=0)
    return recv


def _halo_exchange(h, send_idx, send_mask, recv_pos, axis_name: str,
                   ring_chunks: int = 0):
    """One exchange round: ship owned boundary rows, land them in halo
    slots.  h: (maxN, D); send_idx/mask/recv_pos: (P, maxS[, 1])."""
    out = h[send_idx] * send_mask[..., None]          # (P, maxS, D)
    recv = _exchange(out, axis_name, ring_chunks)
    # recv[q] = rows partition q sent me; scatter into my halo slots
    flat_pos = recv_pos.reshape(-1)
    flat_val = recv.reshape(-1, h.shape[-1])
    return h.at[flat_pos].set(flat_val.astype(h.dtype))


# ---------------------------------------------------------------------------
# wire codecs (compressed communication)
# ---------------------------------------------------------------------------

HALO_COMPRESS_MODES = ("none", "fp16", "int8")


def quantize_rows(x, mode: str):
    """Quantize ``x`` (..., D) row-wise -> ``(payload, scale)``.

    ``fp16``  plain downcast, no side channel (scale is None).
    ``int8``  symmetric per-row scale ``max(|row|) / 127``: payload is int8
              in [-127, 127], scale travels as one float32 per row.  An
              all-zero row quantizes to (0, scale 0) and dequantizes to
              exact zeros — the property that keeps pad slots (and through
              them the trash row) clean across a compressed exchange.

    All arithmetic runs in ``x``'s dtype, so under ``jax_enable_x64`` the
    sequential fp64 oracle models the engine's quantization EXACTLY.
    """
    if mode == "fp16":
        return x.astype(jnp.float16), None
    if mode == "int8":
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = amax / x.dtype.type(127.0)
        safe = jnp.where(scale > 0, scale, jnp.ones_like(scale))
        q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
        return q, scale.astype(jnp.float32)
    raise ValueError(f"unknown halo compression mode {mode!r} "
                     f"(expected one of {HALO_COMPRESS_MODES[1:]})")


def dequantize_rows(payload, scale, mode: str, dtype):
    """Inverse of :func:`quantize_rows` into ``dtype``.  Deterministic and
    elementwise, so sender-side (error feedback) and receiver-side
    dequantization of the same payload are bitwise identical."""
    if mode == "fp16":
        return payload.astype(dtype)
    if mode == "int8":
        return payload.astype(dtype) * scale.astype(dtype)
    raise ValueError(f"unknown halo compression mode {mode!r}")


def wire_row_bytes(d: int, mode: str, itemsize: int = 4) -> int:
    """Bytes ONE exchanged embedding row of width ``d`` occupies on the
    wire: the uncompressed row is ``d * itemsize``, fp16 halves it, int8
    ships one byte per element plus the row's float32 scale."""
    if mode == "none":
        return d * itemsize
    if mode == "fp16":
        return d * 2
    if mode == "int8":
        return d + 4
    raise ValueError(f"unknown halo compression mode {mode!r}")


def _ef_quantized_exchange(sent, mask3, residual, mode: str, axis_name: str,
                           ring_chunks: int, out_dtype):
    """Error-compensated quantized exchange of an already-gathered send
    buffer.  Returns ``(recv, new_residual)``:

      sent_ef = (sent + residual) * mask        # carry last round's error
      payload = quantize(sent_ef)               # what goes on the wire
      new_residual = (sent_ef - dequant(payload)) * mask
      recv = dequant(exchange(payload))         # landed at the receiver

    Quantization happens BEFORE the collective, so the all_to_all and the
    chunked ppermute ring move bit-identical payload buffers — compression
    and schedule compose freely.  The int8 per-row scales travel as a
    second (tiny) collective over the same schedule.
    """
    sent_ef = (sent + residual.astype(sent.dtype)) * mask3
    payload, scale = quantize_rows(sent_ef, mode)
    deq = dequantize_rows(payload, scale, mode, sent.dtype)
    new_residual = ((sent_ef - deq) * mask3).astype(residual.dtype)
    recv_p = _exchange(payload, axis_name, ring_chunks)
    recv_s = (None if scale is None
              else _exchange(scale, axis_name, ring_chunks))
    return dequantize_rows(recv_p, recv_s, mode, out_dtype), new_residual


def halo_refresh_plan(age: int, refresh_every: int, cv: bool,
                      max_send: int) -> tuple[int, int]:
    """Static send-slot range ``[lo, hi)`` the next cached forward refreshes.

    ``age`` counts distributed eval forwards since the cache was created
    (host-side, so the choice is a Python constant baked into the trace —
    the cached-epoch executable contains NO collective at all).

      age % K == 0        full refresh: (0, max_send) — bit-for-bit the
                          synchronous exchange, which is what makes the
                          staleness-0 (K == 1) path bitwise-identical to
                          :func:`make_distributed_forward`.
      otherwise, cv off   (0, 0): aggregate purely against the cache.
      otherwise, cv on    the VR-GCN-style partial refresh: the slot space
                          is cut into K-1 contiguous chunks and cached
                          epoch c refreshes chunk c, so every halo row is
                          re-exchanged within K epochs (staleness bound)
                          and each cached epoch pays ~1/(K-1) of the full
                          payload — the "cached h plus the delta of the
                          refreshed rows" estimator.
    """
    K = max(1, int(refresh_every))
    if K == 1 or age % K == 0:
        return 0, max_send
    if not cv:
        return 0, 0
    c = (age % K) - 1
    nc = K - 1
    return (c * max_send) // nc, ((c + 1) * max_send) // nc


# ---------------------------------------------------------------------------
# aggregation backends
# ---------------------------------------------------------------------------

def make_ref_mean_agg(max_nodes: int):
    """jnp segment-op mean aggregation over a shard's local edge list — the
    interpret-mode / differentiable fallback (same math as kernels/ref.py,
    specialised to the padded shard layout)."""

    def mean_agg(h, shard):
        msg = h[shard["edge_src"]] * shard["edge_mask"][:, None].astype(h.dtype)
        s = jax.ops.segment_sum(msg, shard["edge_dst"], num_segments=max_nodes)
        deg = jax.ops.segment_sum(shard["edge_mask"].astype(h.dtype),
                                  shard["edge_dst"], num_segments=max_nodes)
        return s / jnp.maximum(deg, 1.0)[:, None]

    return mean_agg


def make_ref_split_agg(own_cap: int):
    """jnp segment-op interior/boundary aggregation pair for the overlapped
    forward.  Returns ``(agg_interior, agg_boundary)``; each maps
    ``(h, shard) -> (own_cap, D)`` and is only meaningful on its own row
    range (rows < n_int for interior, [n_int, n_own) for boundary) — the
    caller selects per row with a bitwise-safe ``jnp.where``.

    No mask multiply and no runtime degree pass: padding edges read the
    guaranteed-zero trash row and land in the sacrificial segment row
    ``own_cap`` (sliced off), and the static in-degree ships precomputed in
    ``shard["deg"]`` — two of the wins the split layout buys even before
    any exchange is overlapped.
    """

    def agg_interior(h, shard):
        s = jax.ops.segment_sum(h[shard["int_src"]], shard["int_dst"],
                                num_segments=own_cap + 1)[:own_cap]
        return s / shard["deg"][:, None].astype(h.dtype)

    def agg_boundary(h, shard):
        s = jax.ops.segment_sum(h[shard["bnd_src"]], shard["bnd_dst"],
                                num_segments=own_cap + 1)[:own_cap]
        return s / shard["deg"][:, None].astype(h.dtype)

    return agg_interior, agg_boundary


def make_pallas_mean_agg(max_nodes: int, *, interpret: bool = True):
    """Pallas-kernel mean aggregation: the GNN hot-spot on the MXU.

    Reads the paired forward/transpose blocked-CSR structure
    (``shard["blk"]``, built by ``engine.stacking.build_stacked_vjp_blocks``)
    and routes through the ONE differentiable op
    ``kernels.ops.segment_mean_op`` — ``jax.grad`` through this forward
    stages the transpose aggregation kernel (full-graph training,
    DESIGN.md §6) instead of falling back to jnp scatter ops.
    """
    from ..kernels.ops import segment_mean_op

    def mean_agg(h, shard):
        return segment_mean_op(h, shard["blk"], num_rows=max_nodes,
                               interpret=interpret).astype(h.dtype)

    return mean_agg


def make_pallas_split_agg(own_cap: int, *, interpret: bool = True):
    """Pallas interior/boundary aggregation pair for the overlapped forward.

    Each half's blocked structure covers only its own row range — interior
    rows [0, n_int), boundary rows REBASED to [0, n_own - n_int) — and is
    placed into the (own_cap, D) output by the unified op's ``row_base``
    (the row-range variant of ``segment_mean_op``), so each pass pays for
    ceil(range / BN) node blocks instead of the whole local space and stays
    differentiable: the boundary half's backward routes gradient into owned
    AND halo source rows, from where the halo exchange's own VJP carries it
    back to the owning partition.
    """
    from ..kernels.ops import segment_mean_op

    def agg_interior(h, shard):
        return segment_mean_op(h, shard["blk_int"], num_rows=own_cap,
                               row_base=0, interpret=interpret).astype(h.dtype)

    def agg_boundary(h, shard):
        return segment_mean_op(h, shard["blk_bnd"], num_rows=own_cap,
                               row_base=shard["n_int"],
                               interpret=interpret).astype(h.dtype)

    return agg_interior, agg_boundary


# ---------------------------------------------------------------------------
# SPMD forwards
# ---------------------------------------------------------------------------

def make_distributed_forward(model: GraphSAGE, pg_meta: dict,
                             axis_name: str = "data", agg=None,
                             compress: str = "none", ring_chunks: int = 0):
    """Build the per-shard n-layer SYNCHRONOUS forward with halo exchange.

    Returns ``fwd(params, shard) -> logits`` where ``shard`` is the
    per-partition slice of the stacked PartitionedGraph arrays; call it
    inside ``shard_map`` over a partition mesh, or under
    ``vmap(..., axis_name=...)`` for the single-device stacked fallback
    (jax batches ``all_to_all`` across the vmapped axis with the same
    transpose semantics — see DESIGN.md §3).

    ``agg(h, shard) -> (max_nodes, D)`` selects the aggregation backend;
    default is the jnp segment-op reference, the SPMD engine passes
    :func:`make_pallas_mean_agg` to put the Pallas kernel on the hot path.

    ``compress`` (DESIGN.md §11): ``"none"`` returns EXACTLY the forward
    above — the same closure, no extra arguments, so compression off is
    bit-for-bit today's trace by construction.  ``"fp16"``/``"int8"``
    return the error-compensated quantized variant
    ``fwd(params, shard, residual) -> (logits, new_residual)`` where
    ``residual["r{i}"]`` is layer i's carried send-side quantization error
    (same (P, maxS, D_i) geometry as the send lists); ``ring_chunks``
    selects the exchange schedule for the quantized payloads (the
    uncompressed forward keeps its all_to_all spelling untouched).

    Every layer's exchange fully serialises before any aggregation — the
    baseline :func:`make_overlap_forward` is benchmarked against.
    """
    max_nodes = pg_meta["max_nodes"]
    mean_agg = agg if agg is not None else make_ref_mean_agg(max_nodes)

    if compress == "none":
        def fwd(params: SAGEParams, shard: dict) -> jnp.ndarray:
            h = shard["features"]
            last = len(params.layers) - 1
            for i, lp in enumerate(params.layers):
                h = _halo_exchange(h, shard["send_idx"], shard["send_mask"],
                                   shard["recv_pos"], axis_name)
                a = mean_agg(h, shard)
                h = h @ lp.w_self + a @ lp.w_neigh + lp.b
                if i < last:
                    h = jax.nn.relu(h)
            return h

        return fwd

    def fwd_c(params: SAGEParams, shard: dict, residual: dict):
        h = shard["features"]
        mask3 = shard["send_mask"][..., None]
        last = len(params.layers) - 1
        new_res = {}
        for i, lp in enumerate(params.layers):
            sent = h[shard["send_idx"]] * mask3
            recv, new_res[f"r{i}"] = _ef_quantized_exchange(
                sent, mask3, residual[f"r{i}"], compress, axis_name,
                ring_chunks, h.dtype)
            h = h.at[shard["recv_pos"].reshape(-1)].set(
                recv.reshape(-1, h.shape[-1]).astype(h.dtype))
            a = mean_agg(h, shard)
            h = h @ lp.w_self + a @ lp.w_neigh + lp.b
            if i < last:
                h = jax.nn.relu(h)
        return h, new_res

    return fwd_c


def make_cached_forward(model: GraphSAGE, pg_meta: dict,
                        axis_name: str = "data", agg=None,
                        refresh_lo: int = 0, refresh_hi: int | None = None,
                        ring_chunks: int = 0, compress: str = "none"):
    """Build the per-shard n-layer forward against a HISTORICAL halo cache.

    Returns ``fwd(params, shard, cache) -> (logits, new_cache)`` where
    ``cache`` holds each layer's last-received exchange buffers in recv
    layout: ``{"h0": (P, maxS, D), "h1": (P, maxS, H), ...}`` per partition
    (``cache["hl"][q]`` = the rows partition q last sent here for layer l).
    Pad slots are zero at init and the refresh writes sender-masked zeros
    into them, so landing the cache never dirties the trash row.

    ``[refresh_lo, refresh_hi)`` is the STATIC send-slot range this call
    re-exchanges (from :func:`halo_refresh_plan`); everything outside it
    aggregates against the cached rows:

      full range    skip the cache landing entirely — gather/exchange/
                    scatter is then exactly :func:`_halo_exchange`, so a
                    refresh step is bit-for-bit the synchronous forward
                    while ALSO snapshotting the recv buffers into the cache.
      empty range   land cached rows only; the trace contains no collective.
      partial       land the cache, then exchange just the slot slice and
                    overwrite those rows fresh (the control-variate delta).

    Cached halo rows enter aggregation as constants (no VJP through past
    epochs), which is the VR-GCN historical-activation semantics.

    ``compress != "none"`` quantizes the REFRESH payload (the ``[lo, hi)``
    slice) with error feedback on the matching residual slot slice; the
    cache stores the DEQUANTIZED rows, so cached aggregation math is
    untouched.  The signature gains the residual:
    ``fwd(params, shard, cache, residual) -> (logits, new_cache,
    new_residual)``.  ``compress == "none"`` keeps today's closure and
    signature bit-for-bit.
    """
    max_nodes = pg_meta["max_nodes"]
    mean_agg = agg if agg is not None else make_ref_mean_agg(max_nodes)
    lo = int(refresh_lo)

    def land_and_refresh(h, shard, cached, res=None):
        hi = shard["send_idx"].shape[-1] if refresh_hi is None else refresh_hi
        full = lo == 0 and hi == shard["send_idx"].shape[-1]
        if hi > lo:
            # gather (and, compressed, quantize) BEFORE any cache landing:
            # send_idx only ever points at owned rows, and keeping the order
            # is what preserves today's trace for compress == "none"
            mask3 = shard["send_mask"][:, lo:hi][..., None]
            sent = h[shard["send_idx"][:, lo:hi]] * mask3
        if not full:
            h = h.at[shard["recv_pos"].reshape(-1)].set(
                cached.reshape(-1, h.shape[-1]).astype(h.dtype))
        if hi > lo:
            if res is None:
                recv = _exchange(sent, axis_name, ring_chunks)
            else:
                recv, new_r = _ef_quantized_exchange(
                    sent, mask3, res[:, lo:hi], compress, axis_name,
                    ring_chunks, h.dtype)
                res = res.at[:, lo:hi].set(new_r)
            h = h.at[shard["recv_pos"][:, lo:hi].reshape(-1)].set(
                recv.reshape(-1, h.shape[-1]).astype(h.dtype))
            cached = cached.at[:, lo:hi].set(recv.astype(cached.dtype))
        return h, cached, res

    def fwd(params: SAGEParams, shard: dict, cache: dict):
        h = shard["features"]
        last = len(params.layers) - 1
        new_cache = {}
        for i, lp in enumerate(params.layers):
            h, new_cache[f"h{i}"], _ = land_and_refresh(h, shard,
                                                        cache[f"h{i}"])
            a = mean_agg(h, shard)
            h = h @ lp.w_self + a @ lp.w_neigh + lp.b
            if i < last:
                h = jax.nn.relu(h)
        return h, new_cache

    def fwd_c(params: SAGEParams, shard: dict, cache: dict, residual: dict):
        h = shard["features"]
        last = len(params.layers) - 1
        new_cache, new_res = {}, {}
        for i, lp in enumerate(params.layers):
            h, new_cache[f"h{i}"], new_res[f"r{i}"] = land_and_refresh(
                h, shard, cache[f"h{i}"], residual[f"r{i}"])
            a = mean_agg(h, shard)
            h = h @ lp.w_self + a @ lp.w_neigh + lp.b
            if i < last:
                h = jax.nn.relu(h)
        return h, new_cache, new_res

    return fwd if compress == "none" else fwd_c


def make_overlap_forward(model: GraphSAGE, pg_meta: dict,
                         axis_name: str = "data", agg_interior=None,
                         agg_boundary=None, ring_chunks: int = 0):
    """Build the per-shard n-layer OVERLAPPED forward (DESIGN.md §5).

    Per layer the program is issued in an order XLA's async collective
    scheduler can overlap on a real mesh:

      1. gather the send rows and START the exchange (all_to_all, or a
         ``ring_chunks``-chunked ppermute ring),
      2. interior aggregation + the self-term matmul — neither reads a halo
         row, so both run while the exchange is in flight,
      3. land the received rows in the halo slots,
      4. boundary aggregation (the only halo-dependent compute), then the
         bitwise-safe per-row select between the two halves.

    Beyond the overlap, the split layout does strictly less work than the
    synchronous forward: dense transforms and aggregation outputs cover the
    ``own_cap`` owned rows instead of the full padded local space (halo
    rows are recomputed by their OWNING partition and exchanged, never
    transformed locally), degrees are static host constants, and padding
    edges read the guaranteed-zero trash row so no edge mask multiply runs.
    On owned rows the result is bit-for-bit identical to
    :func:`make_distributed_forward` (tests/test_engine_parity.py); halo
    and pad logit rows are NOT meaningful in either forward and differ
    between the two.

    Overlap is a no-op when P == 1 or every halo is empty: the exchange
    carries nothing, the boundary ranges are empty, and the per-row select
    resolves entirely to the interior half.
    """
    max_nodes = pg_meta["max_nodes"]
    own_cap = pg_meta["own_cap"]
    if agg_interior is None or agg_boundary is None:
        agg_interior, agg_boundary = make_ref_split_agg(own_cap)
    rows = np.arange(own_cap)[:, None]

    def split_layer(h, shard, layer, activate: bool):
        # (1) start the exchange first so everything until (3) overlaps it
        sent = h[shard["send_idx"]] * shard["send_mask"][..., None]
        recv = _exchange(sent, axis_name, ring_chunks)
        # (2) halo-independent compute
        agg_i = agg_interior(h, shard)
        self_t = h[:own_cap] @ layer.w_self
        # (3) land the halo rows
        flat_pos = shard["recv_pos"].reshape(-1)
        h = h.at[flat_pos].set(recv.reshape(-1, h.shape[-1]).astype(h.dtype))
        # (4) boundary aggregation + bitwise-safe per-row select
        agg_b = agg_boundary(h, shard)
        agg = jnp.where(rows < shard["n_int"], agg_i, agg_b)
        out = self_t + agg @ layer.w_neigh + layer.b
        if activate:
            out = jax.nn.relu(out)
        return out

    def embed(out):
        # re-embed owned rows into the padded local space: halo slots are
        # refreshed by the NEXT layer's exchange before anything reads them,
        # and the trash row (maxN - 1 > own_cap - 1) stays zero
        return jnp.zeros((max_nodes, out.shape[-1]), out.dtype).at[:own_cap].set(out)

    def fwd(params: SAGEParams, shard: dict) -> jnp.ndarray:
        h = shard["features"]
        last = len(params.layers) - 1
        for i, lp in enumerate(params.layers):
            h = embed(split_layer(h, shard, lp, activate=i < last))
        return h

    return fwd


def make_export_forward(model: GraphSAGE, pg_meta: dict,
                        axis_name: str = "data", agg=None):
    """Synchronous forward that ALSO materializes the serving handoff.

    Returns ``fwd(params, shard) -> {"layers", "logits", "cache"}`` where
    ``layers[i]`` is layer i's POST-exchange input embedding over the full
    padded local space (owned rows + freshly landed halo rows), ``logits``
    is bit-for-bit :func:`make_distributed_forward`'s output (same gather/
    exchange/scatter spelling, same contraction order), and ``cache`` is
    the recv-layout halo buffer snapshot ``{"h{i}": (P, maxS, D_i)}`` — the
    exact arrays a full-refresh :func:`make_cached_forward` step would have
    written, so the serving engine lands its halo rows from the same PR-6
    cache geometry (``recv_pos`` slots) the training eval path uses.
    """
    max_nodes = pg_meta["max_nodes"]
    mean_agg = agg if agg is not None else make_ref_mean_agg(max_nodes)

    def fwd(params: SAGEParams, shard: dict) -> dict:
        h = shard["features"]
        last = len(params.layers) - 1
        layers, cache = [], {}
        for i, lp in enumerate(params.layers):
            sent = h[shard["send_idx"]] * shard["send_mask"][..., None]
            recv = _exchange(sent, axis_name)
            h = h.at[shard["recv_pos"].reshape(-1)].set(
                recv.reshape(-1, h.shape[-1]).astype(h.dtype))
            cache[f"h{i}"] = recv
            layers.append(h)
            a = mean_agg(h, shard)
            h = h @ lp.w_self + a @ lp.w_neigh + lp.b
            if i < last:
                h = jax.nn.relu(h)
        return {"layers": tuple(layers), "logits": h, "cache": cache}

    return fwd


class RecomputePlanner:
    """Dirty-set propagation over the partitioned CSR shards (serving).

    Built once from a :class:`PartitionedGraph`; answers "after these rows'
    layer-(l-1) embeddings changed, which OWNED rows must recompute layer
    l?" per partition, including the replica mirroring between layers that
    keeps halo copies consistent with their owners.

    The rule per layer (DESIGN.md §9): a row recomputes iff its own input
    changed (self term) or a local in-neighbour's input changed (edges are
    stored dst-major per partition; the planner holds the src-major CSC
    mirror of the same local edge lists).  Rows whose IN-EDGES changed are
    seeded at layer 1 and carried forward by the self term.  Edge removals
    are only RECORDED at first: stale out-edges can only over-propagate
    (recompute a clean row to the same value), never under-propagate, so
    correctness needs no eager CSC deletion.  Once a partition accumulates
    ``compact_after`` recorded removals the planner compacts — rebuilds
    that shard's CSC from (static minus removed) plus the dynamically
    added edges — so long-running serving with heavy churn stops paying
    for dirty cones through edges that no longer exist.  :meth:`compact`
    forces the rebuild on demand.

    The replica map comes from the send/recv lists: owner p's local row
    ``send_idx[p, q, s]`` has a halo copy at q's ``recv_pos[q, p, s]``.
    Serving-time halo growth registers new replicas / out-edges through
    :meth:`add_replica` / :meth:`add_out_edge`.
    """

    def __init__(self, pg: PartitionedGraph, *, compact_after: int = 64):
        P = pg.num_parts
        self.num_parts = P
        self.compact_after = int(compact_after)
        self.compactions = 0
        self.n_own = np.asarray(pg.n_own).copy()
        self._csc = []
        for p in range(P):
            real = np.asarray(pg.edge_mask[p]) > 0
            src = np.asarray(pg.edge_src[p])[real].astype(np.int64)
            dst = np.asarray(pg.edge_dst[p])[real].astype(np.int64)
            order = np.argsort(src, kind="stable")
            n_rows = int(pg.max_nodes)
            counts = np.bincount(src, minlength=n_rows)
            ptr = np.zeros(n_rows + 1, np.int64)
            np.cumsum(counts, out=ptr[1:])
            self._csc.append((ptr, dst[order]))
        # dynamically added out-edges (src_local -> [dst_local]) per part
        self._extra_out: list[dict[int, list[int]]] = [{} for _ in range(P)]
        # removals recorded against the static CSC, pending compaction
        self._removed: list[set[tuple[int, int]]] = [set() for _ in range(P)]
        # replica lists: owner p's local row -> [(peer q, q's halo row)]
        self._rep: list[dict[int, list[tuple[int, int]]]] = [{} for _ in range(P)]
        send_idx = np.asarray(pg.send_idx)
        send_mask = np.asarray(pg.send_mask)
        recv_pos = np.asarray(pg.recv_pos)
        for p in range(P):
            for q in range(P):
                m = send_mask[p, q] > 0
                for s_loc, r_loc in zip(send_idx[p, q][m], recv_pos[q, p][m]):
                    self._rep[p].setdefault(int(s_loc), []).append((q, int(r_loc)))

    # ------------------------------------------------------------- mutation
    def add_out_edge(self, p: int, src_local: int, dst_local: int) -> None:
        self._extra_out[p].setdefault(int(src_local), []).append(int(dst_local))

    def add_replica(self, owner: int, row: int, peer: int, peer_row: int) -> None:
        self._rep[owner].setdefault(int(row), []).append((peer, int(peer_row)))

    def remove_out_edge(self, p: int, src_local: int, dst_local: int) -> None:
        """Record the removal of local edge src -> dst on partition p.

        A dynamically added edge is deleted in place; a static-CSC edge is
        only logged (stale until the next compaction, which is safe — it
        over-propagates).  Hitting ``compact_after`` pending removals
        triggers an automatic compaction of that partition's shard.
        """
        src_local, dst_local = int(src_local), int(dst_local)
        extra = self._extra_out[p].get(src_local)
        if extra is not None and dst_local in extra:
            extra.remove(dst_local)
            if not extra:
                del self._extra_out[p][src_local]
            return
        self._removed[p].add((src_local, dst_local))
        if len(self._removed[p]) >= self.compact_after:
            self._compact(p)

    def compact(self, p: int | None = None) -> None:
        """Force-rebuild the CSC shard(s) so every recorded removal and
        dynamic addition is folded into the static adjacency."""
        for q in ([p] if p is not None else range(self.num_parts)):
            if self._removed[q] or self._extra_out[q]:
                self._compact(int(q))

    def _compact(self, p: int) -> None:
        ptr, dst = self._csc[p]
        n_static = len(ptr) - 1
        src = np.repeat(np.arange(n_static, dtype=np.int64), np.diff(ptr))
        removed = self._removed[p]
        if removed:
            keep = np.fromiter(((int(s), int(d)) not in removed
                                for s, d in zip(src, dst)), bool, src.size)
            src, dst = src[keep], dst[keep]
        ex_src: list[int] = []
        ex_dst: list[int] = []
        for s, lst in self._extra_out[p].items():
            ex_src.extend([int(s)] * len(lst))
            ex_dst.extend(int(d) for d in lst)
        if ex_src:
            src = np.concatenate([src, np.asarray(ex_src, np.int64)])
            dst = np.concatenate([dst, np.asarray(ex_dst, np.int64)])
        n_rows = max(n_static, int(src.max()) + 1 if src.size else 0)
        counts = np.bincount(src, minlength=n_rows)
        new_ptr = np.zeros(n_rows + 1, np.int64)
        np.cumsum(counts, out=new_ptr[1:])
        order = np.argsort(src, kind="stable")
        self._csc[p] = (new_ptr, dst[order])
        self._extra_out[p] = {}
        self._removed[p].clear()
        self.compactions += 1

    # -------------------------------------------------------------- queries
    def replicas(self, p: int, rows: np.ndarray):
        """(peer, peer_row, owner_row) triples for every replica of ``rows``."""
        rep = self._rep[p]
        for r in np.asarray(rows):
            for q, qrow in rep.get(int(r), ()):
                yield q, qrow, int(r)

    def out_rows(self, p: int, rows: np.ndarray) -> np.ndarray:
        """Local out-neighbours (always owned rows: edges target dst-owned)."""
        ptr, dst = self._csc[p]
        extra = self._extra_out[p]
        segs = []
        n_static = len(ptr) - 1
        for r in np.asarray(rows):
            r = int(r)
            if r < n_static:
                segs.append(dst[ptr[r]:ptr[r + 1]])
            if r in extra:
                segs.append(np.asarray(extra[r], np.int64))
        if not segs:
            return np.empty(0, np.int64)
        return np.unique(np.concatenate(segs))

    def propagate(self, dirty_h0: dict[int, np.ndarray],
                  edge_seeds: dict[int, np.ndarray],
                  num_layers: int) -> list[dict[int, np.ndarray]]:
        """``plans[l-1][p]`` = sorted owned rows partition p recomputes at
        layer l (1-based), given local rows (owned or halo) whose input
        features changed and owned rows whose in-edge lists changed."""
        P = self.num_parts
        empty = np.empty(0, np.int64)
        cur = {p: np.unique(np.asarray(dirty_h0.get(p, empty), np.int64))
               for p in range(P)}
        plans: list[dict[int, np.ndarray]] = []
        for l in range(1, num_layers + 1):
            rec = {}
            for p in range(P):
                parts = [self.out_rows(p, cur[p]),
                         cur[p][cur[p] < self.n_own[p]]]
                if l == 1:
                    parts.append(np.asarray(
                        sorted(edge_seeds.get(p, ())), np.int64))
                rec[p] = np.unique(np.concatenate(parts)) if parts else empty
            plans.append(rec)
            if l < num_layers:
                nxt = {p: [rec[p]] for p in range(P)}
                for p in range(P):
                    for q, qrow, _ in self.replicas(p, rec[p]):
                        nxt[q].append(np.asarray([qrow], np.int64))
                cur = {p: np.unique(np.concatenate(nxt[p])) for p in range(P)}
        return plans
