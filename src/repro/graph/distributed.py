"""Distributed graph storage + halo exchange — DistDGL's communication
pattern rendered as TPU-native SPMD collectives.

Each partition owns a contiguous local index space:

    [0, n_own)            owned nodes (this shard computes their embeddings)
    [n_own, n_own+n_halo) halo slots (1-hop remote neighbours, received)
    [n_local, maxN)       padding (+ one trash row at maxN-1)

Per layer, boundary embeddings are exchanged with a single
``jax.lax.all_to_all`` over the data axis using *precomputed, padded* send
lists (DistDGL's dynamic RPC → static collective; DESIGN.md §2).  The bytes
on the wire are exactly ``2 · Σ_p halo_p · D · dtype`` per forward — i.e.
proportional to the edge-cut that EW partitioning minimises, which is how
the paper's comm saving shows up on a TPU mesh.

Everything is padded to identical shapes across partitions so the whole
structure stacks into (P, ...) arrays sharded over the data axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph
from .sage import GraphSAGE, SAGEParams

__all__ = ["PartitionedGraph", "build_partitioned_graph", "make_distributed_forward",
           "make_ref_mean_agg", "make_pallas_mean_agg"]


@dataclass
class PartitionedGraph:
    """Stacked, padded per-partition arrays (leading axis = partition)."""

    num_parts: int
    n_own: np.ndarray          # (P,) owned-node counts
    n_halo: np.ndarray         # (P,) halo counts
    max_nodes: int             # padded local size (incl. trash row)
    features: np.ndarray       # (P, maxN, D)   halo+pad rows zero
    labels: np.ndarray         # (P, maxN)      -1 on non-owned
    edge_src: np.ndarray       # (P, maxE) local ids  (pad -> trash row)
    edge_dst: np.ndarray       # (P, maxE) local ids  (pad -> trash row)
    edge_mask: np.ndarray      # (P, maxE) float32
    send_idx: np.ndarray       # (P, P, maxS) local owned ids to send to q
    send_mask: np.ndarray      # (P, P, maxS)
    recv_pos: np.ndarray       # (P, P, maxS) local halo slot for recv from q
    global_ids: np.ndarray     # (P, maxN) global node id (-1 pad)
    train_mask: np.ndarray     # (P, maxN) bool, owned train nodes
    val_mask: np.ndarray       # (P, maxN)
    test_mask: np.ndarray      # (P, maxN)

    @property
    def halo_bytes_per_layer(self) -> int:
        d = self.features.shape[-1]
        return int(self.n_halo.sum()) * d * self.features.dtype.itemsize

    def summary(self) -> str:
        return (
            f"P={self.num_parts} own={self.n_own.tolist()} halo={self.n_halo.tolist()} "
            f"maxN={self.max_nodes} maxE={self.edge_src.shape[1]} "
            f"halo_bytes/layer={self.halo_bytes_per_layer}"
        )


def build_partitioned_graph(
    graph: CSRGraph, parts: np.ndarray, num_parts: int
) -> PartitionedGraph:
    parts = np.asarray(parts)
    n = graph.num_nodes
    owned = [np.flatnonzero(parts == p) for p in range(num_parts)]

    # 1-hop halo: in-neighbour sources of owned nodes living elsewhere
    halos, local_edges = [], []
    for p in range(num_parts):
        own = owned[p]
        src_all, dst_all = [], []
        for v in own:
            nbrs = graph.neighbors(v)
            src_all.append(nbrs)
            dst_all.append(np.full(len(nbrs), v))
        src = np.concatenate(src_all) if src_all else np.zeros(0, np.int64)
        dst = np.concatenate(dst_all) if dst_all else np.zeros(0, np.int64)
        halo = np.unique(src[parts[src] != p])
        halos.append(halo)
        local_edges.append((src, dst))

    n_own = np.array([len(o) for o in owned])
    n_halo = np.array([len(h) for h in halos])
    max_nodes = int((n_own + n_halo).max()) + 1          # +1 trash row
    max_edges = max(1, int(max(len(e[0]) for e in local_edges)))

    d = graph.feature_dim
    P = num_parts
    feats = np.zeros((P, max_nodes, d), dtype=np.float32)
    labels = np.full((P, max_nodes), -1, dtype=np.int64)
    gids = np.full((P, max_nodes), -1, dtype=np.int64)
    e_src = np.full((P, max_edges), max_nodes - 1, dtype=np.int32)
    e_dst = np.full((P, max_edges), max_nodes - 1, dtype=np.int32)
    e_msk = np.zeros((P, max_edges), dtype=np.float32)
    tr_m = np.zeros((P, max_nodes), dtype=bool)
    va_m = np.zeros((P, max_nodes), dtype=bool)
    te_m = np.zeros((P, max_nodes), dtype=bool)

    # global -> (partition, local id)
    g2l = np.full(n, -1, dtype=np.int64)
    for p in range(P):
        g2l[owned[p]] = np.arange(n_own[p])

    halo_l = [dict() for _ in range(P)]  # global id -> halo slot
    for p in range(P):
        for i, h in enumerate(halos[p]):
            halo_l[p][int(h)] = n_own[p] + i

    tr, va, te = set(graph.train_idx), set(graph.val_idx), set(graph.test_idx)
    for p in range(P):
        own = owned[p]
        feats[p, : n_own[p]] = graph.features[own]
        labels[p, : n_own[p]] = graph.labels[own]
        gids[p, : n_own[p]] = own
        if len(halos[p]):
            # halo features start zero; they arrive via exchange
            gids[p, n_own[p] : n_own[p] + n_halo[p]] = halos[p]
        for j, v in enumerate(own):
            tr_m[p, j] = int(v) in tr
            va_m[p, j] = int(v) in va
            te_m[p, j] = int(v) in te

        src, dst = local_edges[p]
        loc_src = np.empty(len(src), dtype=np.int32)
        for i, s in enumerate(src):
            loc_src[i] = g2l[s] if parts[s] == p else halo_l[p][int(s)]
        loc_dst = g2l[dst].astype(np.int32)
        e_src[p, : len(src)] = loc_src
        e_dst[p, : len(dst)] = loc_dst
        e_msk[p, : len(src)] = 1.0

    # send lists: p sends owned node g to q whenever g is in q's halo
    send_lists = [[[] for _ in range(P)] for _ in range(P)]
    recv_lists = [[[] for _ in range(P)] for _ in range(P)]
    for q in range(P):
        for g in halos[q]:
            p = int(parts[g])
            send_lists[p][q].append(int(g2l[g]))
            recv_lists[q][p].append(halo_l[q][int(g)])
    max_s = max(1, max(len(send_lists[p][q]) for p in range(P) for q in range(P)))
    s_idx = np.zeros((P, P, max_s), dtype=np.int32)
    s_msk = np.zeros((P, P, max_s), dtype=np.float32)
    r_pos = np.full((P, P, max_s), max_nodes - 1, dtype=np.int32)  # pad -> trash
    for p in range(P):
        for q in range(P):
            ks = len(send_lists[p][q])
            if ks:
                s_idx[p, q, :ks] = send_lists[p][q]
                s_msk[p, q, :ks] = 1.0
            kr = len(recv_lists[p][q])  # aligned with send_lists[q][p]
            if kr:
                r_pos[p, q, :kr] = recv_lists[p][q]

    return PartitionedGraph(
        num_parts=P, n_own=n_own, n_halo=n_halo, max_nodes=max_nodes,
        features=feats, labels=labels, edge_src=e_src, edge_dst=e_dst,
        edge_mask=e_msk, send_idx=s_idx, send_mask=s_msk, recv_pos=r_pos,
        global_ids=gids, train_mask=tr_m, val_mask=va_m, test_mask=te_m,
    )


# ---------------------------------------------------------------------------
# SPMD forward with per-layer halo exchange
# ---------------------------------------------------------------------------

def _halo_exchange(h, send_idx, send_mask, recv_pos, axis_name: str):
    """One all_to_all round: ship owned boundary rows, land them in halo
    slots.  h: (maxN, D); send_idx/mask/recv_pos: (P, maxS[, 1])."""
    out = h[send_idx] * send_mask[..., None]          # (P, maxS, D)
    recv = jax.lax.all_to_all(out, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    # recv[q] = rows partition q sent me; scatter into my halo slots
    flat_pos = recv_pos.reshape(-1)
    flat_val = recv.reshape(-1, h.shape[-1])
    return h.at[flat_pos].set(flat_val.astype(h.dtype))


def make_ref_mean_agg(max_nodes: int):
    """jnp segment-op mean aggregation over a shard's local edge list — the
    interpret-mode / differentiable fallback (same math as kernels/ref.py,
    specialised to the padded shard layout)."""

    def mean_agg(h, shard):
        msg = h[shard["edge_src"]] * shard["edge_mask"][:, None].astype(h.dtype)
        s = jax.ops.segment_sum(msg, shard["edge_dst"], num_segments=max_nodes)
        deg = jax.ops.segment_sum(shard["edge_mask"].astype(h.dtype),
                                  shard["edge_dst"], num_segments=max_nodes)
        return s / jnp.maximum(deg, 1.0)[:, None]

    return mean_agg


def make_pallas_mean_agg(max_nodes: int, *, interpret: bool = True):
    """Pallas-kernel mean aggregation: the GNN hot-spot on the MXU.

    Reads the blocked-CSR structure (``blk_src``/``blk_dst``/``blk_mask``/
    ``blk_deg``, built by ``repro.engine.stacking.build_stacked_blocks``)
    from the shard, gathers messages in XLA and reduces them with
    ``kernels.segment_agg.segment_agg_blocks``.  Forward-only (no VJP): the
    engine uses it for full-graph inference; training gradients flow through
    the sampled minibatch path.
    """
    from ..kernels.segment_agg import segment_agg_blocks

    def mean_agg(h, shard):
        src = shard["blk_src"].reshape(-1)            # (nb*BE,) local ids
        msgs = h[src]                                  # XLA gather
        out = segment_agg_blocks(msgs, shard["blk_dst"], shard["blk_mask"],
                                 shard["blk_deg"], mean=True,
                                 interpret=interpret)
        return out[:max_nodes].astype(h.dtype)

    return mean_agg


def make_distributed_forward(model: GraphSAGE, pg_meta: dict,
                             axis_name: str = "data", agg=None):
    """Build the per-shard 2-layer forward with halo exchange.

    Returns ``fwd(params, shard) -> logits`` where ``shard`` is the
    per-partition slice of the stacked PartitionedGraph arrays; call it
    inside ``shard_map`` over a partition mesh, or under
    ``vmap(..., axis_name=...)`` for the single-device stacked fallback
    (jax batches ``all_to_all`` across the vmapped axis with the same
    transpose semantics — see DESIGN.md §3).

    ``agg(h, shard) -> (max_nodes, D)`` selects the aggregation backend;
    default is the jnp segment-op reference, the SPMD engine passes
    :func:`make_pallas_mean_agg` to put the Pallas kernel on the hot path.
    """
    max_nodes = pg_meta["max_nodes"]
    mean_agg = agg if agg is not None else make_ref_mean_agg(max_nodes)

    def fwd(params: SAGEParams, shard: dict) -> jnp.ndarray:
        h = shard["features"]
        h = _halo_exchange(h, shard["send_idx"], shard["send_mask"],
                           shard["recv_pos"], axis_name)
        agg0 = mean_agg(h, shard)
        h1 = jax.nn.relu(h @ params.layer1.w_self + agg0 @ params.layer1.w_neigh
                         + params.layer1.b)
        h1 = _halo_exchange(h1, shard["send_idx"], shard["send_mask"],
                            shard["recv_pos"], axis_name)
        agg1 = mean_agg(h1, shard)
        logits = (h1 @ params.layer2.w_self + agg1 @ params.layer2.w_neigh
                  + params.layer2.b)
        return logits

    return fwd
