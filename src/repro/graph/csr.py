"""CSR graph container used across the GNN substrate (DGL-format analogue).

Row ``v`` of the CSR stores the *in*-neighbourhood N(v) — the message
sources for Eq. 1 — matching DGL's convention for message passing.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import scipy.sparse as sp

__all__ = ["CSRGraph"]


@dataclass
class CSRGraph:
    indptr: np.ndarray           # (n+1,)
    indices: np.ndarray          # (nnz,) in-neighbour ids
    features: np.ndarray         # (n, d) float32
    labels: np.ndarray           # (n,) int64, -1 = unlabelled
    train_idx: np.ndarray
    val_idx: np.ndarray
    test_idx: np.ndarray
    num_classes: int
    name: str = "graph"

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    @property
    def feature_dim(self) -> int:
        return self.features.shape[1]

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def adjacency(self) -> sp.csr_matrix:
        n = self.num_nodes
        return sp.csr_matrix(
            (np.ones(self.num_edges, dtype=np.float64), self.indices, self.indptr),
            shape=(n, n),
        )

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def induced_subgraph(self, nodes: np.ndarray) -> tuple["CSRGraph", np.ndarray]:
        """Subgraph on ``nodes`` (global ids).  Returns (subgraph, nodes) with
        edges relabelled to local ids; split sets intersected and relabelled."""
        nodes = np.asarray(nodes)
        n = self.num_nodes
        g2l = np.full(n, -1, dtype=np.int64)
        g2l[nodes] = np.arange(len(nodes))
        new_indptr = [0]
        new_indices = []
        for v in nodes:
            nbrs = g2l[self.neighbors(v)]
            nbrs = nbrs[nbrs >= 0]
            new_indices.append(nbrs)
            new_indptr.append(new_indptr[-1] + len(nbrs))
        indices = (
            np.concatenate(new_indices) if new_indices else np.zeros(0, dtype=np.int64)
        )

        def remap(idx: np.ndarray) -> np.ndarray:
            m = g2l[idx]
            return m[m >= 0]

        return (
            CSRGraph(
                indptr=np.asarray(new_indptr, dtype=np.int64),
                indices=indices.astype(np.int64),
                features=self.features[nodes],
                labels=self.labels[nodes],
                train_idx=remap(self.train_idx),
                val_idx=remap(self.val_idx),
                test_idx=remap(self.test_idx),
                num_classes=self.num_classes,
                name=f"{self.name}-sub",
            ),
            nodes,
        )

    def summary(self) -> str:
        return (
            f"{self.name}: |V|={self.num_nodes} |E|={self.num_edges} "
            f"d={self.feature_dim} classes={self.num_classes} "
            f"train/val/test={len(self.train_idx)}/{len(self.val_idx)}/{len(self.test_idx)}"
        )
