"""Synthetic graph benchmarks engineered to exhibit the paper's pathologies.

The paper's datasets (Reddit, OGBN-Products, OGBN-Papers100M, Flickr, Yelp)
are not downloadable offline, so we generate degree-corrected stochastic
block-model graphs with:

  · Zipf class imbalance (Fig. 1b — OGBN-Products' long tail),
  · homophily (same-label nodes connect preferentially — what makes EW work),
  · feature–label correlation (class prototypes + noise — what Alg. 1 taps),
  · power-law degrees (hub structure of Reddit),
  · optional unlabelled majority (OGBN-Papers' ~98% unlabelled),
  · optional out-of-distribution test split (OGBN-Products' 8/2/90 split).

``BENCHMARKS`` maps small-scale stand-ins for each paper dataset; every
experiment records which stand-in it ran on.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .csr import CSRGraph

__all__ = ["SyntheticSpec", "make_benchmark", "BENCHMARKS"]


@dataclass(frozen=True)
class SyntheticSpec:
    name: str
    num_nodes: int
    avg_degree: float
    num_classes: int
    feature_dim: int
    class_zipf: float = 1.2        # Zipf exponent of class sizes (0 = uniform)
    homophily: float = 0.8         # P(edge endpoint same class)
    feature_noise: float = 0.5     # noise std around the class prototype
    degree_alpha: float = 0.8      # power-law-ish degree propensity exponent
    train_frac: float = 0.5
    val_frac: float = 0.2
    labelled_frac: float = 1.0     # OGBN-Papers ≈ 0.02
    ood_test: bool = False         # skew test split toward tail classes
    seed: int = 0


def _class_sizes(spec: SyntheticSpec, rng: np.random.Generator) -> np.ndarray:
    ranks = np.arange(1, spec.num_classes + 1, dtype=np.float64)
    p = ranks ** (-spec.class_zipf)
    return p / p.sum()


def make_benchmark(spec: SyntheticSpec) -> CSRGraph:
    rng = np.random.default_rng([spec.seed, 0x5EED])
    n, k = spec.num_nodes, spec.num_classes

    class_p = _class_sizes(spec, rng)
    labels = rng.choice(k, size=n, p=class_p).astype(np.int64)

    # class prototypes on a scaled simplex + noise -> feature-label correlation
    protos = rng.normal(0.0, 1.0, size=(k, spec.feature_dim))
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    feats = protos[labels] + rng.normal(0.0, spec.feature_noise, (n, spec.feature_dim))
    feats = feats.astype(np.float32)

    # degree-corrected SBM edges: hub propensity ~ power law
    prop = (1.0 / (np.arange(n) + 1.0)) ** spec.degree_alpha
    rng.shuffle(prop)
    num_edges = int(n * spec.avg_degree)

    # class-bucketed node lists with propensity weights for homophilous picks
    by_class = [np.flatnonzero(labels == c) for c in range(k)]
    w_by_class = [prop[idx] / prop[idx].sum() for idx in by_class]
    w_all = prop / prop.sum()

    src = rng.choice(n, size=num_edges, p=w_all)
    homo = rng.random(num_edges) < spec.homophily
    dst = np.empty(num_edges, dtype=np.int64)
    # homophilous endpoints: same class as src; others: global propensity draw
    for c in range(k):
        m = homo & (labels[src] == c)
        cnt = int(m.sum())
        if cnt and len(by_class[c]):
            dst[m] = rng.choice(by_class[c], size=cnt, p=w_by_class[c])
        elif cnt:
            dst[m] = rng.choice(n, size=cnt, p=w_all)
    nh = ~homo
    dst[nh] = rng.choice(n, size=int(nh.sum()), p=w_all)
    keep = src != dst
    src, dst = src[keep], dst[keep]

    # symmetrize + dedupe, build CSR of in-neighbours
    import scipy.sparse as sp

    a = sp.csr_matrix(
        (np.ones(2 * len(src)), (np.concatenate([src, dst]), np.concatenate([dst, src]))),
        shape=(n, n),
    )
    a.data[:] = 1.0
    a.setdiag(0)
    a.eliminate_zeros()

    # splits
    perm = rng.permutation(n)
    labelled = perm[: int(n * spec.labelled_frac)]
    final_labels = np.full(n, -1, dtype=np.int64)
    final_labels[labelled] = labels[labelled]

    if spec.ood_test:
        # OGBN-Products-style OOD: train on the HEAD (popular classes),
        # test skews toward the tail — descending class popularity with
        # noise so the split is shifted, not disjoint
        head_score = class_p[labels[labelled]]
        noise = rng.random(len(labelled)) * float(class_p.max())
        order = labelled[np.argsort(-(head_score + noise))]
    else:
        order = labelled
    n_lab = len(labelled)
    n_tr = int(n_lab * spec.train_frac)
    n_va = int(n_lab * spec.val_frac)
    train_idx = order[:n_tr]
    val_idx = order[n_tr : n_tr + n_va]
    test_idx = order[n_tr + n_va :]

    return CSRGraph(
        indptr=a.indptr.astype(np.int64),
        indices=a.indices.astype(np.int64),
        features=feats,
        labels=final_labels,
        train_idx=np.sort(train_idx),
        val_idx=np.sort(val_idx),
        test_idx=np.sort(test_idx),
        num_classes=k,
        name=spec.name,
    )


# Small-scale stand-ins for the paper's five benchmarks (Table I), scaled to
# CPU-feasible sizes while keeping each dataset's signature pathology.
BENCHMARKS: dict[str, SyntheticSpec] = {
    # Flickr: 7 classes, noisy labels -> high feature noise, low homophily
    "flickr-s": SyntheticSpec(
        name="flickr-s", num_nodes=6_000, avg_degree=10, num_classes=7,
        feature_dim=64, class_zipf=0.8, homophily=0.55, feature_noise=1.0, seed=1,
    ),
    # Yelp: many classes (100 -> 32 here), moderate degree
    "yelp-s": SyntheticSpec(
        name="yelp-s", num_nodes=12_000, avg_degree=20, num_classes=32,
        feature_dim=64, class_zipf=1.0, homophily=0.7, feature_noise=0.7, seed=2,
    ),
    # Reddit: very high degree, strong homophily, 41 classes
    "reddit-s": SyntheticSpec(
        name="reddit-s", num_nodes=10_000, avg_degree=60, num_classes=16,
        feature_dim=96, class_zipf=1.1, homophily=0.85, feature_noise=0.4,
        train_frac=0.66, val_frac=0.10, seed=3,
    ),
    # OGBN-Products: heavy class imbalance + OOD test split (8/2/90)
    "products-s": SyntheticSpec(
        name="products-s", num_nodes=20_000, avg_degree=25, num_classes=24,
        feature_dim=64, class_zipf=1.6, homophily=0.8, feature_noise=0.5,
        train_frac=0.08, val_frac=0.02, ood_test=True, seed=4,
    ),
    # OGBN-Papers: mostly unlabelled
    "papers-s": SyntheticSpec(
        name="papers-s", num_nodes=30_000, avg_degree=15, num_classes=32,
        feature_dim=64, class_zipf=1.4, homophily=0.75, feature_noise=0.6,
        labelled_frac=0.10, train_frac=0.78, val_frac=0.08, seed=5,
    ),
    # tiny graph for unit tests
    "tiny": SyntheticSpec(
        name="tiny", num_nodes=600, avg_degree=8, num_classes=5,
        feature_dim=16, class_zipf=1.2, homophily=0.8, feature_noise=0.4, seed=6,
    ),
    # medium single benchmark for scaling tables
    "products-m": SyntheticSpec(
        name="products-m", num_nodes=60_000, avg_degree=25, num_classes=24,
        feature_dim=64, class_zipf=1.6, homophily=0.8, feature_noise=0.5,
        train_frac=0.12, val_frac=0.03, ood_test=True, seed=7,
    ),
    # wide-feature benchmark for the two-tier feature store: the stacked
    # (P, maxN, D) feature plane is the dominant array, so a feat_budget_mb
    # between the streamed feat-store peak and the all-resident footprint
    # demonstrates a graph that only trains with --feat-store (DESIGN.md §12)
    "featstore-xl": SyntheticSpec(
        name="featstore-xl", num_nodes=16_000, avg_degree=10, num_classes=16,
        feature_dim=96, class_zipf=1.2, homophily=0.75, feature_noise=0.5,
        train_frac=0.20, val_frac=0.05, seed=8,
    ),
}
