"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh", "data_axes_of", "model_axis_of"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axis_of(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
