"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

``make_mesh_compat`` papers over the ``jax.make_mesh`` signature drift:
newer jax takes ``axis_types=(AxisType.Auto, ...)``, jax 0.4.x predates
``jax.sharding.AxisType`` entirely.  All mesh construction in this repo
(production, tests, the SPMD engine) goes through it.
"""
from __future__ import annotations

import jax

__all__ = [
    "make_mesh_compat",
    "make_production_mesh",
    "make_partition_mesh",
    "data_axes_of",
    "model_axis_of",
]


def make_mesh_compat(shape: tuple[int, ...], axes: tuple[str, ...],
                     devices=None):
    """Version-portable ``jax.make_mesh`` (auto axis types where supported)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = {} if devices is None else {"devices": devices}
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes),
                                 **kwargs)
        except TypeError:  # make_mesh without axis_types kwarg
            pass
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_partition_mesh(num_parts: int, axis_name: str = "parts"):
    """1-D mesh over ``num_parts`` devices for the SPMD engine's shard_map
    path.  Requires at least ``num_parts`` visible devices (e.g. via
    ``--xla_force_host_platform_device_count``); callers should fall back to
    the stacked vmap path otherwise."""
    devices = jax.devices()
    if len(devices) < num_parts:
        raise ValueError(
            f"need {num_parts} devices for the partition mesh, "
            f"have {len(devices)}"
        )
    return make_mesh_compat((num_parts,), (axis_name,),
                            devices=devices[:num_parts])


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axis_of(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
