"""Serving driver (CLI): batched generation with any zoo architecture, or
the partitioned GNN inference service.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --batch 4 --prompt-len 32 --new-tokens 16 [--swa]

    PYTHONPATH=src python -m repro.launch.serve --gnn --dataset tiny \
        --parts 4 --ticks 20 --updates-per-tick 4 --queries-per-tick 16 \
        [--checkpoint results/ckpt.msgpack]

On CPU the transformer path runs the REDUCED config; on TPU hardware the
same ServeEngine steps are what the decode dry-run shapes lower for the
production mesh.  The GNN path precomputes per-partition layer embeddings
from an ``SPMDEngine`` export, then serves a synthetic request stream of
feature updates + logit queries with incremental recomputation.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Transformer
from repro.serve import ServeEngine


def gnn_main(args) -> int:
    from repro.core import GPHyperParams, partition_graph
    from repro.engine import EngineConfig, SPMDEngine
    from repro.graph import (BENCHMARKS, GraphSAGE, build_partitioned_graph,
                             make_benchmark)
    from repro.serve import GNNServingEngine
    from repro.train.optim import AdamW

    g = make_benchmark(BENCHMARKS[args.dataset])
    r = partition_graph(g.indptr, g.indices, g.features, g.labels,
                        args.parts, method="ew", seed=args.seed)
    pg = build_partitioned_graph(g, r.parts, args.parts)
    model = GraphSAGE(feature_dim=g.feature_dim, hidden_dim=args.hidden,
                      num_classes=g.num_classes)
    eng = SPMDEngine(model, model.make_loss_fn(), AdamW(lr=1e-3), pg,
                     GPHyperParams(),
                     EngineConfig(mode="stacked", use_pallas_agg=False))
    if args.checkpoint:
        srv = GNNServingEngine.from_checkpoint(args.checkpoint, eng, pg)
    else:
        srv = GNNServingEngine.from_engine(eng, pg, model.init(args.seed))
    print(f"{g.name}: {g.num_nodes} nodes, P={args.parts}, "
          f"{model.num_layers}-layer SAGE, store ready "
          f"(halo rows live in recv-slot geometry)")

    if args.fail_partition >= 0:
        from repro.robustness import FaultPlan
        fail_tick = max(1, args.fail_at_tick)
        srv.set_fault_plan(FaultPlan(
            serve_fail={fail_tick: (args.fail_partition,)},
            serve_recover={fail_tick + args.recover_after_ticks:
                           (args.fail_partition,)}))
        print(f"fault plan: partition {args.fail_partition} fails at tick "
              f"{fail_tick}, recovers after {args.recover_after_ticks} ticks")

    rng = np.random.default_rng(args.seed)
    lat = []
    stale_answers = 0
    t_start = time.time()
    for _ in range(args.ticks):
        for v in rng.choice(g.num_nodes, args.updates_per_tick,
                            replace=False):
            srv.update_features(int(v), rng.normal(
                0, 1, g.feature_dim).astype(np.float32))
        srv.submit(rng.choice(g.num_nodes, args.queries_per_tick,
                              replace=False))
        t0 = time.perf_counter()
        _, tick_stats = srv.tick()
        lat.append(time.perf_counter() - t0)
        stale_answers += len(tick_stats.get("staleness", {}))
    wall = time.time() - t_start
    qps = args.ticks * args.queries_per_tick / wall
    p50, p99 = np.percentile(lat, [50, 99])
    s = srv.stats
    print(f"{args.ticks} ticks x ({args.updates_per_tick} updates + "
          f"{args.queries_per_tick} queries): p50 {p50 * 1e3:.1f} ms, "
          f"p99 {p99 * 1e3:.1f} ms, {qps:.0f} queries/s")
    print(f"rows recomputed {s['rows_recomputed']}, gather calls "
          f"{s['gather_calls']}, halo rows grown {s['halo_rows_grown']}")
    if s["failovers"] or s["updates_queued"]:
        print(f"degraded mode: {s['failovers']} failover(s), "
              f"{s['degraded_queries']} degraded queries "
              f"({stale_answers} stale answers), {s['updates_queued']} "
              f"updates queued, {s['replay_attempts']} replay attempts, "
              f"{s['replayed']} replayed after {s['recoveries']} "
              f"recovery(ies); final health {srv.health}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gnn", action="store_true",
                    help="serve the partitioned GNN instead of a "
                         "transformer")
    ap.add_argument("--dataset", default="tiny")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--ticks", type=int, default=20)
    ap.add_argument("--updates-per-tick", type=int, default=4)
    ap.add_argument("--queries-per-tick", type=int, default=16)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--fail-partition", type=int, default=-1,
                    help="GNN degraded-mode demo: fail this partition "
                         "mid-stream (queries keep answering from its "
                         "frozen store, updates queue)")
    ap.add_argument("--fail-at-tick", type=int, default=5)
    ap.add_argument("--recover-after-ticks", type=int, default=8)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--swa", action="store_true",
                    help="rolling sliding-window cache serving variant")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.gnn:
        return gnn_main(args)

    cfg = get_config(args.arch, "swa" if args.swa else None).reduced()
    model = Transformer(cfg)
    params = model.init(args.seed)
    rng = np.random.default_rng(args.seed)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.prefix_tokens:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.prefix_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)

    rolling = args.swa and cfg.sliding_window is not None
    cache = (cfg.sliding_window if rolling
             else args.prompt_len + args.new_tokens + 4)
    engine = ServeEngine(model, params, cache_size=cache, rolling=rolling)
    t0 = time.time()
    out = engine.generate(batch, max_new_tokens=args.new_tokens,
                          temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    tps = out.size / dt
    print(f"{cfg.name}: {out.shape[0]} seqs x {out.shape[1]} tokens "
          f"in {dt:.2f}s ({tps:.1f} tok/s, reduced config on CPU)")
    print(out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
