"""Serving driver (CLI): batched generation with any zoo architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --batch 4 --prompt-len 32 --new-tokens 16 [--swa]

On CPU this runs the REDUCED config; on TPU hardware the same ServeEngine
steps are what the decode dry-run shapes lower for the production mesh.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Transformer
from repro.serve import ServeEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--swa", action="store_true",
                    help="rolling sliding-window cache serving variant")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, "swa" if args.swa else None).reduced()
    model = Transformer(cfg)
    params = model.init(args.seed)
    rng = np.random.default_rng(args.seed)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.prefix_tokens:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.prefix_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)

    rolling = args.swa and cfg.sliding_window is not None
    cache = (cfg.sliding_window if rolling
             else args.prompt_len + args.new_tokens + 4)
    engine = ServeEngine(model, params, cache_size=cache, rolling=rolling)
    t0 = time.time()
    out = engine.generate(batch, max_new_tokens=args.new_tokens,
                          temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    tps = out.size / dt
    print(f"{cfg.name}: {out.shape[0]} seqs x {out.shape[1]} tokens "
          f"in {dt:.2f}s ({tps:.1f} tok/s, reduced config on CPU)")
    print(out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
