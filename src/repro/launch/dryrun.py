import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
#
# Multi-pod dry-run: lower + compile every (arch × input shape) on the
# production meshes, print memory/cost analysis, dump roofline terms to JSON.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
#         --shape train_4k [--multi-pod] [--phase generalize|personalize] \
#         [--variant base|swa] [--out results.json]
#
# Exit code 0 = the combination lowers, compiles and fits; anything else is a
# bug in the distribution config (sharding mismatch, OOM at compile, ...).

import argparse
import json
import sys
import time
from dataclasses import replace

import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.roofline import analyze_compiled, collective_bytes_from_hlo


def active_params(cfg) -> tuple[float, float]:
    """(total_params, active_params) — active excludes non-routed experts."""
    from repro.models.transformer import Transformer
    m = Transformer(cfg)
    shapes = jax.eval_shape(lambda: m.init(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = active = 0.0
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        n = float(np.prod(leaf.shape))
        total += n
        if "expert" in name and cfg.num_experts:
            active += n * cfg.top_k / cfg.num_experts
        else:
            active += n
    return total, active


def long_500k_supported(cfg, variant: str | None) -> bool:
    return cfg.supports_long_context or variant == "swa"


def _measure_true_cost(cfg, shape, mesh, phase: str, step_kw: dict | None = None) -> dict:
    """XLA counts while bodies once, so the full artifact's cost_analysis
    undercounts scans (layers × chunks).  Compile fully-UNROLLED R=1 and R=2
    variants and extrapolate: cost(R) = c1 + (R-1)·(c2-c1).  Exact for the
    per-layer work; the embed/head/loss base is in c1."""
    meas = []
    for r in (1, 2):
        kw = dict(num_repeats=r, scan_unroll=True)
        if cfg.encoder_layers:
            kw["encoder_layers"] = r
        mcfg = replace(cfg, **kw)
        built = build_step(mcfg, shape, mesh, **(step_kw or {"phase": phase}))
        # opt level 0: ~25% faster compiles; FLOP counts are identical
        # (verified) — only fusion-dependent bytes differ slightly
        compiled = built.lower().compile(
            compiler_options={"xla_backend_optimization_level": 0})
        cost = compiled.cost_analysis()
        coll = collective_bytes_from_hlo(compiled.as_text())
        meas.append((float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)), coll))
    r_eff = cfg.num_repeats
    # clamp: per-layer diffs can be slightly negative at batch=1 decode where
    # the base dominates and fusion choices differ between R=1/R=2 — the
    # extrapolation must never fall below the R=1 measurement itself
    f = max(meas[0][0], meas[0][0] + (r_eff - 1) * (meas[1][0] - meas[0][0]))
    b = max(meas[0][1], meas[0][1] + (r_eff - 1) * (meas[1][1] - meas[0][1]))
    kinds = set(meas[0][2]) | set(meas[1][2])
    coll = {k: int(meas[0][2].get(k, 0)
                   + (r_eff - 1) * (meas[1][2].get(k, 0) - meas[0][2].get(k, 0)))
            for k in kinds}
    coll = {k: max(meas[0][2].get(k, 0), v) for k, v in coll.items()}
    return {"flops": f, "bytes": b, "coll": coll}


def run_one(arch: str, shape_name: str, *, multi_pod: bool, variant: str | None,
            phase: str = "generalize", measure: bool = True,
            overrides: dict | None = None, seq_shard_residual: bool = True,
            constrain_attn: bool = True, tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    # long_500k policy (DESIGN.md): full-attention archs need the swa variant
    eff_variant = variant
    base_cfg = get_config(arch)
    if shape_name == "long_500k" and not base_cfg.supports_long_context:
        if variant not in ("swa",):
            return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                    "status": "skipped",
                    "reason": "full attention; run with --variant swa"}
    cfg = get_config(arch, eff_variant)
    if overrides:
        cfg = replace(cfg, **overrides)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    step_kw = dict(phase=phase, seq_shard_residual=seq_shard_residual,
                   constrain_attn=constrain_attn)
    with jax.set_mesh(mesh):
        t0 = time.perf_counter()
        built = build_step(cfg, shape, mesh, **step_kw)
        lowered = built.lower()
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        print(f"== {built.name} mesh={mesh.devices.shape} ==")
        print(f"memory_analysis: {mem}")
        cost = compiled.cost_analysis()
        print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})

        total_p, active_p = active_params(cfg)
        # MODEL_FLOPS: 6·N·D for training (fwd+bwd), 2·N·D forward-only for
        # serving; D = processed tokens (B·S for train/prefill, B for decode)
        if shape.kind == "decode":
            tokens, flop_factor = shape.global_batch, 2.0
        elif shape.kind == "prefill":
            tokens, flop_factor = shape.global_batch * shape.seq_len, 2.0
        else:
            tokens, flop_factor = shape.global_batch * shape.seq_len, 6.0
        rep = analyze_compiled(built.name, lowered, compiled, chips=chips,
                               n_active_params=active_p,
                               tokens=tokens * flop_factor / 6.0)
        raw = {"flops": rep.hlo_flops, "bytes": rep.hlo_bytes,
               "coll": dict(rep.coll_bytes)}
        # correct the while-counted-once undercount via unrolled R=1/2 diff
        # (single-pod only: the §Roofline table is single-pod by design)
        if measure:
            try:
                true_cost = _measure_true_cost(cfg, shape, mesh, phase, step_kw)
                rep.hlo_flops = true_cost["flops"]
                rep.hlo_bytes = true_cost["bytes"]
                rep.coll_bytes = true_cost["coll"]
            except Exception as e:  # noqa: BLE001
                print(f"measurement extrapolation failed ({e!r}); raw cost "
                      f"kept", file=sys.stderr)

    row = rep.row()
    row["raw_cost_analysis"] = raw
    if tag:
        row["tag"] = tag
    if overrides:
        row["overrides"] = {k: str(v) for k, v in overrides.items()}
    row["seq_shard_residual"] = seq_shard_residual
    row["constrain_attn"] = constrain_attn
    row.update({
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "variant": eff_variant or "base", "phase": phase, "status": "ok",
        "total_params": total_p, "active_params": active_p,
        "lower_s": t_lower, "compile_s": t_compile,
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
    })
    print(json.dumps({k: row[k] for k in
                      ("compute_s", "memory_s", "collective_s", "dominant",
                       "useful_flops_ratio", "compile_s")}, indent=None))
    return row


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help=f"one of {ARCH_IDS} or 'all'")
    ap.add_argument("--shape", default="all", help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default=None, choices=(None, "base", "swa"))
    ap.add_argument("--phase", default="generalize",
                    choices=("generalize", "personalize"))
    ap.add_argument("--auto-swa", action="store_true",
                    help="use the swa serving variant automatically for "
                         "long_500k on full-attention archs")
    ap.add_argument("--no-measure", action="store_true",
                    help="skip the unrolled R=1/2 cost-extrapolation compiles")
    ap.add_argument("--no-seq-shard", action="store_true",
                    help="disable Megatron sequence-sharding of the residual")
    ap.add_argument("--no-constrain-attn", action="store_true",
                    help="drop the head-sharding constraint on attention acts")
    ap.add_argument("--override", action="append", default=[],
                    help="ModelConfig field override, e.g. capacity_factor=1.0")
    ap.add_argument("--tag", default="", help="label stored with the rows")
    ap.add_argument("--out", default=None, help="append JSON rows here")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = tuple(SHAPES) if args.shape == "all" else (args.shape,)
    rows, failures = [], []
    for arch in archs:
        for shape_name in shapes:
            variant = args.variant
            if (args.auto_swa and shape_name == "long_500k"
                    and not get_config(arch).supports_long_context):
                variant = "swa"
            overrides = {}
            for ov in args.override:
                k, v = ov.split("=", 1)
                overrides[k] = (float(v) if "." in v else
                                (None if v == "None" else int(v)))
            try:
                rows.append(run_one(
                    arch, shape_name, multi_pod=args.multi_pod,
                    variant=variant, phase=args.phase,
                    measure=not args.no_measure,
                    overrides=overrides or None,
                    seq_shard_residual=not args.no_seq_shard,
                    constrain_attn=not args.no_constrain_attn,
                    tag=args.tag))
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, shape_name, repr(e)))
                rows.append({"arch": arch, "shape": shape_name,
                             "multi_pod": args.multi_pod, "status": "error",
                             "error": repr(e)[:2000]})
                print(f"FAILED {arch} x {shape_name}: {e!r}", file=sys.stderr)
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        with open(args.out, "w") as f:
            json.dump(existing + rows, f, indent=1, default=str)
    print(f"\n{len(rows) - len(failures)}/{len(rows)} combination(s) OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
