"""Step builders + sharding assignment for the dry-run and real launches.

Given (config, input shape, mesh) this module produces the jit-able step
function with fully-specified in/out shardings:

  train_4k     -> train_step  (phase-0 generalize; phase-1 also buildable)
  prefill_32k  -> prefill_step
  decode_32k   -> serve_step  (one token, cache of seq_len)
  long_500k    -> serve_step  (sub-quadratic path per DESIGN.md policy)

All PartitionSpecs are *sanitized* against the mesh: an axis is only applied
to a dim it divides evenly (e.g. whisper's vocab 51865 stays replicated;
qwen2's 14 heads skip the head constraint while its packed 896-wide
projections still shard).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import InputShape, decode_cache_width, input_specs
from ..core.gp.trainer import broadcast_to_partitions
from ..models.config import ModelConfig
from ..models.sharding import ShardingPolicy
from ..models.transformer import Transformer
from ..train.optim import AdamW, apply_updates
from .mesh import data_axes_of, model_axis_of

__all__ = ["BuiltStep", "build_step", "sanitize_spec"]


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Drop mesh axes from dims they do not divide evenly."""
    parts: list = []
    for d in range(len(shape)):
        entry = spec[d] if d < len(spec) else None
        if entry is None:
            parts.append(None)
            continue
        axes = list(entry) if isinstance(entry, tuple) else [entry]
        while axes:
            size = math.prod(mesh.shape[a] for a in axes)
            if shape[d] % size == 0:
                break
            axes.pop()
        parts.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return P(*parts)


def _tree_shardings(specs, structs, mesh):
    return jax.tree.map(
        lambda spec, st: NamedSharding(mesh, sanitize_spec(spec, st.shape, mesh)),
        specs, structs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _batch_specs(batch_struct: dict, dax: tuple[str, ...]) -> dict:
    out = {}
    for k, v in batch_struct.items():
        out[k] = P(dax, *([None] * (len(v.shape) - 1)))
    return out


def _cache_spec_for(path: str, shape: tuple[int, ...], dax, mesh) -> P:
    """(R, B, H, W, Dh) KV / (R, B, H, N, P) ssm / (R, B, K, C) conv."""
    nd = len(shape)
    if path.endswith("k") or path.endswith("v"):
        cand = P(None, dax, "model", None, None)
        s = sanitize_spec(cand, shape, mesh)
        if s[2] is None and shape[3] % mesh.shape["model"] == 0:
            # heads not shardable -> context-parallel cache (shard sequence)
            s = sanitize_spec(P(None, dax, None, "model", None), shape, mesh)
        return s
    if path.endswith("ssm"):
        return sanitize_spec(P(None, dax, "model", None, None), shape, mesh)
    if path.endswith("conv"):
        return sanitize_spec(P(None, dax, None, "model"), shape, mesh)
    return P(*([None] * nd))


@dataclass
class BuiltStep:
    name: str
    step: Callable
    in_shardings: Any
    out_shardings: Any
    arg_structs: tuple
    model: Transformer
    policy: ShardingPolicy

    def jitted(self):
        return jax.jit(self.step, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings)

    def lower(self):
        return self.jitted().lower(*self.arg_structs)


def _make_policy(mesh, cfg: ModelConfig, *, seq_shard_residual: bool = True,
                 constrain_attn: bool = True) -> ShardingPolicy:
    return ShardingPolicy(
        data_axes=data_axes_of(mesh),
        model_axis=model_axis_of(mesh),
        seq_shard_residual=seq_shard_residual,
        constrain_attn=constrain_attn,
        enabled=True,
        axis_sizes={a: int(mesh.shape[a]) for a in mesh.axis_names},
    )


def build_step(cfg: ModelConfig, shape: InputShape, mesh, *,
               optimizer: AdamW | None = None,
               phase: str = "generalize",
               num_partitions: int | None = None,
               seq_shard_residual: bool = True,
               constrain_attn: bool = True) -> BuiltStep:
    dax = data_axes_of(mesh)
    policy = _make_policy(mesh, cfg, seq_shard_residual=seq_shard_residual,
                          constrain_attn=constrain_attn)
    model = Transformer(cfg, policy)
    optimizer = optimizer or AdamW(lr=1e-3, weight_decay=0.01, grad_clip=1.0)

    params_struct = jax.eval_shape(lambda: model.init(0))
    p_specs = policy.param_specs(params_struct)
    p_shard = _tree_shardings(p_specs, params_struct, mesh)

    if shape.kind == "train" and phase == "generalize":
        opt_struct = jax.eval_shape(optimizer.init, params_struct)
        # moment tensors mirror the parameter sharding
        o_shard = type(opt_struct)(
            step=NamedSharding(mesh, P()),
            mu=jax.tree.map(lambda s: s, p_shard),
            nu=jax.tree.map(lambda s: s, p_shard),
        )
        batch_struct = input_specs(cfg, shape)
        b_specs = _batch_specs(batch_struct, dax)
        b_shard = _tree_shardings(b_specs, batch_struct, mesh)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, opt_state, loss

        return BuiltStep(
            name=f"train:{cfg.name}:{shape.name}",
            step=train_step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, NamedSharding(mesh, P())),
            arg_structs=(params_struct, opt_struct, batch_struct),
            model=model, policy=policy,
        )

    if shape.kind == "train" and phase == "personalize":
        # per-partition replicas: leading axis sharded over the data axes
        npart = num_partitions or math.prod(mesh.shape[a] for a in dax)
        pp_struct = jax.eval_shape(
            lambda: broadcast_to_partitions(model.init(0), npart))
        pp_specs = jax.tree.map(
            lambda s: P(dax, *s), policy.param_specs(params_struct),
            is_leaf=lambda x: isinstance(x, P))
        pp_shard = _tree_shardings(pp_specs, pp_struct, mesh)
        opt_struct = jax.eval_shape(jax.vmap(optimizer.init), pp_struct)
        oo_shard = type(opt_struct)(
            step=NamedSharding(mesh, P()),
            mu=jax.tree.map(lambda s: s, pp_shard),
            nu=jax.tree.map(lambda s: s, pp_shard),
        )
        b_local = shape.global_batch // npart
        batch_struct = input_specs(cfg, shape)
        batch_struct = jax.tree.map(
            lambda st: jax.ShapeDtypeStruct((npart, b_local) + st.shape[1:], st.dtype),
            batch_struct)
        bb_specs = {k: P(dax, *([None] * (len(v.shape) - 1)))
                    for k, v in batch_struct.items()}
        bb_shard = _tree_shardings(bb_specs, batch_struct, mesh)
        active_struct = jax.ShapeDtypeStruct((npart,), jnp.bool_)
        a_shard = NamedSharding(mesh, sanitize_spec(P(dax), active_struct.shape, mesh))

        from ..core.gp.trainer import GPHyperParams, make_personalize_step
        inner = make_personalize_step(model.train_loss, optimizer, GPHyperParams())

        def personalize_step(params_p, opt_p, batch_p, global_params, active):
            return inner(params_p, opt_p, batch_p, global_params, active)

        return BuiltStep(
            name=f"train-personalize:{cfg.name}:{shape.name}",
            step=personalize_step,
            in_shardings=(pp_shard, oo_shard, bb_shard, p_shard, a_shard),
            out_shardings=(pp_shard, oo_shard,
                           NamedSharding(mesh, sanitize_spec(P(dax), (npart,), mesh))),
            arg_structs=(pp_struct, opt_struct, batch_struct, params_struct,
                         active_struct),
            model=model, policy=policy,
        )

    if shape.kind == "prefill":
        batch_struct = input_specs(cfg, shape)
        b_specs = _batch_specs(batch_struct, dax)
        b_shard = _tree_shardings(b_specs, batch_struct, mesh)
        width, rolling = decode_cache_width(cfg, shape)

        def prefill_step(params, batch):
            logits, caches, cache_len = model.prefill(
                params, batch, cache_size=None)
            return logits, caches, cache_len

        # out shardings: infer cache specs from the eval_shape of the step
        out_struct = jax.eval_shape(prefill_step, params_struct, batch_struct)
        logits_sh = NamedSharding(
            mesh, sanitize_spec(P(dax, "model"), out_struct[0].shape, mesh))
        cache_sh = _cache_tree_shardings(out_struct[1], dax, mesh)
        return BuiltStep(
            name=f"prefill:{cfg.name}:{shape.name}",
            step=prefill_step,
            in_shardings=(p_shard, b_shard),
            out_shardings=(logits_sh, cache_sh, NamedSharding(mesh, P())),
            arg_structs=(params_struct, batch_struct),
            model=model, policy=policy,
        )

    # decode / serve step
    spec = input_specs(cfg, shape)
    token_struct, caches_struct = spec["token"], spec["caches"]
    clen_struct, rolling = spec["cache_len"], spec["rolling"]
    t_shard = NamedSharding(mesh, sanitize_spec(P(dax, None), token_struct.shape, mesh))
    c_shard = _cache_tree_shardings(caches_struct, dax, mesh)
    l_shard = NamedSharding(mesh, P())

    def serve_step(params, token, caches, cache_len):
        logits, new_caches = model.decode_step(params, token, caches, cache_len,
                                               rolling=rolling)
        return logits, new_caches

    out_struct = jax.eval_shape(serve_step, params_struct, token_struct,
                                caches_struct, clen_struct)
    logits_sh = NamedSharding(
        mesh, sanitize_spec(P(dax, "model"), out_struct[0].shape, mesh))
    return BuiltStep(
        name=f"serve:{cfg.name}:{shape.name}",
        step=serve_step,
        in_shardings=(p_shard, t_shard, c_shard, l_shard),
        out_shardings=(logits_sh, c_shard),
        arg_structs=(params_struct, token_struct, caches_struct, clen_struct),
        model=model, policy=policy,
    )


def _cache_tree_shardings(caches_struct, dax, mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_struct)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append(NamedSharding(mesh, _cache_spec_for(name, leaf.shape, dax, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)
