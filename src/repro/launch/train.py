"""End-to-end training driver (CLI).

Two modes, both exercising the paper's full pipeline (EW partitioning →
CBS sampling → GP two-phase training):

  gnn   the faithful reproduction: distributed GraphSAGE on a synthetic
        benchmark partitioned across N logical hosts
            PYTHONPATH=src python -m repro.launch.train gnn \
                --dataset products-s --parts 4 --method ew --epochs 30

  llm   the framework generalisation: any ``--arch`` from the zoo (reduced
        size on CPU) trained on an entropy-sharded domain corpus
            PYTHONPATH=src python -m repro.launch.train llm \
                --arch llama3.2-1b --shards 4 --steps 60

The gnn mode executes through the SPMD engine (repro.engine): with >= N
devices each epoch runs as one ``shard_map`` step over a partition mesh;
on a single CPU the SAME per-shard program runs under ``vmap`` with
identical collective semantics (DESIGN.md §3).  ``--engine sequential``
selects the legible per-partition Python-loop reference, which the engine
reproduces bit-for-bit in float64 (tests/test_engine_parity.py).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def run_gnn(args) -> dict:
    if args.engine == "spmd":
        # a partition mesh needs >= parts devices; on a plain CPU host force
        # XLA's host-platform device split BEFORE jax initialises (no-op when
        # the flag is already set, e.g. on a real mesh)
        import os
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.parts}").strip()
    from repro.pipeline import EATConfig, run_eat_distgnn

    cfg = EATConfig(
        dataset=args.dataset,
        num_parts=args.parts,
        partition_method=args.method,
        use_cbs=not args.no_cbs,
        use_gp=not args.no_gp,
        max_epochs=args.epochs,
        hidden_dim=args.hidden,
        batch_size=args.batch_size,
        fanouts=(args.fanout, args.fanout),
        seed=args.seed,
        centralized=args.centralized,
        engine_mode=args.engine,
        use_pallas_agg=not args.no_pallas_agg,
        overlap_halo=args.overlap_halo,
        ring_chunks=args.ring_chunks,
        interpret=not args.no_interpret,
        async_personalize=args.async_personalize,
        async_generalize=args.async_generalize,
        double_buffer=not args.no_double_buffer,
        phase0_fraction=args.phase0_frac,
        full_graph_train=args.full_graph_train,
        full_graph_iters=args.full_graph_iters,
        halo_cache=args.halo_cache,
        halo_refresh_every=args.halo_refresh_every,
        halo_cv=args.halo_cv,
        halo_compress=args.halo_compress,
        grad_compress=args.grad_compress,
        grad_topk_frac=args.grad_topk_frac,
        grad_bucket_kb=args.grad_bucket_kb,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        keep_checkpoints=args.keep_checkpoints,
        resume=args.resume,
        feat_store=args.feat_store,
        hot_frac=args.hot_frac,
        hot_policy=args.hot_policy,
        feat_groups=args.feat_groups,
        feat_budget_mb=args.feat_budget_mb,
    )
    fault_plan = None
    if args.crash_at_epoch or args.drop_refresh_at:
        from repro.robustness import FaultPlan
        fault_plan = FaultPlan(
            crash_epochs=frozenset(args.crash_at_epoch or ()),
            drop_refresh_epochs=frozenset(args.drop_refresh_at or ()))
    result = run_eat_distgnn(cfg, verbose=True, fault_plan=fault_plan)
    print(json.dumps(result.summary(), indent=2))
    return result.summary()


def run_llm(args) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core import (GPController, GPScheduleConfig, GPHyperParams,
                            make_generalize_step, make_personalize_step,
                            broadcast_to_partitions)
    from repro.data import (CorpusSpec, DomainCorpus, ShardedBatcher,
                            shard_corpus_by_entropy)
    from repro.models import Transformer
    from repro.train.optim import AdamW, apply_updates

    cfg = get_config(args.arch).reduced(d_model=args.d_model)
    model = Transformer(cfg)
    spec = CorpusSpec(num_docs=args.docs, doc_len=args.seq, vocab_size=cfg.vocab_size,
                      num_domains=8, seed=args.seed)
    corpus = DomainCorpus(spec)
    shards = shard_corpus_by_entropy(corpus, args.shards, method=args.method)
    print(f"corpus shard domain entropies ({args.method}): "
          f"{shards.shard_entropies.round(3).tolist()}")
    batcher = ShardedBatcher(corpus, shards, batch_per_shard=args.batch,
                             class_balanced=not args.no_cbs, seed=args.seed)

    def loss_fn(params, batch):
        return model.train_loss(params, batch)

    opt = AdamW(lr=3e-3, grad_clip=1.0)
    params = model.init(args.seed)
    opt_state = opt.init(params)
    gen_step = jax.jit(make_generalize_step(loss_fn, opt))
    steps_phase0 = int(args.steps * args.phase0_frac)
    hist = []
    t0 = time.time()
    for step in range(steps_phase0):
        nb = batcher.next_batch()
        # phase-0: explicit gradient averaging across shards (the pmean)
        losses, grads_acc = [], None
        for pshard in range(args.shards):
            b = {"tokens": jnp.asarray(nb["tokens"][pshard]),
                 "labels": jnp.asarray(nb["labels"][pshard])}
            l, g = jax.value_and_grad(loss_fn)(params, b)
            losses.append(float(l))
            grads_acc = g if grads_acc is None else jax.tree.map(
                lambda a, b_: a + b_, grads_acc, g)
        grads = jax.tree.map(lambda g_: g_ / args.shards, grads_acc)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        hist.append(float(np.mean(losses)))
        if step % 10 == 0:
            print(f"[phase-0] step {step:4d} loss {hist[-1]:.4f}")

    global_params = params
    # phase-1: personalization (per-shard replicas, no gradient traffic)
    pstep = jax.jit(make_personalize_step(
        loss_fn, opt, GPHyperParams(lambda_prox=args.lambda_prox)))
    pparams = broadcast_to_partitions(params, args.shards)
    popt = jax.vmap(opt.init)(pparams)
    active = jnp.ones((args.shards,), bool)
    ploss_hist = []
    for step in range(args.steps - steps_phase0):
        nb = batcher.next_batch()
        batch_p = {"tokens": jnp.asarray(nb["tokens"]),
                   "labels": jnp.asarray(nb["labels"])}
        pparams, popt, losses = pstep(pparams, popt, batch_p, global_params, active)
        ploss_hist.append(np.asarray(losses))
        if step % 10 == 0:
            print(f"[phase-1] step {step:4d} per-shard loss "
                  f"{np.asarray(losses).round(4).tolist()}")
    out = {
        "arch": args.arch, "method": args.method,
        "shard_entropies": shards.shard_entropies.tolist(),
        "phase0_final_loss": hist[-1] if hist else None,
        "phase1_final_loss": (np.asarray(ploss_hist[-1]).tolist()
                              if ploss_hist else None),
        "wall_s": time.time() - t0,
    }
    print(json.dumps(out, indent=2))
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    g = sub.add_parser("gnn")
    g.add_argument("--dataset", default="products-s")
    g.add_argument("--parts", type=int, default=4)
    g.add_argument("--method", default="ew",
                   choices=("random", "metis", "ew", "ew_balanced"))
    g.add_argument("--no-cbs", action="store_true")
    g.add_argument("--no-gp", action="store_true")
    g.add_argument("--epochs", type=int, default=30)
    g.add_argument("--hidden", type=int, default=128)
    g.add_argument("--batch-size", type=int, default=256)
    g.add_argument("--fanout", type=int, default=10)
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--engine", default="auto",
                   choices=("auto", "spmd", "stacked", "sequential"),
                   help="epoch executor: shard_map over a partition mesh, "
                        "single-device stacked vmap, or the sequential "
                        "Python-loop reference")
    g.add_argument("--no-pallas-agg", action="store_true",
                   help="use the jnp segment-op fallback instead of the "
                        "Pallas segment_agg kernel on the eval forward")
    g.add_argument("--overlap-halo", action="store_true",
                   help="boundary/interior split forward: overlap each "
                        "layer's halo exchange with interior aggregation "
                        "and restrict dense compute to owned rows")
    g.add_argument("--ring-chunks", type=int, default=0,
                   help="exchange as a ppermute ring with N chunks per "
                        "step instead of one all_to_all (0 = all_to_all); "
                        "only meaningful with --overlap-halo")
    g.add_argument("--halo-cache", action="store_true",
                   help="historical-embedding halo cache: eval forwards "
                        "aggregate against the last-received boundary "
                        "embeddings and only pay the exchange on the "
                        "--halo-refresh-every cadence (DESIGN.md §8)")
    g.add_argument("--halo-refresh-every", type=int, default=4,
                   help="full halo refresh cadence K with --halo-cache: "
                        "every K-th eval forward pays the full exchange "
                        "(1 = refresh always, i.e. no staleness)")
    g.add_argument("--halo-cv", action="store_true",
                   help="VR-GCN control-variate mode: cached forwards "
                        "refresh a rotating 1/(K-1) chunk of the send "
                        "slots instead of going fully stale between "
                        "full refreshes")
    g.add_argument("--halo-compress", default="none",
                   choices=("none", "fp16", "int8"),
                   help="quantize the eval forwards' halo exchange payload "
                        "(error-compensated per-row codec; composes with "
                        "--halo-cache and --ring-chunks, DESIGN.md §11)")
    g.add_argument("--grad-compress", default="none",
                   choices=("none", "bucketed", "topk"),
                   help="phase-0 gradient all-reduce spelling: bucketed "
                        "ring-psum slices, or top-k sparsification with "
                        "error feedback (DESIGN.md §11)")
    g.add_argument("--grad-topk-frac", type=float, default=0.01,
                   help="fraction of gradient entries --grad-compress=topk "
                        "ships per sync")
    g.add_argument("--grad-bucket-kb", type=int, default=512,
                   help="slice size of the bucketed gradient all-reduce")
    g.add_argument("--no-interpret", action="store_true",
                   help="run Pallas kernels compiled (real TPU) instead of "
                        "interpret mode; pair with --engine spmd on a mesh")
    g.add_argument("--centralized", action="store_true",
                   help="single host, no partitioning (the Table IV "
                        "baseline configuration)")
    g.add_argument("--full-graph-train", action="store_true",
                   help="phase-0 trains full-graph (full-batch "
                        "value_and_grad through the distributed forward "
                        "and the differentiable Pallas aggregation op) "
                        "instead of sampled minibatches; with --centralized "
                        "this is the Table IV baseline at full-graph scale")
    g.add_argument("--full-graph-iters", type=int, default=1,
                   help="full-batch steps per phase-0 epoch with "
                        "--full-graph-train")
    g.add_argument("--async-personalize", action="store_true",
                   help="phase-1 with per-partition iteration budgets and "
                        "the CBS mini-epoch draw on device (no host NumPy "
                        "on the mini-epoch path)")
    g.add_argument("--async-generalize", action="store_true",
                   help="phase-0 epoch draw on device (uniform shuffle, or "
                        "the CBS mini-epoch with CBS on) with the train "
                        "scan and the validation eval fused into ONE "
                        "device program per epoch — retires the host "
                        "prefetcher on that path")
    g.add_argument("--no-double-buffer", action="store_true",
                   help="disable overlapping host-side sampling of epoch "
                        "t+1 with the device step of epoch t")
    g.add_argument("--checkpoint-dir", default=None,
                   help="save an epoch-granular full-pipeline checkpoint "
                        "here (atomic, checksummed, last "
                        "--keep-checkpoints retained)")
    g.add_argument("--checkpoint-every", type=int, default=1,
                   help="checkpoint every k-th epoch boundary")
    g.add_argument("--keep-checkpoints", type=int, default=3)
    g.add_argument("--resume", action="store_true",
                   help="resume from the newest intact checkpoint in "
                        "--checkpoint-dir; the finished run is bit-for-bit "
                        "the uninterrupted one")
    g.add_argument("--crash-at-epoch", type=int, nargs="*", default=None,
                   metavar="E",
                   help="fault injection: raise InjectedCrash after the "
                        "epoch-E boundary checkpoint")
    g.add_argument("--drop-refresh-at", type=int, nargs="*", default=None,
                   metavar="E",
                   help="fault injection: drop epoch E's halo-cache "
                        "refresh payload (eval serves the stale cache)")
    g.add_argument("--phase0-frac", type=float, default=None,
                   help="hard phase split: fraction of --epochs spent "
                        "generalizing (default: loss-driven trigger; "
                        "async runs default to 0.4)")
    g.add_argument("--feat-store", action="store_true",
                   help="two-tier feature store: keep the top --hot-frac "
                        "of each partition's feature rows resident on "
                        "device and stage the cold remainder from host "
                        "numpy per compiled call (DESIGN.md §12)")
    g.add_argument("--hot-frac", type=float, default=0.5,
                   help="fraction of feature rows kept device-resident "
                        "with --feat-store (0.0..1.0; 1.0 = all resident, "
                        "zero cold traffic)")
    g.add_argument("--hot-policy", default="degree",
                   choices=("degree", "freq"),
                   help="hot-set ranking: clamped in-degree, or degree "
                        "with a dominating boost for training-set rows")
    g.add_argument("--feat-groups", type=int, default=0,
                   help="stream the eval forward over groups of G <= parts "
                        "partitions (stacked mode, needs --feat-store): "
                        "only G assembled feature planes exist at once, so "
                        "graphs bigger than the stacked plane still run")
    g.add_argument("--feat-budget-mb", type=float, default=0.0,
                   help="refuse to build when peak device feature bytes "
                        "exceed this budget (0 disables) — the "
                        "bigger-than-device gate")

    l = sub.add_parser("llm")
    l.add_argument("--arch", default="llama3.2-1b")
    l.add_argument("--shards", type=int, default=4)
    l.add_argument("--method", default="ew", choices=("random", "metis", "ew"))
    l.add_argument("--no-cbs", action="store_true")
    l.add_argument("--steps", type=int, default=60)
    l.add_argument("--phase0-frac", type=float, default=0.6)
    l.add_argument("--lambda-prox", type=float, default=0.01)
    l.add_argument("--docs", type=int, default=512)
    l.add_argument("--seq", type=int, default=64)
    l.add_argument("--batch", type=int, default=8)
    l.add_argument("--d-model", type=int, default=128)
    l.add_argument("--seed", type=int, default=0)

    args = ap.parse_args()
    if args.mode == "gnn":
        run_gnn(args)
    else:
        run_llm(args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
