"""Partitioned online GNN inference service (DESIGN.md §9).

DistDGL's serving shape — per-partition precomputed state, a hot-row
cache, cross-partition request batching — rendered over this repo's
partition layout:

  · **Embedding store.**  One host array per (layer, partition):
    ``h[l][p]`` holds layer l's POST-exchange input embedding for every
    local row (owned + halo), ``h[L][p]`` the final logits for owned
    rows.  Initialised from :meth:`SPMDEngine.export_serving_state`:
    owned rows from the exported layer embeddings, halo rows landed from
    the exported recv-layout cache buffers through ``pg.recv_pos`` — the
    same PR-6 cache geometry the training eval path refreshes through.

  · **Dirty-set incremental recompute.**  Feature and edge updates mark
    rows dirty; :class:`~repro.graph.distributed.RecomputePlanner`
    propagates the dirty set one hop per layer through the CSR shards
    (self term ∪ local out-neighbours, halo replicas mirrored between
    layers), and :meth:`flush` recomputes ONLY those rows — a gathered
    sub-edge-list aggregation through ``segment_mean_op`` (or the jnp
    segment-sum reference) plus a row-gathered dense transform.  On this
    backend a row-subset matmul is bitwise the corresponding rows of the
    full matmul for >= 2 rows (single-row falls onto a gemv kernel with
    different reduction order), so every batch is padded to at least two
    rows via the trash row; sub-edge segment sums keep each row's edges
    in the canonical ascending-global-id order the full aggregation
    uses.  Served logits after any update sequence therefore match a
    from-scratch forward bit-for-bit in fp64 (tests/test_serve_gnn.py).

  · **Query batching tick.**  Queries accumulate in :meth:`submit`;
    each :meth:`tick` flushes pending recomputes once, answers repeat
    queries from an LRU hot-row cache (flush invalidates exactly the
    recomputed final-layer rows, so hits are bitwise the store row; hit /
    miss counts land in ``stats``), then groups the remaining node ids by
    owning partition and serves each group with ONE fused device gather
    from that partition's logits store.

Staleness contract: reads between ``tick``/``flush`` calls serve the
last flushed state; a flush makes every preceding update visible
atomically (layer l+1 never reads a mix of old and new layer-l rows,
because replicas are pushed before the next layer recomputes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.distributed import PartitionedGraph, RecomputePlanner
from ..graph.csr import CSRGraph

__all__ = ["GNNServingEngine", "apply_updates_to_graph"]


def _bucket(n: int, lo: int = 2) -> int:
    """Next power of two >= max(n, lo) — bounds distinct jit shapes."""
    m = max(lo, int(n))
    return 1 << (m - 1).bit_length()


@partial(jax.jit, static_argnames=("activate",))
def _dense_recompute(h_prev, w_self, w_neigh, b, rows, src, dst, deg,
                     activate: bool):
    """Recompute ``rows``' next-layer embedding from the level-(l-1) store.

    Mirrors ``make_ref_mean_agg`` + the layer matmul spelling exactly:
    segment-sum over the (rebased) sub-edge list, divide by the clamped
    degree, then ``h @ w_self + agg @ w_neigh + b``.  Pad rows gather the
    all-zero trash row; pad edges land in the sacrificial segment M.
    """
    m = rows.shape[0]
    s = jax.ops.segment_sum(h_prev[src], dst, num_segments=m + 1)[:m]
    agg = s / jnp.maximum(deg, 1.0)[:, None]
    out = h_prev[rows] @ w_self + agg @ w_neigh + b
    return jax.nn.relu(out) if activate else out


@partial(jax.jit, static_argnames=("activate", "interpret"))
def _pallas_recompute(h_prev, w_self, w_neigh, b, rows, blocks,
                      activate: bool, interpret: bool):
    """The same recompute with the aggregation through ``segment_mean_op``
    (the blocked Pallas kernel every training forward uses)."""
    from ..kernels.ops import segment_mean_op

    agg = segment_mean_op(h_prev, blocks, num_rows=int(rows.shape[0]),
                          interpret=interpret).astype(h_prev.dtype)
    out = h_prev[rows] @ w_self + agg @ w_neigh + b
    return jax.nn.relu(out) if activate else out


_gather = jax.jit(lambda table, rows: table[rows])


class GNNServingEngine:
    """Online inference over a trained partitioned GraphSAGE.

    ``export`` is :meth:`SPMDEngine.export_serving_state`'s dict; the
    engine serves from host-resident growable per-partition stores and
    runs all numeric work (recompute, gather) as jitted device calls, so
    incremental results are bitwise the from-scratch forward.
    """

    def __init__(self, model, params, pg: PartitionedGraph, export: dict, *,
                 use_pallas_agg: bool = False, interpret: bool = True,
                 hot_cache_rows: int = 256, planner_compact_after: int = 64):
        if len(params.layers) != model.num_layers:
            raise ValueError("params depth != model.num_layers")
        self.model = model
        self.params = params
        self.L = model.num_layers
        self.use_pallas_agg = bool(use_pallas_agg)
        self.interpret = bool(interpret)
        P = pg.num_parts
        self.num_parts = P
        self.n_own = np.asarray(pg.n_own).astype(np.int64)
        self.trash_row = int(pg.trash_row)

        # ---- ownership + local<->global maps -----------------------------
        gids_all = np.asarray(pg.global_ids)
        self.num_nodes = int(gids_all.max()) + 1
        self.owner_part = np.full(self.num_nodes, -1, np.int32)
        self.owner_row = np.full(self.num_nodes, -1, np.int64)
        for p in range(P):
            own = gids_all[p][: self.n_own[p]]
            self.owner_part[own] = p
            self.owner_row[own] = np.arange(self.n_own[p])
        self.l2g = [gids_all[p].copy() for p in range(P)]
        self.g2l = [{int(g): i for i, g in enumerate(self.l2g[p]) if g >= 0}
                    for p in range(P)]

        # ---- per-owned-row in-neighbour lists (ascending global id, the
        # order build_partitioned_graph emits and scipy-canonical CSR uses)
        self.nbr_loc: list[list[np.ndarray]] = []
        self.nbr_gid: list[list[np.ndarray]] = []
        for p in range(P):
            real = np.asarray(pg.edge_mask[p]) > 0
            src = np.asarray(pg.edge_src[p])[real].astype(np.int64)
            dst = np.asarray(pg.edge_dst[p])[real].astype(np.int64)
            counts = np.bincount(dst, minlength=int(self.n_own[p]))
            bounds = np.zeros(int(self.n_own[p]) + 1, np.int64)
            np.cumsum(counts[: self.n_own[p]], out=bounds[1:])
            # dst-major emitted order: row v's edges are contiguous
            self.nbr_loc.append([src[bounds[v]:bounds[v + 1]].copy()
                                 for v in range(int(self.n_own[p]))])
            self.nbr_gid.append([self.l2g[p][s] for s in self.nbr_loc[p]])

        # ---- embedding store: land halo rows from the exported recv-layout
        # cache buffers through recv_pos (the PR-6 cache geometry)
        recv_pos = np.asarray(pg.recv_pos)
        self.h: list[list[np.ndarray]] = []
        for l in range(self.L):
            per_part = []
            for p in range(P):
                arr = np.array(export["layers"][l][p], copy=True)
                arr[self.n_own[p]:] = 0          # halo re-landed, pads zeroed
                buf = np.asarray(export["cache"][f"h{l}"][p])
                arr[recv_pos[p].reshape(-1)] = buf.reshape(-1, arr.shape[-1])
                per_part.append(arr)
            self.h.append(per_part)
        self.h.append([np.array(export["logits"][p][: self.n_own[p]],
                                copy=True) for p in range(P)])
        self.dtype = self.h[0][0].dtype

        self.planner = RecomputePlanner(pg,
                                        compact_after=planner_compact_after)
        self._dirty0: list[set[int]] = [set() for _ in range(P)]
        self._edge_seeds: list[set[int]] = [set() for _ in range(P)]
        self._pending: list[int] = []
        # hot-row query cache: gid -> last served logit row, LRU up to
        # hot_cache_rows entries.  Entries are invalidated whenever a flush
        # recomputes that row's final-layer store, so a hit is always
        # bitwise the store row the gather path would have returned.
        self.hot_cache_rows = int(hot_cache_rows)
        self._hot: dict[int, np.ndarray] = {}
        self.stats = {"ticks": 0, "flushes": 0, "rows_recomputed": 0,
                      "gather_calls": 0, "queries": 0, "halo_rows_grown": 0,
                      "updates_queued": 0, "replay_attempts": 0,
                      "replayed": 0, "degraded_queries": 0,
                      "failovers": 0, "recoveries": 0,
                      "cache_hits": 0, "cache_misses": 0,
                      "planner_compactions": 0}

        # ---- per-partition health state machine (DESIGN.md §10) ----------
        # healthy -> failed (fail_partition / an injected serve fault) ->
        # healthy (recover_partition).  While a partition is failed its
        # stored embeddings stay FROZEN-CONSISTENT: any update whose
        # propagation cone would touch it is queued in arrival order and
        # applied NOWHERE, so reads of the failed store remain exactly the
        # last flushed state; queries it owns are answered from that state
        # with a per-answer staleness tag.  Queue replay is retried with
        # bounded exponential backoff and drains FIFO on recovery.
        self.health: list[str] = ["healthy"] * P
        self._failed_since: list[int] = [0] * P
        self._tick_no = 0
        self._queue: list[tuple] = []
        self._queued_feat: set[int] = set()
        self._queued_edges: set[tuple[int, int]] = set()
        self.max_backoff = 8          # backoff cap, in ticks
        self._backoff = 1
        self._retry_next = 0
        self.fault_plan = None

    # ------------------------------------------------------------- updates
    def _local(self, p: int, gid: int) -> int:
        """Local row of ``gid`` on partition p, growing a halo row (seeded
        with the owner's current per-layer embeddings, registered as a
        replica so future flushes keep it in sync) if p has never seen it."""
        row = self.g2l[p].get(gid)
        if row is not None:
            return row
        q = int(self.owner_part[gid])
        qrow = int(self.owner_row[gid])
        row = self.h[0][p].shape[0]
        for l in range(self.L):
            self.h[l][p] = np.concatenate(
                [self.h[l][p], self.h[l][q][qrow][None]], axis=0)
        self.l2g[p] = np.append(self.l2g[p], gid)
        self.g2l[p][gid] = row
        self.planner.add_replica(q, qrow, p, row)
        if qrow in self._dirty0[q]:
            self._dirty0[p].add(row)
        self.stats["halo_rows_grown"] += 1
        return row

    def update_features(self, gid: int, vec: np.ndarray) -> None:
        """Overwrite one node's input features (owner + every halo copy).
        While any partition in the update's propagation cone is failed the
        update is queued whole (applied nowhere) and replays on recovery."""
        gid = int(gid)
        if self._should_queue_feat(gid):
            self._queue.append(("feat", gid,
                                np.array(vec, self.dtype, copy=True)))
            self._queued_feat.add(gid)
            self.stats["updates_queued"] += 1
            return
        p = int(self.owner_part[gid])
        row = int(self.owner_row[gid])
        vec = np.asarray(vec, self.dtype)
        self.h[0][p][row] = vec
        self._dirty0[p].add(row)
        for q, qrow, _ in self.planner.replicas(p, np.asarray([row])):
            self.h[0][q][qrow] = vec
            self._dirty0[q].add(qrow)

    def add_edge(self, u: int, v: int) -> bool:
        """Add directed edge u -> v (u becomes an in-neighbour of v).
        Returns False if it already exists.  Growing a previously unseen
        cross-partition source appends a halo row on v's partition."""
        u, v = int(u), int(v)
        if self._should_queue_edge(u, v, adding=True):
            self._queue.append(("add", u, v))
            self._queued_edges.add((u, v))
            self.stats["updates_queued"] += 1
            return True
        p = int(self.owner_part[v])
        vrow = int(self.owner_row[v])
        pos = int(np.searchsorted(self.nbr_gid[p][vrow], u))
        if (pos < len(self.nbr_gid[p][vrow])
                and self.nbr_gid[p][vrow][pos] == u):
            return False
        urow = self._local(p, u)
        self.nbr_gid[p][vrow] = np.insert(self.nbr_gid[p][vrow], pos, u)
        self.nbr_loc[p][vrow] = np.insert(self.nbr_loc[p][vrow], pos, urow)
        self.planner.add_out_edge(p, urow, vrow)
        self._edge_seeds[p].add(vrow)
        return True

    def remove_edge(self, u: int, v: int) -> bool:
        """Remove directed edge u -> v; returns False if absent.  The
        removal is recorded with the planner, which keeps the stale
        out-edge until its per-partition compaction threshold (stale
        over-propagation is always safe; compaction stops paying for it)."""
        u, v = int(u), int(v)
        if self._should_queue_edge(u, v, adding=False):
            self._queue.append(("remove", u, v))
            self._queued_edges.add((u, v))
            self.stats["updates_queued"] += 1
            return True
        p = int(self.owner_part[v])
        vrow = int(self.owner_row[v])
        pos = int(np.searchsorted(self.nbr_gid[p][vrow], u))
        if (pos >= len(self.nbr_gid[p][vrow])
                or self.nbr_gid[p][vrow][pos] != u):
            return False
        urow = int(self.nbr_loc[p][vrow][pos])
        self.nbr_gid[p][vrow] = np.delete(self.nbr_gid[p][vrow], pos)
        self.nbr_loc[p][vrow] = np.delete(self.nbr_loc[p][vrow], pos)
        self.planner.remove_out_edge(p, urow, vrow)
        self._edge_seeds[p].add(vrow)
        return True

    # --------------------------------------------------------------- flush
    def _recompute_rows(self, l: int, p: int, rows: np.ndarray) -> None:
        h_prev = self.h[l - 1][p]
        lp = self.params.layers[l - 1]
        activate = l < self.L
        m = int(rows.size)
        # full-partition refresh keeps its exact (stable) shape; partial
        # batches pad to a power-of-two bucket, never below two rows
        mp = m if (m == self.n_own[p] and m >= 2) else _bucket(m)
        rp = np.full(mp, self.trash_row, np.int64)
        rp[:m] = rows
        srcs = [self.nbr_loc[p][r] for r in rows]
        counts = np.fromiter((s.size for s in srcs), np.int64, m)
        src = (np.concatenate(srcs) if m else np.empty(0, np.int64))
        dst = np.repeat(np.arange(m), counts)
        if self.use_pallas_agg:
            from ..kernels.ops import build_vjp_blocks
            blocks = build_vjp_blocks(src, dst, num_rows=mp,
                                      num_src_rows=h_prev.shape[0])
            out = _pallas_recompute(
                jnp.asarray(h_prev), lp.w_self, lp.w_neigh, lp.b,
                jnp.asarray(rp), jax.tree.map(jnp.asarray, blocks),
                activate=activate, interpret=self.interpret)
        else:
            e = int(src.size)
            ep = _bucket(e, lo=1)
            src_p = np.full(ep, self.trash_row, np.int64)
            dst_p = np.full(ep, mp, np.int64)   # sacrificial segment
            src_p[:e] = src
            dst_p[:e] = dst
            deg = np.ones(mp, self.dtype)
            deg[:m] = counts
            out = _dense_recompute(
                jnp.asarray(h_prev), lp.w_self, lp.w_neigh, lp.b,
                jnp.asarray(rp), jnp.asarray(src_p), jnp.asarray(dst_p),
                jnp.asarray(deg), activate=activate)
        self.h[l][p][rows] = np.asarray(out)[:m]

    def flush(self) -> dict:
        """Apply every pending update to the embedding store: propagate the
        dirty set one hop per layer, recompute exactly those owned rows,
        and mirror refreshed rows to their halo replicas between layers."""
        if (not any(self._dirty0) and not any(self._edge_seeds)):
            self.stats["planner_compactions"] = self.planner.compactions
            return {"rows_recomputed": 0, "per_layer": [0] * self.L}
        P = self.num_parts
        plans = self.planner.propagate(
            {p: np.fromiter(self._dirty0[p], np.int64, len(self._dirty0[p]))
             for p in range(P)},
            {p: np.fromiter(self._edge_seeds[p], np.int64,
                            len(self._edge_seeds[p])) for p in range(P)},
            self.L)
        per_layer, total = [], 0
        for l, rec in enumerate(plans, start=1):
            cnt = 0
            for p in range(P):
                if rec[p].size:
                    self._recompute_rows(l, p, rec[p])
                    cnt += int(rec[p].size)
            if l < self.L:
                for p in range(P):
                    for q, qrow, r in self.planner.replicas(p, rec[p]):
                        self.h[l][q][qrow] = self.h[l][p][r]
            else:
                # final-layer rows changed: their hot-cache entries are stale
                if self._hot:
                    for p in range(P):
                        for r in rec[p]:
                            self._hot.pop(int(self.l2g[p][r]), None)
            per_layer.append(cnt)
            total += cnt
        self._dirty0 = [set() for _ in range(P)]
        self._edge_seeds = [set() for _ in range(P)]
        self.stats["flushes"] += 1
        self.stats["rows_recomputed"] += total
        self.stats["planner_compactions"] = self.planner.compactions
        return {"rows_recomputed": total, "per_layer": per_layer}

    def refresh_full(self) -> dict:
        """From-scratch rematerialization through the same flush machinery
        (every owned row dirty) — the baseline :meth:`flush` must beat."""
        if self._any_failed():
            raise RuntimeError(
                "refresh_full requires every partition healthy; failed: "
                f"{[p for p, h in enumerate(self.health) if h != 'healthy']}")
        for p in range(self.num_parts):
            self._dirty0[p].update(range(int(self.n_own[p])))
        return self.flush()

    # ------------------------------------- health machine / degraded mode
    def _any_failed(self) -> bool:
        return any(h != "healthy" for h in self.health)

    def set_fault_plan(self, plan) -> None:
        """Attach a :class:`~repro.robustness.FaultPlan`; its serve fail /
        recover events are applied at the start of each :meth:`tick`."""
        self.fault_plan = plan

    def fail_partition(self, p: int) -> None:
        """Mark partition ``p`` failed at the current tick boundary.

        Pending dirty work is flushed FIRST (the failure lands on a flush
        boundary), so the failed store freezes in a fully consistent
        state; from here on any update whose cone touches ``p`` queues."""
        p = int(p)
        if self.health[p] != "healthy":
            return
        self.flush()
        self.health[p] = "failed"
        self._failed_since[p] = self._tick_no
        self.stats["failovers"] += 1

    def recover_partition(self, p: int) -> None:
        """Mark partition ``p`` healthy again; the queued updates replay
        (FIFO, all-or-nothing) at the next :meth:`tick`'s drain."""
        p = int(p)
        if self.health[p] != "failed":
            return
        self.health[p] = "healthy"
        self._backoff = 1
        self._retry_next = self._tick_no
        self.stats["recoveries"] += 1

    def _probe_touches_failed(self, seeds_h0: dict, seeds_edge: dict) -> bool:
        """Would an update with these dirty seeds propagate into a failed
        partition?  Runs the planner's cone (the exact sets flush would
        recompute + the replica pushes between layers) over the probe."""
        failed = {p for p, h in enumerate(self.health) if h != "healthy"}
        if not failed:
            return False
        P = self.num_parts
        for p in failed:
            if seeds_h0.get(p) or seeds_edge.get(p):
                return True
        plans = self.planner.propagate(
            {p: np.fromiter(sorted(seeds_h0.get(p, ())), np.int64,
                            len(seeds_h0.get(p, ()))) for p in range(P)},
            {p: np.fromiter(sorted(seeds_edge.get(p, ())), np.int64,
                            len(seeds_edge.get(p, ()))) for p in range(P)},
            self.L)
        for l, rec in enumerate(plans, start=1):
            for p in range(P):
                if p in failed and rec[p].size:
                    return True
                if l < self.L and rec[p].size:
                    for q, _qrow, _r in self.planner.replicas(p, rec[p]):
                        if q in failed:
                            return True
        return False

    def _should_queue_feat(self, gid: int) -> bool:
        if not self._queue and not self._any_failed():
            return False
        if gid in self._queued_feat:
            return True            # FIFO order behind the queued write
        if not self._any_failed():
            return False
        p = int(self.owner_part[gid])
        row = int(self.owner_row[gid])
        if self.health[p] != "healthy":
            return True
        seeds = {p: {row}}
        for q, qrow, _ in self.planner.replicas(p, np.asarray([row])):
            if self.health[q] != "healthy":
                return True        # h0 mirror would write into q
            seeds.setdefault(q, set()).add(qrow)
        return self._probe_touches_failed(seeds, {})

    def _should_queue_edge(self, u: int, v: int, *, adding: bool) -> bool:
        if not self._queue and not self._any_failed():
            return False
        if (u, v) in self._queued_edges:
            return True            # FIFO order behind the queued edge op
        if not self._any_failed():
            return False
        p = int(self.owner_part[v])
        if self.health[p] != "healthy":
            return True
        if adding and self.health[int(self.owner_part[u])] != "healthy":
            return True            # halo grow would subscribe to a dead host
        return self._probe_touches_failed({}, {p: {int(self.owner_row[v])}})

    def _drain_queue(self) -> None:
        """Replay the queued updates FIFO once every partition is healthy;
        while one is still failed, retry with bounded exponential backoff
        (1, 2, 4, ... capped at ``max_backoff`` ticks)."""
        if not self._queue:
            self._backoff = 1
            self._retry_next = 0
            return
        if self._tick_no < self._retry_next:
            return
        self.stats["replay_attempts"] += 1
        if self._any_failed():
            self._backoff = min(self._backoff * 2, self.max_backoff)
            self._retry_next = self._tick_no + self._backoff
            return
        ops, self._queue = self._queue, []
        self._queued_feat.clear()
        self._queued_edges.clear()
        for op in ops:
            if op[0] == "feat":
                self.update_features(op[1], op[2])
            elif op[0] == "add":
                self.add_edge(op[1], op[2])
            else:
                self.remove_edge(op[1], op[2])
        self.stats["replayed"] += len(ops)
        self._backoff = 1
        self._retry_next = 0

    # ------------------------------------------------------------- queries
    def submit(self, gids) -> None:
        self._pending.extend(int(g) for g in np.atleast_1d(np.asarray(gids)))

    def tick(self) -> tuple[dict, dict]:
        """One serving tick: apply scheduled fault events, attempt a queue
        drain, flush pending updates, then answer every queued query with
        one fused gather per owning partition.  Queries owned by a failed
        partition are answered from its frozen (last-flushed) logits and
        tagged in ``flush_stats['staleness']`` with the number of ticks
        since that partition failed."""
        self._tick_no += 1
        if self.fault_plan is not None:
            for kind, p in self.fault_plan.serve_events(self._tick_no):
                if kind == "fail":
                    self.fail_partition(p)
                else:
                    self.recover_partition(p)
        self._drain_queue()
        flush_stats = self.flush()
        results: dict[int, np.ndarray] = {}
        staleness: dict[int, int] = {}
        by_part: dict[int, list[int]] = {}
        for gid in self._pending:
            p = int(self.owner_part[gid])
            hot = self._hot.get(gid) if self.health[p] == "healthy" else None
            if hot is not None:
                self._hot[gid] = self._hot.pop(gid)    # LRU touch
                results[gid] = hot
                self.stats["cache_hits"] += 1
                continue
            by_part.setdefault(p, []).append(gid)
        for p, gids in by_part.items():
            rows = self.owner_row[np.asarray(gids, np.int64)]
            mp = _bucket(len(rows), lo=1)
            rp = np.zeros(mp, np.int64)
            rp[: len(rows)] = rows
            out = np.asarray(_gather(jnp.asarray(self.h[self.L][p]),
                                     jnp.asarray(rp)))[: len(rows)]
            self.stats["gather_calls"] += 1
            self.stats["cache_misses"] += len(gids)
            degraded = self.health[p] != "healthy"
            age = self._tick_no - self._failed_since[p] if degraded else 0
            for g, logit_row in zip(gids, out):
                results[g] = logit_row
                if degraded:
                    staleness[g] = age
                elif self.hot_cache_rows > 0:
                    self._hot.pop(g, None)
                    self._hot[g] = logit_row
            if degraded:
                self.stats["degraded_queries"] += len(gids)
            while len(self._hot) > self.hot_cache_rows:
                self._hot.pop(next(iter(self._hot)))
        self.stats["queries"] += len(self._pending)
        self.stats["ticks"] += 1
        self._pending.clear()
        flush_stats["staleness"] = staleness
        flush_stats["queued_updates"] = len(self._queue)
        flush_stats["health"] = list(self.health)
        return results, flush_stats

    def query(self, gids) -> np.ndarray:
        """Submit + tick: logits (k, C) aligned with ``gids``."""
        gids = np.atleast_1d(np.asarray(gids, np.int64))
        self.submit(gids)
        results, _ = self.tick()
        return np.stack([results[int(g)] for g in gids])

    def predict(self, gids) -> np.ndarray:
        return np.argmax(self.query(gids), axis=-1)

    def export_logits(self) -> np.ndarray:
        """(num_nodes, C) logits in global id order (flush first)."""
        self.flush()
        out = np.zeros((self.num_nodes, self.h[self.L][0].shape[-1]),
                       self.dtype)
        for p in range(self.num_parts):
            own = self.l2g[p][: self.n_own[p]]
            out[own] = self.h[self.L][p]
        return out

    # --------------------------------------------------------- constructors
    @classmethod
    def from_engine(cls, engine, pg: PartitionedGraph, params, **kw):
        return cls(engine.model, params, pg,
                   engine.export_serving_state(params), **kw)

    @classmethod
    def from_checkpoint(cls, path: str, engine, pg: PartitionedGraph, **kw):
        """Serve a checkpoint saved with ``train.checkpoint.save_pytree``."""
        from ..train.checkpoint import load_pytree

        params = load_pytree(path, engine.model.init(0))
        return cls.from_engine(engine, pg, params, **kw)


def apply_updates_to_graph(graph: CSRGraph, feature_updates: dict | None = None,
                           add_edges=(), remove_edges=()) -> CSRGraph:
    """Oracle-side mirror of the serving update API: rebuild a CSRGraph
    with the given updates applied.  Per-row in-neighbour lists stay
    sorted by global id — the canonical order both build paths aggregate
    in — so a from-scratch forward over the result is the serving
    engine's bitwise reference."""
    rows = {}

    def row(v: int) -> list[int]:
        if v not in rows:
            rows[v] = list(graph.neighbors(v))
        return rows[v]

    for u, v in add_edges:
        r = row(int(v))
        pos = int(np.searchsorted(r, int(u)))
        if pos >= len(r) or r[pos] != int(u):
            r.insert(pos, int(u))
    for u, v in remove_edges:
        r = row(int(v))
        pos = int(np.searchsorted(r, int(u)))
        if pos < len(r) and r[pos] == int(u):
            r.pop(pos)

    n = graph.num_nodes
    counts = np.diff(graph.indptr).copy()
    for v, r in rows.items():
        counts[v] = len(r)
    indptr = np.zeros(n + 1, graph.indptr.dtype)
    np.cumsum(counts, out=indptr[1:])
    indices = np.empty(int(indptr[-1]), graph.indices.dtype)
    for v in range(n):
        seg = (rows[v] if v in rows
               else graph.indices[graph.indptr[v]:graph.indptr[v + 1]])
        indices[indptr[v]:indptr[v + 1]] = seg

    features = np.array(graph.features, copy=True)
    for gid, vec in (feature_updates or {}).items():
        features[int(gid)] = np.asarray(vec, features.dtype)
    return CSRGraph(indptr=indptr, indices=indices, features=features,
                    labels=graph.labels, train_idx=graph.train_idx,
                    val_idx=graph.val_idx, test_idx=graph.test_idx,
                    num_classes=graph.num_classes, name=graph.name)
