"""Batched serving engine: prefill + greedy/temperature decode loop.

The jitted steps are exactly the ones the dry-run lowers for the decode
shapes (`decode_32k`, `long_500k`); here they run at small scale on CPU for
the examples and integration tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Transformer

__all__ = ["ServeEngine"]


@dataclass
class ServeEngine:
    model: Transformer
    params: Any
    cache_size: int
    rolling: bool = False

    def __post_init__(self):
        cfg = self.model.cfg
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_size=self.cache_size))
        self._decode = jax.jit(
            partial(self.model.decode_step, rolling=self.rolling))

    def generate(
        self,
        batch: dict[str, np.ndarray],
        max_new_tokens: int,
        *,
        temperature: float = 0.0,
        seed: int = 0,
        eos_id: int | None = None,
        truncate_done: bool = False,
    ) -> np.ndarray:
        """batch: {"tokens": (B, S)[, "patch_embeds"/"enc_embeds"]} ->
        (B, max_new_tokens) generated ids (greedy if temperature == 0).

        When every row has emitted ``eos_id`` the decode loop stops early,
        but the result is still padded to ``max_new_tokens`` with ``eos_id``
        so the output shape depends only on the arguments — not on which
        rows happened to share the batch.  ``truncate_done=True`` restores
        the old width-varies-with-batch truncating behavior."""
        key = jax.random.key(seed)
        logits, caches, cache_len = self._prefill(self.params, batch)
        b = logits.shape[0]
        out = np.zeros((b, max_new_tokens), dtype=np.int32)
        done = np.zeros(b, dtype=bool)
        tok = None
        for t in range(max_new_tokens):
            if temperature > 0.0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            tok_np = np.asarray(tok, dtype=np.int32)
            if eos_id is not None:
                # rows that already emitted EOS are finished: freeze every
                # later position to eos_id instead of resampling over it
                tok_np = np.where(done, eos_id, tok_np)
            out[:, t] = tok_np
            if eos_id is not None:
                done |= tok_np == eos_id
                if done.all():
                    if truncate_done:
                        out = out[:, : t + 1]
                    else:
                        out[:, t + 1:] = eos_id
                    break
            if t + 1 < max_new_tokens:   # the last token needs no decode
                logits, caches = self._decode(
                    self.params, jnp.asarray(tok_np)[:, None], caches,
                    cache_len)
                cache_len = cache_len + 1
        return out
