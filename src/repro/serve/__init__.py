from .engine import ServeEngine
from .gnn import GNNServingEngine, apply_updates_to_graph

__all__ = ["ServeEngine", "GNNServingEngine", "apply_updates_to_graph"]
