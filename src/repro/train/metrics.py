"""F1 metrics exactly as the paper reports them.

micro-F1    — global TP/FP/FN over all test examples (== accuracy for
              single-label multi-class).
macro-F1    — unweighted mean of per-class F1.
weighted-F1 — per-class F1 averaged with class-frequency weights.

Implemented in both NumPy (host evaluation) and jnp (on-device eval inside
jitted loops); no sklearn offline.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

try:  # jnp variant is optional at import time for host-only tooling
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None

__all__ = ["F1Report", "f1_scores", "f1_scores_jnp", "confusion_counts"]


@dataclass(frozen=True)
class F1Report:
    micro: float
    macro: float
    weighted: float
    per_class: np.ndarray
    support: np.ndarray

    def row(self) -> str:
        return f"micro={self.micro*100:.2f} macro={self.macro*100:.2f} weighted={self.weighted*100:.2f}"


def confusion_counts(
    preds: np.ndarray, labels: np.ndarray, num_classes: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(tp, fp, fn) per class, ignoring labels < 0.

    An out-of-range prediction (negative or >= num_classes) names no real
    class: it counts as a miss (fn on the true class) but contributes fp to
    NO class — the same rule f1_scores_jnp applies, so the two paths agree
    on adversarial inputs (np.add.at would otherwise wrap negatives and
    crash on >= num_classes).
    """
    valid = labels >= 0
    preds, labels = preds[valid], labels[valid]
    tp = np.zeros(num_classes)
    fp = np.zeros(num_classes)
    fn = np.zeros(num_classes)
    hit = preds == labels
    in_range = (preds >= 0) & (preds < num_classes)
    np.add.at(tp, labels[hit], 1.0)
    np.add.at(fp, preds[~hit & in_range], 1.0)
    np.add.at(fn, labels[~hit], 1.0)
    return tp, fp, fn


def f1_scores(preds: np.ndarray, labels: np.ndarray, num_classes: int) -> F1Report:
    preds = np.asarray(preds)
    labels = np.asarray(labels)
    tp, fp, fn = confusion_counts(preds, labels, num_classes)
    denom = 2 * tp + fp + fn
    per_class = np.where(denom > 0, 2 * tp / np.maximum(denom, 1e-12), 0.0)
    support = tp + fn
    total = support.sum()
    micro_den = 2 * tp.sum() + fp.sum() + fn.sum()
    micro = float(2 * tp.sum() / micro_den) if micro_den > 0 else 0.0
    present = support > 0
    macro = float(per_class[present].mean()) if present.any() else 0.0
    weighted = float((per_class * support).sum() / total) if total > 0 else 0.0
    return F1Report(micro=micro, macro=macro, weighted=weighted,
                    per_class=per_class, support=support)


def f1_scores_jnp(preds, labels, num_classes: int):
    """jnp micro/macro/weighted triple for on-device eval steps."""
    valid = labels >= 0
    safe_labels = jnp.maximum(labels, 0)
    hit = (preds == labels) & valid
    miss = (preds != labels) & valid
    # out-of-range preds are fn-only misses, matching confusion_counts: the
    # explicit in-range mask (not maximum/OOB-drop, which disagree between
    # the two ends of the range) keeps the scatter index always valid
    fp_ok = miss & (preds >= 0) & (preds < num_classes)
    safe_preds = jnp.clip(preds, 0, num_classes - 1)
    tp = jnp.zeros(num_classes).at[safe_labels].add(hit.astype(jnp.float32))
    fn = jnp.zeros(num_classes).at[safe_labels].add(miss.astype(jnp.float32))
    fp = jnp.zeros(num_classes).at[safe_preds].add(fp_ok.astype(jnp.float32))
    denom = 2 * tp + fp + fn
    per_class = jnp.where(denom > 0, 2 * tp / jnp.maximum(denom, 1e-12), 0.0)
    support = tp + fn
    micro = 2 * tp.sum() / jnp.maximum(2 * tp.sum() + fp.sum() + fn.sum(), 1e-12)
    present = (support > 0).astype(jnp.float32)
    macro = (per_class * present).sum() / jnp.maximum(present.sum(), 1.0)
    weighted = (per_class * support).sum() / jnp.maximum(support.sum(), 1.0)
    return micro, macro, weighted
