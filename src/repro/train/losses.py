"""Loss functions: cross-entropy, focal loss (artifact's macro-F1 companion
to CBS), and the GP proximal penalty (paper Eq. 4)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["cross_entropy_loss", "focal_loss", "prox_penalty", "multilabel_bce_loss"]

PyTree = Any


def cross_entropy_loss(
    logits: jnp.ndarray,
    labels: jnp.ndarray,
    mask: jnp.ndarray | None = None,
    label_smoothing: float = 0.0,
) -> jnp.ndarray:
    """Mean softmax cross-entropy over (optionally masked) examples.

    ``labels`` are int class ids; entries < 0 are treated as padding and
    excluded (on top of ``mask`` if given).
    """
    valid = labels >= 0
    if mask is not None:
        valid = valid & (mask > 0)
    safe_labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    if label_smoothing > 0.0:
        nll = (1.0 - label_smoothing) * nll - label_smoothing * logp.mean(axis=-1)
    w = valid.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def focal_loss(
    logits: jnp.ndarray,
    labels: jnp.ndarray,
    gamma: float = 2.0,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Focal loss FL = (1-p_t)^γ · CE — down-weights easy (majority-class)
    examples; the artifact pairs it with CBS to lift macro-F1."""
    valid = labels >= 0
    if mask is not None:
        valid = valid & (mask > 0)
    safe_labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    logpt = jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    pt = jnp.exp(logpt)
    fl = -jnp.power(1.0 - pt, gamma) * logpt
    w = valid.astype(jnp.float32)
    return jnp.sum(fl * w) / jnp.maximum(jnp.sum(w), 1.0)


def multilabel_bce_loss(
    logits: jnp.ndarray, targets: jnp.ndarray, mask: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Sigmoid BCE for multilabel graphs (the paper's Yelp benchmark)."""
    logits = logits.astype(jnp.float32)
    per = jnp.maximum(logits, 0) - logits * targets + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    per = per.mean(axis=-1)
    if mask is None:
        return per.mean()
    w = mask.astype(jnp.float32)
    return jnp.sum(per * w) / jnp.maximum(jnp.sum(w), 1.0)


def prox_penalty(personal_params: PyTree, global_params: PyTree) -> jnp.ndarray:
    """Eq. 4 regulariser: ‖W_P − W_G‖₂² summed over the whole pytree.

    ``global_params`` is the frozen phase-0 model (treated as a constant —
    callers should ``lax.stop_gradient`` it or simply not differentiate
    w.r.t. it, which is the default when it enters as a closure constant).
    """
    diffs = jax.tree.map(
        lambda p, g: jnp.sum(jnp.square(p.astype(jnp.float32) - g.astype(jnp.float32))),
        personal_params,
        global_params,
    )
    return sum(jax.tree_util.tree_leaves(diffs))
