from .optim import AdamW, OptState, SGDM
from .losses import cross_entropy_loss, focal_loss, prox_penalty
from .metrics import f1_scores, F1Report

__all__ = [
    "AdamW", "SGDM", "OptState",
    "cross_entropy_loss", "focal_loss", "prox_penalty",
    "f1_scores", "F1Report",
]
