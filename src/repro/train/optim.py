"""Minimal-yet-real optimizers as pure pytree transforms (optax is not
available offline; these mirror its update contract so they could be swapped
out 1:1).

Every optimizer is a dataclass of hyper-parameters with

    init(params)              -> OptState
    update(grads, state, params) -> (updates, new_state)

where ``updates`` are *deltas* to add to params.  All state is a pytree of
arrays so the whole thing jits, shards (the personalized phase vmaps a
leading partition axis straight through it) and checkpoints.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptState", "AdamW", "SGDM", "clip_by_global_norm", "global_norm"]

PyTree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree      # first moment  (zeros pytree for SGDM's momentum)
    nu: PyTree      # second moment (unused by SGDM)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree)


@dataclass(frozen=True)
class AdamW:
    """AdamW with decoupled weight decay and linear-warmup-constant LR."""

    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    warmup_steps: int = 0
    grad_clip: float | None = None

    def init(self, params: PyTree) -> OptState:
        zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())

    def _lr_at(self, step: jnp.ndarray) -> jnp.ndarray:
        if self.warmup_steps <= 0:
            return jnp.asarray(self.lr, jnp.float32)
        frac = jnp.minimum(1.0, (step + 1) / self.warmup_steps)
        return jnp.asarray(self.lr, jnp.float32) * frac

    def update(self, grads: PyTree, state: OptState, params: PyTree) -> tuple[PyTree, OptState]:
        if self.grad_clip is not None:
            grads = clip_by_global_norm(grads, self.grad_clip)
        step = state.step + 1
        lr = self._lr_at(state.step)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads
        )
        t = step.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1.0 - b1**t)
        nu_hat_scale = 1.0 / (1.0 - b2**t)

        def upd(m, v, p):
            u = -lr * (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + self.eps)
            if self.weight_decay:
                u = u - lr * self.weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, OptState(step=step, mu=mu, nu=nu)


@dataclass(frozen=True)
class SGDM:
    """SGD with momentum — used for cheap ablation baselines."""

    lr: float = 1e-2
    momentum: float = 0.9
    grad_clip: float | None = None

    def init(self, params: PyTree) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)

    def update(self, grads: PyTree, state: OptState, params: PyTree) -> tuple[PyTree, OptState]:
        if self.grad_clip is not None:
            grads = clip_by_global_norm(grads, self.grad_clip)
        mu = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32), state.mu, grads
        )
        updates = jax.tree.map(lambda m, p: (-self.lr * m).astype(p.dtype), mu, params)
        return updates, OptState(step=state.step + 1, mu=mu, nu=state.nu)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u, params, updates)
