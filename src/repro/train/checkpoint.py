"""Checkpointing: pytree <-> npz with path-keyed entries (+ best-model
bookkeeping for the GP phases: one global W^G, one W^P per partition)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]

_SEP = "::"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz cannot round-trip ml_dtypes; widen (load casts back)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_pytree(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    entries = _flatten(tree)
    np.savez(path, **entries)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2)


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Best-model tracking for GP training.

    Phase-0 keeps the best GLOBAL model (avg val micro-F1); phase-1 keeps the
    best PERSONAL model per partition (its own val micro-F1) — 'the best
    model is saved' per the paper, independently for each phase/host.
    """

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def save_global(self, params: Any, epoch: int, score: float) -> None:
        save_pytree(os.path.join(self.dir, "global_best.npz"), params,
                    meta={"epoch": epoch, "score": score, "phase": 0})

    def save_personal(self, partition: int, params: Any, epoch: int, score: float) -> None:
        save_pytree(os.path.join(self.dir, f"personal_{partition}_best.npz"), params,
                    meta={"epoch": epoch, "score": score, "phase": 1,
                          "partition": partition})

    def load_global(self, like: Any) -> Any:
        return load_pytree(os.path.join(self.dir, "global_best.npz"), like)

    def load_personal(self, partition: int, like: Any) -> Any:
        return load_pytree(os.path.join(self.dir, f"personal_{partition}_best.npz"), like)
