"""Checkpointing: pytree <-> npz with path-keyed entries (+ best-model
bookkeeping for the GP phases: one global W^G, one W^P per partition).

Durability contract (DESIGN.md §10):

  · **Atomic writes.**  ``save_pytree`` writes the npz to a tmp file in the
    target directory and publishes it with ``os.replace`` — a reader never
    observes a half-written archive, a crash mid-save leaves the previous
    checkpoint intact.  The sidecar ``<name>.npz.meta.json`` is written the
    same way, AFTER the arrays, so meta/array mismatch is detectable (CRC)
    rather than silent.
  · **Per-entry CRC.**  meta.json carries a crc32 per flattened entry;
    ``load_pytree`` verifies every entry it restores.  A truncated or
    bit-flipped file raises :class:`CheckpointCorruptError` NAMING the
    offending entry key — not a raw numpy zipfile traceback.
  · **Key diagnosis.**  A checkpoint whose entries don't match the ``like``
    template raises :class:`CheckpointKeyError` reporting the FULL missing
    and unexpected key sets in one message, so partial/foreign checkpoints
    are diagnosable at a glance.
  · **Dtype fidelity.**  bfloat16 leaves are widened to float32 on save
    (npz cannot round-trip ml_dtypes) and cast back on load — the round
    trip restores the exact bf16 payload.  A NumPy template leaf restores
    to a NumPy array of the template dtype (no silent f64→f32 downcast
    through jnp under x64-off), a JAX template leaf to a jnp array.
"""
from __future__ import annotations

import json
import os
import zipfile
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_pytree", "load_pytree", "CheckpointManager",
           "CheckpointCorruptError", "CheckpointKeyError"]

_SEP = "::"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file is unreadable or fails its integrity check."""


class CheckpointKeyError(RuntimeError):
    """Checkpoint entries do not match the restore template."""


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def _meta_path(path: str) -> str:
    return _npz_path(path) + ".meta.json"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz cannot round-trip ml_dtypes; widen (load casts back)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _atomic_write(path: str, write_fn) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def save_pytree(path: str, tree: Any, meta: dict | None = None) -> None:
    """Atomically persist ``tree``: tmp + ``os.replace`` for the npz, then
    the meta sidecar (caller meta under ``"meta"``, per-entry crc32 under
    ``"crc32"``)."""
    final = _npz_path(path)
    os.makedirs(os.path.dirname(final) or ".", exist_ok=True)
    entries = _flatten(tree)
    crcs = {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
            for k, v in entries.items()}
    _atomic_write(final, lambda f: np.savez(f, **entries))
    doc = json.dumps({"crc32": crcs, "meta": meta or {}}, indent=2)
    _atomic_write(_meta_path(path), lambda f: f.write(doc.encode()))


def load_meta(path: str) -> dict:
    """The caller-supplied meta dict saved alongside ``path`` ({} if none)."""
    mp = _meta_path(path)
    if not os.path.exists(mp):
        return {}
    with open(mp) as f:
        doc = json.load(f)
    # pre-PR-8 checkpoints stored the user meta at top level
    return doc.get("meta", doc) if isinstance(doc, dict) else {}


def _load_crcs(path: str) -> dict[str, int]:
    mp = _meta_path(path)
    if not os.path.exists(mp):
        return {}
    try:
        with open(mp) as f:
            doc = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointCorruptError(f"{mp}: unreadable meta sidecar ({e})")
    return doc.get("crc32", {}) if isinstance(doc, dict) else {}


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template).

    Raises :class:`CheckpointCorruptError` naming the offending entry on a
    truncated/bit-flipped archive or a CRC mismatch, and
    :class:`CheckpointKeyError` listing the full missing/unexpected key
    sets when the checkpoint doesn't match the template.
    """
    final = _npz_path(path)
    try:
        data = np.load(final)
        available = set(data.files)
    except (zipfile.BadZipFile, OSError, ValueError, EOFError, KeyError) as e:
        raise CheckpointCorruptError(f"{final}: unreadable archive ({e})")
    crcs = _load_crcs(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = [_SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
            for p, _ in flat]
    missing = sorted(set(keys) - available)
    unexpected = sorted(available - set(keys))
    if missing or unexpected:
        raise CheckpointKeyError(
            f"{final}: entries do not match template — "
            f"missing {missing or '[]'}, unexpected {unexpected or '[]'}")
    leaves = []
    for key, (p, leaf) in zip(keys, flat):
        try:
            arr = data[key]
        except (zipfile.BadZipFile, zlib.error, OSError, ValueError,
                EOFError) as e:
            raise CheckpointCorruptError(
                f"{final}: entry '{key}' is corrupt ({e})")
        if key in crcs and zlib.crc32(
                np.ascontiguousarray(arr).tobytes()) != crcs[key]:
            raise CheckpointCorruptError(
                f"{final}: entry '{key}' failed its crc32 integrity check")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        if isinstance(leaf, np.ndarray):
            leaves.append(arr.astype(leaf.dtype, copy=False))
        else:
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Best-model tracking for GP training.

    Phase-0 keeps the best GLOBAL model (avg val micro-F1); phase-1 keeps the
    best PERSONAL model per partition (its own val micro-F1) — 'the best
    model is saved' per the paper, independently for each phase/host.
    ``update_*`` persist only on a strict score improvement and return
    whether they saved; ``save_*`` persist unconditionally.
    """

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def _global_path(self) -> str:
        return os.path.join(self.dir, "global_best.npz")

    def _personal_path(self, partition: int) -> str:
        return os.path.join(self.dir, f"personal_{partition}_best.npz")

    def save_global(self, params: Any, epoch: int, score: float) -> None:
        save_pytree(self._global_path(), params,
                    meta={"epoch": epoch, "score": score, "phase": 0})

    def save_personal(self, partition: int, params: Any, epoch: int, score: float) -> None:
        save_pytree(self._personal_path(partition), params,
                    meta={"epoch": epoch, "score": score, "phase": 1,
                          "partition": partition})

    def global_meta(self) -> dict:
        return load_meta(self._global_path())

    def personal_meta(self, partition: int) -> dict:
        return load_meta(self._personal_path(partition))

    def update_global(self, params: Any, epoch: int, score: float) -> bool:
        prev = self.global_meta().get("score")
        if prev is not None and score <= prev:
            return False
        self.save_global(params, epoch, score)
        return True

    def update_personal(self, partition: int, params: Any, epoch: int,
                        score: float) -> bool:
        prev = self.personal_meta(partition).get("score")
        if prev is not None and score <= prev:
            return False
        self.save_personal(partition, params, epoch, score)
        return True

    def load_global(self, like: Any) -> Any:
        return load_pytree(self._global_path(), like)

    def load_personal(self, partition: int, like: Any) -> Any:
        return load_pytree(self._personal_path(partition), like)
