"""Partition-group streamed evaluation for the two-tier feature store
(DESIGN.md §12).

With ``feat_groups = G`` the stacked engine never materializes all P
assembled ``(max_nodes, D)`` feature planes at once: the eval runs as an
eager host-orchestrated loop that stages each partition's cold rows and
assembles its plane only while that partition's group is being processed.
Only layer 1 reads the raw feature planes, so the streaming is a two-pass
schedule over that layer:

  pass A   per group: assemble the group's planes, reduce each to its
           ``(P, maxS, D)`` halo SEND buffer (the all_to_all payload —
           tiny next to the plane), discard the planes;
  pass B   per group: re-assemble (the cold rows are staged a second
           time — the deliberate residency-for-traffic trade, counted),
           land the halo rows from the stored send buffers, run the
           layer-1 compute down to hidden width, discard the plane.

Layers >= 2 are hidden-width and run over all P partitions with the plain
explicit exchange.  Every op is the sequential reference's op
(``_exchange`` / ``_full_forward_plain`` / ``_eval``) in the same order on
bitwise-identical inputs (the featstore reconstruction invariant), so the
streamed eval is bit-for-bit the all-resident eval — locked in
tests/test_featstore.py.

Peak feature bytes: ``P*H*D*B + G*C*D*B + G*maxN*D*B``
(:func:`repro.graph.featstore.feat_peak_bytes` with ``groups=G``), which
is what lets a graph whose stacked plane exceeds the all-resident
footprint evaluate at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..graph.featstore import assemble_features

__all__ = ["StreamedEvaluator"]


class StreamedEvaluator:
    """Eager streamed eval over an engine built with ``feat_groups``."""

    def __init__(self, engine):
        self.engine = engine
        # per-partition views of the aggregation structure (fs_* entries are
        # consumed by the assembly itself, never by the forward)
        agg_shards = {k: v for k, v in engine.shards.items()
                      if not k.startswith("fs_")}
        self._shards = [jax.tree.map(lambda x: x[p], agg_shards)
                        for p in range(engine.num_parts)]

    # ---------------------------------------------------------- primitives
    def _assemble(self, p: int):
        """Partition p's full feature plane, cold rows staged host->device
        now (counted per staging — pass A and pass B each pay once)."""
        eng = self.engine
        cold_np = eng._fs.cold[p]
        self._cold_bytes += cold_np.nbytes
        return assemble_features(
            eng.shards["fs_hot"][p], eng.shards["fs_rows_hot"][p],
            jnp.asarray(cold_np), eng.shards["fs_rows_cold"][p],
            eng.max_nodes)

    def _exchange(self, hs: list) -> list:
        """The sequential reference's explicit halo exchange, verbatim:
        recv[q][p] = sent[p][q], scattered into each halo slot range."""
        eng = self.engine
        P = eng.num_parts
        send_idx = eng.shards["send_idx"]
        send_mask = eng.shards["send_mask"]
        sent = [hs[p][send_idx[p]] * send_mask[p][..., None]
                for p in range(P)]
        return [self._land(hs[q], sent, q) for q in range(P)]

    def _land(self, h, sent: list, q: int):
        """Scatter partition q's received rows into its halo slots."""
        eng = self.engine
        recv = jnp.stack([sent[p][q] for p in range(eng.num_parts)])
        flat_pos = eng.shards["recv_pos"][q].reshape(-1)
        flat_val = recv.reshape(-1, h.shape[-1])
        return h.at[flat_pos].set(flat_val.astype(h.dtype))

    def _layer(self, h, lp, p: int, activate: bool):
        eng = self.engine
        agg = eng._mean_agg(h, self._shards[p])
        out = h @ lp.w_self + agg @ lp.w_neigh + lp.b
        return jax.nn.relu(out) if activate else out

    # -------------------------------------------------------------- driver
    def evaluate(self, params, split: str, per_partition_params: bool):
        """``(micro (P,), preds (P, maxN), cold_h2d_bytes)`` for one eval."""
        eng = self.engine
        P = eng.num_parts
        G = int(eng.config.feat_groups)
        self._cold_bytes = 0
        plist = ([jax.tree.map(lambda x: x[p], params) for p in range(P)]
                 if per_partition_params else [params] * P)
        num_layers = len(plist[0].layers)
        send_idx = eng.shards["send_idx"]
        send_mask = eng.shards["send_mask"]

        # pass A: layer-1 send buffers from transiently assembled planes
        sent = [None] * P
        for g0 in range(0, P, G):
            for p in range(g0, min(g0 + G, P)):
                h = self._assemble(p)
                sent[p] = h[send_idx[p]] * send_mask[p][..., None]
                del h
        # pass B: re-assemble per group, land halo rows, layer-1 compute
        hs = [None] * P
        for g0 in range(0, P, G):
            for q in range(g0, min(g0 + G, P)):
                h = self._land(self._assemble(q), sent, q)
                hs[q] = self._layer(h, plist[q].layers[0], q, num_layers > 1)
                del h
        del sent
        # hidden-width layers: all partitions resident, plain schedule
        for i in range(1, num_layers):
            hs = self._exchange(hs)
            hs = [self._layer(hs[p], plist[p].layers[i], p,
                              i < num_layers - 1) for p in range(P)]

        micros, preds = [], []
        for p in range(P):
            pr = jnp.argmax(hs[p], axis=-1)
            micros.append(eng._micro_of(pr, eng.labels[p],
                                        eng.masks[split][p]))
            preds.append(pr)
        return jnp.stack(micros), jnp.stack(preds), self._cold_bytes
