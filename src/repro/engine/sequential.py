"""Sequential reference driver — the parity oracle for the SPMD engine.

Executes the EXACT math of :class:`repro.engine.spmd.SPMDEngine` as legible
Python loops over partitions: per-partition gradients in a loop, the
all-reduce as a deterministic stack-and-sum, the halo exchange as explicit
gather / transpose / scatter.  ``tests/test_engine_parity.py`` asserts the
fused engine reproduces this path's losses and micro-F1 bit-for-bit in
float64 — the self-verification the refactor ships with (DESIGN.md §3).

Aggregation always uses the jnp segment-op reference (kernels/ref.py math):
the Pallas kernel is validated against the same reference separately in
tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.gp.trainer import (GPHyperParams, GRAD_COMPRESS_MODES,
                               make_bucketed_reduce_stacked,
                               make_personalize_partition_step,
                               make_topk_reduce_stacked)
from ..graph.distributed import (HALO_COMPRESS_MODES, PartitionedGraph,
                                 dequantize_rows, halo_refresh_plan,
                                 make_ref_mean_agg, make_ref_split_agg,
                                 quantize_rows, wire_row_bytes)
from ..train.metrics import f1_scores_jnp
from ..train.optim import apply_updates

__all__ = ["SequentialReference"]


class SequentialReference:
    """Same public surface as SPMDEngine (phase0_epoch / phase1_epoch /
    evaluate), Python-loop execution."""

    mode = "sequential"

    def __init__(self, model, loss_fn, optimizer, pg: PartitionedGraph,
                 hp: GPHyperParams = GPHyperParams(), config=None):
        f = config.dtype if config is not None else jnp.float32
        self.model = model
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.num_parts = pg.num_parts
        self.num_classes = model.num_classes
        self.max_nodes = pg.max_nodes
        self.own_cap = pg.own_cap
        self.overlap = bool(getattr(config, "overlap_halo", False))
        self._fg_loss_kind = getattr(config, "fg_loss", "ce")
        # compressed communication (DESIGN.md §11), mirrored from the engine
        self.halo_compress = str(getattr(config, "halo_compress", "none"))
        self.grad_compress = str(getattr(config, "grad_compress", "none"))
        self._grad_topk_frac = float(getattr(config, "grad_topk_frac", 0.01))
        self._grad_bucket_kb = int(getattr(config, "grad_bucket_kb", 512))
        if self.halo_compress not in HALO_COMPRESS_MODES:
            raise ValueError(f"unknown halo_compress {self.halo_compress!r} "
                             f"(expected one of {HALO_COMPRESS_MODES})")
        if self.grad_compress not in GRAD_COMPRESS_MODES:
            raise ValueError(f"unknown grad_compress {self.grad_compress!r} "
                             f"(expected one of {GRAD_COMPRESS_MODES})")
        if self.halo_compress != "none" and self.overlap:
            raise ValueError(
                "halo_compress quantizes the gathered send buffer on the "
                "combined-edge eval forward; the overlap forward has no "
                "compressed spelling — pick one")
        if bool(getattr(config, "feat_store", False)):
            raise ValueError(
                "SequentialReference IS the all-resident oracle the "
                "feat-store engine is locked against; build it without "
                "feat_store (a feat-store DeviceEpochSampler is still "
                "accepted — its gather is bitwise the resident one)")
        self.features = jnp.asarray(pg.features, f)        # (P, maxN, D)
        self.send_idx = jnp.asarray(pg.send_idx)
        self.send_mask = jnp.asarray(pg.send_mask, f)
        self.recv_pos = jnp.asarray(pg.recv_pos)
        self.labels = jnp.asarray(pg.labels)
        self.masks = {
            "train": np.asarray(pg.train_mask),
            "val": np.asarray(pg.val_mask),
            "test": np.asarray(pg.test_mask),
        }
        # per-partition edge views for whichever forward this config runs:
        # either the combined-edge reference aggregation, or (overlap) the
        # destination-disjoint CSR shards + static degree + interior counts
        self.n_int = np.asarray(pg.n_int)
        if self.overlap:
            self._agg_int, self._agg_bnd = make_ref_split_agg(pg.own_cap)
            self._split_shards = [
                {"int_src": jnp.asarray(pg.int_src[p]),
                 "int_dst": jnp.asarray(pg.int_dst[p]),
                 "bnd_src": jnp.asarray(pg.bnd_src[p]),
                 "bnd_dst": jnp.asarray(pg.bnd_dst[p]),
                 "deg": jnp.asarray(pg.deg[p], f)}
                for p in range(pg.num_parts)
            ]
        else:
            self._agg = make_ref_mean_agg(pg.max_nodes)
            self._edge_shards = [
                {"edge_src": jnp.asarray(pg.edge_src[p]),
                 "edge_dst": jnp.asarray(pg.edge_dst[p]),
                 "edge_mask": jnp.asarray(pg.edge_mask[p], f)}
                for p in range(pg.num_parts)
            ]
        self.halo_cache = bool(getattr(config, "halo_cache", False))
        self.last_halo_exchange_bytes = 0
        if self.halo_cache:
            if self.overlap:
                raise ValueError(
                    "halo_cache and overlap_halo are alternative exchange "
                    "optimisations: the cache removes the very exchange the "
                    "overlap would hide — pick one")
            self.halo_refresh_every = int(getattr(config,
                                                  "halo_refresh_every", 1))
            self.halo_cv = bool(getattr(config, "halo_cv", False))
            self.max_send = pg.send_idx.shape[-1]
            self._halo_slot_counts = np.asarray(pg.send_mask).sum(axis=(0, 1))
            self._halo_byte_per_slot = wire_row_bytes(
                pg.features.shape[-1], self.halo_compress,
                pg.features.dtype.itemsize)
            # per-partition recv buffers, one per layer — the legible
            # rendering of the engine's stacked (P, P, maxS, D) cache state
            Pn = pg.num_parts
            self._halo_state = {
                f"h{i}": [jnp.zeros((Pn, self.max_send, d), f)
                          for _ in range(Pn)]
                for i, d in enumerate(model.layer_input_dims)}
            self._halo_age = 0
        self._halo_dtype = f
        self._halo_rows_total = int(pg.n_halo.sum())
        self._halo_row_width = pg.features.shape[-1]
        self._halo_itemsize = pg.features.dtype.itemsize
        if self.halo_compress != "none":
            # per-partition send-side quantization error, one (P, maxS, d)
            # buffer per sender per layer — the legible rendering of the
            # engine's stacked build_stacked_halo_residual state
            Pn = pg.num_parts
            ms = pg.send_idx.shape[-1]
            self._halo_residual = {
                f"r{i}": [jnp.zeros((Pn, ms, d), f) for _ in range(Pn)]
                for i, d in enumerate(model.layer_input_dims)}
        self._grad_res = None   # lazy (P, N) top-k error-feedback state
        self._grad_step = jax.jit(jax.value_and_grad(loss_fn))
        self._pstep1 = jax.jit(make_personalize_partition_step(
            loss_fn, optimizer, hp))
        self._device_sampler = None
        self.last_eval_seconds = 0.0   # wall time of the latest standalone
                                       # _eval (first call includes jit)

        # the all-reduce + optimizer update runs as ONE jitted function:
        # AdamW keeps float32 moments, and XLA's fused rounding of that
        # arithmetic differs from eager op-by-op dispatch at the last ulp —
        # jitting at this granularity is what makes the engine's in-scan
        # update bit-for-bit reproducible here (see test_engine_parity)
        P = pg.num_parts

        @jax.jit
        def _apply_avg(params, opt_state, grads_stacked):
            grads = jax.tree.map(lambda g: jnp.sum(g, axis=0) / P,
                                 grads_stacked)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state

        self._apply_avg = _apply_avg

        # compressed gradient syncs jit at the SAME granularity (reduce +
        # update in one function) for the fused-rounding parity above
        if self.grad_compress == "bucketed":
            red_b = make_bucketed_reduce_stacked(P, self._grad_bucket_kb * 1024)

            @jax.jit
            def _apply_bucketed(params, opt_state, grads_stacked):
                grads = red_b(grads_stacked)
                updates, opt_state = optimizer.update(grads, opt_state, params)
                return apply_updates(params, updates), opt_state

            self._apply_grads = _apply_bucketed
        else:
            self._apply_grads = _apply_avg
        if self.grad_compress == "topk":
            red_t = make_topk_reduce_stacked(P, self._grad_topk_frac)

            @jax.jit
            def _apply_topk(params, opt_state, grads_stacked, res):
                grads, res = red_t(grads_stacked, res)
                updates, opt_state = optimizer.update(grads, opt_state, params)
                return apply_updates(params, updates), opt_state, res

            self._apply_topk = _apply_topk

    # --------------------------------------------------------- forward pass
    def _exchange(self, hs: list) -> list:
        """Explicit halo exchange: recv[q][p] = sent[p][q] (the all_to_all
        transpose), scattered into each partition's halo slots."""
        P = self.num_parts
        sent = [hs[p][self.send_idx[p]] * self.send_mask[p][..., None]
                for p in range(P)]                     # each (P, maxS, D)
        out = []
        for q in range(P):
            recv = jnp.stack([sent[p][q] for p in range(P)])
            flat_pos = self.recv_pos[q].reshape(-1)
            flat_val = recv.reshape(-1, hs[q].shape[-1])
            out.append(hs[q].at[flat_pos].set(flat_val.astype(hs[q].dtype)))
        return out

    def _exchange_comp(self, hs: list, rkey: str) -> list:
        """Error-compensated quantized rendering of :meth:`_exchange` — the
        legible mirror of ``_ef_quantized_exchange``: per sender, fold last
        round's residual into the gathered send buffer, quantize, update
        ``self._halo_residual[rkey]``, then transpose and scatter the
        DEQUANTIZED rows.  Sender-side dequantization is bitwise the
        receiver's (elementwise, deterministic), so dequantizing before the
        transpose models the wire exactly."""
        P = self.num_parts
        mode = self.halo_compress
        res = self._halo_residual[rkey]
        deqs = []
        for p in range(P):
            m3 = self.send_mask[p][..., None]
            sent = hs[p][self.send_idx[p]] * m3
            sent_ef = (sent + res[p].astype(sent.dtype)) * m3
            payload, scale = quantize_rows(sent_ef, mode)
            deq = dequantize_rows(payload, scale, mode, sent.dtype)
            res[p] = ((sent_ef - deq) * m3).astype(res[p].dtype)
            deqs.append(deq)
        out = []
        for q in range(P):
            recv = jnp.stack([deqs[p][q] for p in range(P)])
            flat_pos = self.recv_pos[q].reshape(-1)
            flat_val = recv.reshape(-1, hs[q].shape[-1])
            out.append(hs[q].at[flat_pos].set(flat_val.astype(hs[q].dtype)))
        return out

    def _exchange_cached(self, hs: list, key: str, lo: int, hi: int) -> list:
        """Historical-cache variant of :meth:`_exchange`: land each
        partition's CACHED recv buffers into the halo slots, then exchange
        only send slots ``[lo, hi)`` live and overwrite both the halo rows
        and the cache with the refreshed values.  The full-refresh case
        skips the cache landing entirely, so its op sequence is exactly
        :meth:`_exchange` (the staleness-0 bitwise contract).  Mutates
        ``self._halo_state[key]``."""
        P = self.num_parts
        full = lo == 0 and hi == self.max_send
        cache = self._halo_state[key]
        if hi > lo:
            # gather BEFORE any cache landing (send_idx only ever points at
            # owned rows, and the engine's cached forward uses this order)
            sent = [hs[p][self.send_idx[p][:, lo:hi]]
                    * self.send_mask[p][:, lo:hi][..., None]
                    for p in range(P)]
            if self.halo_compress != "none":
                # quantize the refresh payload with error feedback on the
                # matching residual slot slice; downstream the cache stores
                # the dequantized rows, exactly as the engine's cached
                # forward does
                mode = self.halo_compress
                res = self._halo_residual["r" + key[1:]]
                for p in range(P):
                    m3 = self.send_mask[p][:, lo:hi][..., None]
                    r_sl = res[p][:, lo:hi]
                    sent_ef = (sent[p] + r_sl.astype(sent[p].dtype)) * m3
                    payload, scale = quantize_rows(sent_ef, mode)
                    deq = dequantize_rows(payload, scale, mode,
                                          sent[p].dtype)
                    res[p] = res[p].at[:, lo:hi].set(
                        ((sent_ef - deq) * m3).astype(res[p].dtype))
                    sent[p] = deq
        out = []
        for q in range(P):
            h = hs[q]
            if not full:
                h = h.at[self.recv_pos[q].reshape(-1)].set(
                    cache[q].reshape(-1, h.shape[-1]).astype(h.dtype))
            if hi > lo:
                recv = jnp.stack([sent[p][q] for p in range(P)])
                h = h.at[self.recv_pos[q][:, lo:hi].reshape(-1)].set(
                    recv.reshape(-1, h.shape[-1]).astype(h.dtype))
                cache[q] = cache[q].at[:, lo:hi].set(
                    recv.astype(cache[q].dtype))
            out.append(h)
        return out

    def _full_forward_cached(self, params_list: list) -> list:
        """The cached eval forward: same layer schedule as
        :meth:`_full_forward`, halo rows served from the historical cache
        with the refresh slot range chosen by :func:`halo_refresh_plan`.
        Ages the cache once per call and records the refreshed payload in
        ``last_halo_exchange_bytes``."""
        P = self.num_parts
        lo, hi = halo_refresh_plan(self._halo_age, self.halo_refresh_every,
                                   self.halo_cv, self.max_send)
        hs = [self.features[p] for p in range(P)]
        num_layers = len(params_list[0].layers)
        for i in range(num_layers):
            hs = self._exchange_cached(hs, f"h{i}", lo, hi)
            nxt = []
            for p in range(P):
                lp = params_list[p].layers[i]
                agg = self._agg(hs[p], self._edge_shards[p])
                out = hs[p] @ lp.w_self + agg @ lp.w_neigh + lp.b
                nxt.append(jax.nn.relu(out) if i < num_layers - 1 else out)
            hs = nxt
        real = int(self._halo_slot_counts[lo:hi].sum())
        self.last_halo_exchange_bytes = (num_layers * real
                                         * self._halo_byte_per_slot)
        self._halo_age += 1
        return hs

    def _full_forward_comp(self, params_list: list) -> list:
        """Quantized-exchange eval forward: the plain layer schedule with
        :meth:`_exchange_comp` carrying the per-layer residual.  Records the
        compressed wire payload in ``last_halo_exchange_bytes``."""
        P = self.num_parts
        hs = [self.features[p] for p in range(P)]
        num_layers = len(params_list[0].layers)
        for i in range(num_layers):
            hs = self._exchange_comp(hs, f"r{i}")
            nxt = []
            for p in range(P):
                lp = params_list[p].layers[i]
                agg = self._agg(hs[p], self._edge_shards[p])
                out = hs[p] @ lp.w_self + agg @ lp.w_neigh + lp.b
                nxt.append(jax.nn.relu(out) if i < num_layers - 1 else out)
            hs = nxt
        self.last_halo_exchange_bytes = (num_layers
                                         * self.halo_wire_bytes_per_layer)
        return hs

    def _full_forward(self, params_list: list) -> list:
        """Layer-synchronous n-layer GraphSAGE over all partitions — the same
        schedule the per-shard fwd runs, unrolled in Python."""
        if self.overlap:
            return self._full_forward_overlap(params_list)
        if self.halo_cache:
            return self._full_forward_cached(params_list)
        if self.halo_compress != "none":
            return self._full_forward_comp(params_list)
        return self._full_forward_plain(params_list)

    def _full_forward_plain(self, params_list: list) -> list:
        P = self.num_parts
        hs = [self.features[p] for p in range(P)]
        num_layers = len(params_list[0].layers)
        for i in range(num_layers):
            hs = self._exchange(hs)
            nxt = []
            for p in range(P):
                lp = params_list[p].layers[i]
                agg = self._agg(hs[p], self._edge_shards[p])
                out = hs[p] @ lp.w_self + agg @ lp.w_neigh + lp.b
                nxt.append(jax.nn.relu(out) if i < num_layers - 1 else out)
            hs = nxt
        return hs

    def _split_layer(self, hs: list, layers: list, activate: bool) -> list:
        """One boundary/interior split layer, unrolled in Python — the
        legible rendering of make_overlap_forward's schedule: interior
        aggregation and the self-term run on the pre-exchange embeddings
        (the work that hides the exchange), boundary aggregation on the
        post-exchange ones, and a bitwise-safe per-row select joins them."""
        P, oc = self.num_parts, self.own_cap
        agg_i = [self._agg_int(hs[p], self._split_shards[p]) for p in range(P)]
        self_t = [hs[p][:oc] @ layers[p].w_self for p in range(P)]
        hs = self._exchange(hs)
        outs = []
        for p in range(P):
            agg_b = self._agg_bnd(hs[p], self._split_shards[p])
            rows = jnp.arange(oc)[:, None]
            agg = jnp.where(rows < int(self.n_int[p]), agg_i[p], agg_b)
            out = self_t[p] + agg @ layers[p].w_neigh + layers[p].b
            if activate:
                out = jax.nn.relu(out)
            # owned rows back into the padded local space; trash row stays 0
            outs.append(jnp.zeros((self.max_nodes, out.shape[-1]),
                                  out.dtype).at[:oc].set(out))
        return outs

    def _full_forward_overlap(self, params_list: list) -> list:
        P = self.num_parts
        hs = [self.features[p] for p in range(P)]
        num_layers = len(params_list[0].layers)
        for i in range(num_layers):
            hs = self._split_layer(hs, [p.layers[i] for p in params_list],
                                   i < num_layers - 1)
        return hs

    def _eval(self, params_list: list, split: str):
        import time

        t0 = time.perf_counter()
        logits = self._full_forward(params_list)
        micros, preds = [], []
        for p in range(self.num_parts):
            pr = jnp.argmax(logits[p], axis=-1)
            lab = jnp.where(jnp.asarray(self.masks[split][p]),
                            self.labels[p], -1)
            micro, _, _ = f1_scores_jnp(pr, lab, self.num_classes)
            micros.append(micro)
            preds.append(pr)
        out = jnp.stack(micros), jnp.stack(preds)
        jax.block_until_ready(out)
        self.last_eval_seconds = time.perf_counter() - t0
        return out

    # ------------------------------------------------------- public surface
    def phase0_epoch(self, params, opt_state, batches):
        import time

        P = self.num_parts
        leaves = jax.tree_util.tree_leaves(batches)
        iters = leaves[0].shape[0]
        # warm the jit caches on the first iteration's shapes (results
        # discarded — the functions are pure) so the timed window below
        # excludes XLA compilation, matching the SPMD engine's AOT contract
        b0 = jax.tree.map(lambda x: x[0, 0], batches)
        _, g0 = self._grad_step(params, b0)
        z = jax.tree.map(lambda g: jnp.stack([g] * P), g0)
        topk = self.grad_compress == "topk"
        if topk:
            res = self._grad_residual(params)
            jax.block_until_ready(self._apply_topk(params, opt_state, z, res))
        else:
            jax.block_until_ready(self._apply_grads(params, opt_state, z))

        t0 = time.perf_counter()
        all_losses = []
        for it in range(iters):
            losses, grads = [], []
            for p in range(P):
                b = jax.tree.map(lambda x: x[it, p], batches)
                l, g = self._grad_step(params, b)
                losses.append(l)
                grads.append(g)
            # deterministic all-reduce (stack then axis-0 sum, / P — the same
            # reduction the stacked engine performs) + jitted update
            stacked = jax.tree.map(lambda *gs: jnp.stack(gs), *grads)
            if topk:
                params, opt_state, res = self._apply_topk(
                    params, opt_state, stacked, res)
            else:
                params, opt_state = self._apply_grads(params, opt_state,
                                                      stacked)
            all_losses.append(jnp.stack(losses))
        jax.block_until_ready(params)
        dt = time.perf_counter() - t0
        if topk:
            self._grad_res = res
        val_micro, _ = self._eval([params] * P, "val")
        return params, opt_state, jnp.stack(all_losses), val_micro, dt

    def phase0_epoch_async(self, params, opt_state, keys):
        """Python-loop reference for the fused on-device generalization
        epoch: the SAME per-partition PRNG programs (epoch draw, fanout
        sampling, feature gather) executed one partition at a time, the
        all-reduce as the deterministic stack-and-sum, and the validation
        eval as the explicit Python-loop forward — the parity oracle for
        SPMDEngine.phase0_epoch_async (DESIGN.md §7)."""
        import time

        if self._device_sampler is None:
            raise ValueError("phase0_epoch_async needs set_device_sampler()")
        ds = self._device_sampler
        # a feat-store sampler gathers through [hot | staged cold]; pass its
        # host cold table exactly when the sampler was built with the store
        ck = ({} if getattr(ds, "cold_host", None) is None
              else {"cold": jnp.asarray(ds.cold_host)})
        P = self.num_parts
        iters = ds.num_batches
        # per-partition epoch draws, in the engine's exact key order:
        # kd (draw) then ke split into per-iteration batch keys
        drawn = []
        for p in range(P):
            kd, ke = jax.random.split(keys[p])
            nodes, valid = ds.draw_epoch(kd, ds.logp[p], ds.train_idx[p],
                                         ds.k[p])
            drawn.append((nodes, valid, jax.random.split(ke, iters)))
        # warm the jit caches on the first iteration's shapes (results
        # discarded — the functions are pure) so the timed window excludes
        # XLA compilation, matching the engine's AOT contract
        b0 = ds.make_batch(drawn[0][2][0], drawn[0][0][0], drawn[0][1][0],
                           **ck)
        _, g0 = self._grad_step(params, b0)
        z = jax.tree.map(lambda g: jnp.stack([g] * P), g0)
        topk = self.grad_compress == "topk"
        if topk:
            res = self._grad_residual(params)
            jax.block_until_ready(self._apply_topk(params, opt_state, z, res))
        else:
            jax.block_until_ready(self._apply_grads(params, opt_state, z))

        t0 = time.perf_counter()
        all_losses = []
        for it in range(iters):
            losses, grads = [], []
            for p in range(P):
                nodes, valid, iter_keys = drawn[p]
                b = ds.make_batch(iter_keys[it], nodes[it], valid[it], **ck)
                l, g = self._grad_step(params, b)
                losses.append(l)
                grads.append(g)
            stacked = jax.tree.map(lambda *gs: jnp.stack(gs), *grads)
            if topk:
                params, opt_state, res = self._apply_topk(
                    params, opt_state, stacked, res)
            else:
                params, opt_state = self._apply_grads(params, opt_state,
                                                      stacked)
            all_losses.append(jnp.stack(losses))
        if topk:
            self._grad_res = res
        # the fused program's eval is part of the one device call: include
        # it in the timed window (unlike phase0_epoch, whose eval is a
        # separate call excluded from the train timing)
        val_micro, _ = self._eval([params] * P, "val")
        jax.block_until_ready(val_micro)
        dt = time.perf_counter() - t0
        self.last_eval_seconds = 0.0    # eval is inside dt on this path
        return params, opt_state, jnp.stack(all_losses), val_micro, dt

    def phase0_fullgraph_epoch(self, params, opt_state, iters: int = 1):
        """Full-graph phase-0, legibly: partition p's loss is the train-mask
        cross-entropy of ITS rows of the full multi-partition forward (the
        same Python-loop forward `_eval` uses), differentiated with plain
        ``jax.grad`` — the parity oracle for the engines' fused
        ``value_and_grad`` through the halo exchange and the aggregation
        op's custom VJP."""
        import time

        from functools import partial

        if self.halo_cache:
            raise ValueError(
                "halo_cache is an eval-forward optimisation; full-graph "
                "training differentiates through the live halo exchange "
                "and cannot train against stale cached embeddings")
        if self.grad_compress == "topk":
            raise ValueError(
                "top-k gradient sparsification is a sampled phase-0 feature; "
                "full-graph training keeps the exact (or bucketed) all-reduce")

        from ..train.losses import cross_entropy_loss, focal_loss

        P = self.num_parts
        if not hasattr(self, "_fg_step"):
            labels = self.labels
            train_m = jnp.asarray(self.masks["train"])
            base_loss = (partial(focal_loss, gamma=2.0)
                         if self._fg_loss_kind == "focal"
                         else cross_entropy_loss)
            # training differentiates through the LIVE uncompressed exchange
            # even when halo_compress is on (the engine's self.fwd does the
            # same); only eval forwards quantize
            fg_fwd = (self._full_forward_overlap if self.overlap
                      else self._full_forward_plain)

            def loss_p(prm, p):
                logits = fg_fwd([prm] * P)
                return base_loss(logits[p], labels[p], mask=train_m[p])

            @jax.jit
            def fg_step(params, opt_state):
                losses, grads = [], []
                for p in range(P):
                    l, g = jax.value_and_grad(loss_p)(params, p)
                    losses.append(l)
                    grads.append(g)
                stacked = jax.tree.map(lambda *gs: jnp.stack(gs), *grads)
                # inner jit inlines under this trace: same fused arithmetic
                params, opt_state = self._apply_grads(params, opt_state,
                                                      stacked)
                return params, opt_state, jnp.stack(losses)

            self._fg_step = fg_step

        # compile warm-up outside the timed window (pure, result discarded)
        jax.block_until_ready(self._fg_step(params, opt_state))
        t0 = time.perf_counter()
        all_losses = []
        for _ in range(iters):
            params, opt_state, losses = self._fg_step(params, opt_state)
            all_losses.append(losses)
        jax.block_until_ready(params)
        dt = time.perf_counter() - t0
        val_micro, _ = self._eval([params] * P, "val")
        return params, opt_state, jnp.stack(all_losses), val_micro, dt

    def phase1_epoch(self, pparams, popt, batches, global_params, budgets):
        import time

        P = self.num_parts
        leaves = jax.tree_util.tree_leaves(batches)
        iters = leaves[0].shape[0]
        budgets = np.asarray(budgets)
        if budgets.dtype == bool:        # pre-async API: full epoch or zero
            budgets = np.where(budgets, iters, 0)
        pp = [jax.tree.map(lambda x: x[p], pparams) for p in range(P)]
        po = [jax.tree.map(lambda x: x[p], popt) for p in range(P)]
        # compile warm-up outside the timed window (pure, results discarded)
        jax.block_until_ready(self._pstep1(
            pp[0], po[0], jax.tree.map(lambda x: x[0, 0], batches),
            global_params, jnp.asarray(budgets[0] > 0)))

        t0 = time.perf_counter()
        all_losses = []
        for it in range(iters):
            losses = []
            for p in range(P):
                b = jax.tree.map(lambda x: x[it, p], batches)
                # the masked scan's semantics, legibly: partition p trains
                # while it < its own budget, is frozen bitwise afterwards
                pp[p], po[p], l = self._pstep1(pp[p], po[p], b, global_params,
                                              jnp.asarray(it < budgets[p]))
                losses.append(l)
            all_losses.append(jnp.stack(losses))
        jax.block_until_ready(pp)
        dt = time.perf_counter() - t0
        val_micro, _ = self._eval(pp, "val")
        from .stacking import stack_pytrees
        return (stack_pytrees(pp), stack_pytrees(po),
                jnp.stack(all_losses), val_micro, dt)

    # ----------------------------------------------- async personalization
    def set_device_sampler(self, sampler) -> None:
        self._device_sampler = sampler

    def phase1_epoch_async(self, pparams, popt, keys, budgets, global_params):
        """Python-loop reference for the on-device async path: the SAME
        per-partition PRNG programs (mini-epoch draw, fanout sampling,
        feature gather), executed one partition at a time — the parity
        oracle for SPMDEngine.phase1_epoch_async."""
        import time

        if self._device_sampler is None:
            raise ValueError("phase1_epoch_async needs set_device_sampler()")
        ds = self._device_sampler
        ck = ({} if getattr(ds, "cold_host", None) is None
              else {"cold": jnp.asarray(ds.cold_host)})
        P = self.num_parts
        budgets = np.asarray(budgets)
        iters = ds.num_batches
        pp = [jax.tree.map(lambda x: x[p], pparams) for p in range(P)]
        po = [jax.tree.map(lambda x: x[p], popt) for p in range(P)]

        t0 = time.perf_counter()
        all_losses = []
        for p in range(P):
            kd, ke = jax.random.split(keys[p])
            nodes, valid = ds.draw_epoch(kd, ds.logp[p], ds.train_idx[p],
                                         ds.k[p])
            iter_keys = jax.random.split(ke, iters)
            losses = []
            for it in range(iters):
                batch = ds.make_batch(iter_keys[it], nodes[it], valid[it],
                                      **ck)
                pp[p], po[p], l = self._pstep1(
                    pp[p], po[p], batch, global_params,
                    jnp.asarray(it < budgets[p]))
                losses.append(l)
            all_losses.append(jnp.stack(losses))
        jax.block_until_ready(pp)
        dt = time.perf_counter() - t0
        val_micro, _ = self._eval(pp, "val")
        from .stacking import stack_pytrees
        return (stack_pytrees(pp), stack_pytrees(po),
                jnp.stack(all_losses, axis=1), val_micro, dt)

    def evaluate(self, params, split: str = "test",
                 per_partition_params: bool = True):
        P = self.num_parts
        if per_partition_params:
            plist = [jax.tree.map(lambda x: x[p], params) for p in range(P)]
        else:
            plist = [params] * P
        return self._eval(plist, split)

    # ---- checkpoint/resume surface (mirrors SPMDEngine) ------------------
    def halo_cache_state(self):
        """(cache pytree, age) for checkpointing; None without the cache."""
        if not self.halo_cache:
            return None
        return self._halo_state, self._halo_age

    def restore_halo_cache_state(self, state, age: int) -> None:
        if not self.halo_cache:
            raise ValueError("engine built without halo_cache")
        self._halo_state = jax.tree.map(
            lambda x: jnp.asarray(x, self._halo_dtype), state)
        self._halo_age = int(age)

    # -------------------------------------- compressed communication state
    @property
    def halo_wire_bytes_per_layer(self) -> int:
        """Real payload bytes ONE layer's halo exchange puts on the wire
        under the configured compression (mirrors SPMDEngine)."""
        return self._halo_rows_total * wire_row_bytes(
            self._halo_row_width, self.halo_compress, self._halo_itemsize)

    def _grad_residual(self, params):
        """Lazily-built (P, N) top-k error-feedback state, zero before the
        first compressed sync (mirrors SPMDEngine)."""
        if self._grad_res is None:
            from jax.flatten_util import ravel_pytree

            flat, _ = ravel_pytree(params)
            self._grad_res = jnp.zeros((self.num_parts, flat.shape[0]),
                                       flat.dtype)
        return self._grad_res

    def comm_residual_state(self):
        """``(halo_residual, grad_residual)`` for checkpointing; each entry
        None when the matching compression is off (or, for top-k, before
        the first phase-0 step).  None when neither exists."""
        h = self._halo_residual if self.halo_compress != "none" else None
        g = self._grad_res if self.grad_compress == "topk" else None
        if h is None and g is None:
            return None
        return h, g

    def restore_comm_residual_state(self, state) -> None:
        h, g = state
        if h is not None:
            self._halo_residual = jax.tree.map(
                lambda x: jnp.asarray(x, self._halo_dtype), h)
        if g is not None:
            self._grad_res = jnp.asarray(g)
