"""jax version compatibility shims for the SPMD engine.

The repo targets the jax_pallas toolchain baked into this container
(jax 0.4.x) while staying importable on newer lines where ``shard_map``
graduated out of ``jax.experimental`` and its replication-check kwarg was
renamed (``check_rep`` -> ``check_vma``).
"""
from __future__ import annotations

import jax

__all__ = ["shard_map_compat"]


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across jax versions.

    Checking is disabled because the engine's phase-0 outputs are replicated
    *by construction* (pmean'd grads -> identical updates) which older
    checkers cannot prove through ``lax.scan``.
    """
    sm = _resolve_shard_map()
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
