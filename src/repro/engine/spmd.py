"""SPMD execution engine for the EAT pipeline (DESIGN.md §3).

Fused epoch steps instead of a Python loop over partitions: every
partition's graph shard, blocked aggregation structure and minibatch stream
is stacked into ``(P, ...)`` arrays, and each epoch executes as two
compiled calls — one trace scanning ALL training iterations (with the
cross-partition gradient mean inside the scan), one trace for the
full-graph validation forward with its per-layer halo ``all_to_all``
(compiled separately so the pipeline can time training without eval cost;
see DESIGN.md §3).

Three execution modes share one per-shard program:

  spmd        ``shard_map`` over a 1-D partition mesh — one partition per
              device, real collectives.  Picked by ``auto`` when the host
              exposes >= P devices.
  stacked     single-device fallback: the SAME per-shard function under
              ``vmap(axis_name=...)``; jax batches ``lax.all_to_all`` /
              ``lax.pmean`` across the vmapped axis with identical
              semantics, so the program is bit-compatible with the mesh
              version while running on one chip.
  sequential  legible Python-loop reference (sequential.py) — the parity
              oracle for tests/test_engine_parity.py and the numerically
              faithful descendant of the original per-partition driver.

GraphSAGE's full-graph mean aggregation routes through the Pallas
``segment_agg`` kernel (``use_pallas_agg=True``) with the jnp segment-op
reference as interpret-mode fallback.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.gp.trainer import (GPHyperParams, GRAD_COMPRESS_MODES,
                               grad_topk_size, make_bucketed_reduce_shard,
                               make_bucketed_reduce_stacked,
                               make_fullgraph_loss_fn,
                               make_personalize_partition_step,
                               make_personalize_step,
                               make_topk_reduce_shard,
                               make_topk_reduce_stacked)
from ..graph.distributed import (HALO_COMPRESS_MODES, PartitionedGraph,
                                 halo_refresh_plan,
                                 make_cached_forward, make_distributed_forward,
                                 make_export_forward,
                                 make_overlap_forward, make_pallas_mean_agg,
                                 make_pallas_split_agg, make_ref_mean_agg,
                                 make_ref_split_agg, wire_row_bytes)
from ..graph.featstore import (assemble_features, check_feat_budget,
                               feat_peak_bytes, reconstruct_features)
from ..train.metrics import f1_scores_jnp
from ..train.optim import apply_updates
from .compat import shard_map_compat
from .stacking import (build_stacked_feat_store, build_stacked_halo_cache,
                       build_stacked_halo_residual,
                       build_stacked_split_vjp_blocks,
                       build_stacked_vjp_blocks, stack_pytrees)

__all__ = ["AXIS", "EngineConfig", "SPMDEngine", "stack_epoch_batches"]

AXIS = "parts"


@dataclass(frozen=True)
class EngineConfig:
    mode: str = "auto"              # auto | spmd | stacked | sequential
    use_pallas_agg: bool = True     # route eval aggregation through Pallas
    interpret: bool = True          # Pallas interpret mode (CPU container)
    dtype: Any = jnp.float32        # float dtype of graph features
    # boundary/interior split forward: overlap the halo exchange with
    # interior aggregation + the self-term matmul, and restrict dense
    # compute to owned rows (DESIGN.md §5)
    overlap_halo: bool = False
    # 0 = one all_to_all; >= 1 = ppermute ring with that many chunks per
    # step (per-chunk sends interleave on a real mesh; bit-identical data)
    ring_chunks: int = 0
    # objective of the FULL-GRAPH phase-0 mode (the sampled path's loss is
    # the loss_fn the engine is constructed with): "ce" | "focal"
    fg_loss: str = "ce"
    # historical-embedding halo cache (DESIGN.md §8): eval forwards
    # aggregate against the last-received boundary embeddings and only pay
    # the exchange on the halo_refresh_every cadence; halo_cv refreshes a
    # rotating slot chunk on cached epochs (the VR-GCN control-variate
    # delta) instead of going fully stale between refreshes
    halo_cache: bool = False
    halo_refresh_every: int = 1
    halo_cv: bool = False
    # compressed communication (DESIGN.md §11): quantized halo exchange on
    # the eval forwards ("none" | "fp16" | "int8", error-compensated via a
    # carried send-side residual) and the phase-0 gradient all-reduce
    # spelling ("none" | "bucketed" | "topk"); compression off is bit-for-
    # bit today's traces by construction
    halo_compress: str = "none"
    grad_compress: str = "none"
    grad_topk_frac: float = 0.01    # fraction of entries top-k ships
    grad_bucket_kb: int = 512       # bucketed psum slice size
    # two-tier feature store (DESIGN.md §12): keep only the hot_frac
    # highest-scoring owned feature rows resident per partition; cold rows
    # live in host numpy and are staged as compiled-call arguments — every
    # trace reassembles the full plane bitwise before the forward runs
    feat_store: bool = False
    hot_frac: float = 0.5
    hot_policy: str = "degree"      # degree | freq (see graph/featstore.py)
    # partition-group streaming (0 = off): evaluate in groups of G <= P
    # partitions so no (P, maxN, D) feature stack ever materializes —
    # the bigger-than-device path; requires feat_store, stacked mode
    feat_groups: int = 0
    # feature-memory budget in MB (0 = unchecked): the engine refuses to
    # build a configuration whose closed-form peak device feature bytes
    # exceed it (FeatureBudgetError) instead of OOMing mid-epoch
    feat_budget_mb: float = 0.0


def _resolve_mode(mode: str, num_parts: int) -> str:
    if mode != "auto":
        return mode
    if num_parts > 1 and len(jax.devices()) >= num_parts:
        return "spmd"
    return "stacked"


def stack_epoch_batches(samplers, make_batch: Callable, num_parts: int):
    """Draw one epoch of minibatches from every host's sampler and stack them
    into ``(iters, P, ...)`` arrays for the fused epoch step.

    Mirrors the original driver's schedule exactly: ``iters`` is the longest
    host's batch count and shorter hosts wrap around (``it % len``).  Returns
    ``(batches, host_seconds, iters)`` where ``host_seconds[p]`` is the
    host-side sampling/gather time attributed to partition p (the DistDGL
    CPU-worker cost the paper's epoch times include).
    """
    import time

    host_batches = [s.batches() for s in samplers]
    iters = max(len(b) for b in host_batches)
    t_host = np.zeros(num_parts)
    rows = []
    for it in range(iters):
        per_p = []
        for p in range(num_parts):
            hb = host_batches[p]
            nodes = hb[it % len(hb)]
            t0 = time.perf_counter()
            per_p.append(make_batch(nodes))
            t_host[p] += time.perf_counter() - t0
        rows.append(stack_pytrees(per_p))          # (P, ...)
    return stack_pytrees(rows), t_host, iters      # (iters, P, ...)


class SPMDEngine:
    """Fused-epoch executor over a stacked :class:`PartitionedGraph`.

    Public surface (identical across modes; see sequential.py for the
    reference implementation):

      phase0_epoch(params, opt_state, batches) ->
          (params, opt_state, losses (I, P), val_micro (P,))
      phase0_epoch_async(params, opt_state, keys) ->
          (params, opt_state, losses (I, P), val_micro (P,))
      phase1_epoch(pparams, popt, batches, global_params, budgets) ->
          (pparams, popt, losses (I, P), val_micro (P,))
      phase1_epoch_async(pparams, popt, keys, budgets, global_params) ->
          (pparams, popt, losses (i_run, P), val_micro (P,))
      evaluate(params_or_pparams, split) -> (micro (P,), preds (P, maxN))

    ``budgets`` is a per-partition iteration budget (int32, (P,)); a bool
    ``active`` vector is accepted and promoted to full-epoch-or-zero.  The
    async variants need :meth:`set_device_sampler` and run the epoch draw +
    fanout sampling + feature gather on the epoch trace (DESIGN.md §4, §7);
    ``phase0_epoch_async`` additionally fuses the validation eval forward
    into the SAME compiled call, so a generalization epoch is one
    host→device round-trip.
    """

    def __init__(self, model, loss_fn, optimizer, pg: PartitionedGraph,
                 hp: GPHyperParams = GPHyperParams(),
                 config: EngineConfig = EngineConfig()):
        self.model = model
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.hp = hp
        self.config = config
        self.num_parts = pg.num_parts
        self.num_classes = model.num_classes
        self.max_nodes = pg.max_nodes
        self.mode = _resolve_mode(config.mode, pg.num_parts)
        if config.feat_groups:
            if not config.feat_store:
                raise ValueError(
                    "feat_groups streams the feat-store cold tier over "
                    "partition groups; enable feat_store to use it")
            if not 1 <= config.feat_groups <= pg.num_parts:
                raise ValueError(
                    f"feat_groups must be in [1, num_parts], got "
                    f"{config.feat_groups}")
            if config.mode == "spmd":
                raise ValueError(
                    "feat_groups is a host-orchestrated streaming eval over "
                    "partition groups; the one-partition-per-device mesh "
                    "needs all planes at once — use stacked mode")
            if (config.halo_cache or config.overlap_halo
                    or config.halo_compress != "none"):
                raise ValueError(
                    "feat_groups streams the eval through the plain "
                    "sequential exchange; it has no cached/compressed/"
                    "overlapped spelling — pick one")
            # "auto" must not pick spmd: the streamed eval is stacked-only
            self.mode = "stacked"

        if config.halo_compress not in HALO_COMPRESS_MODES:
            raise ValueError(f"unknown halo_compress {config.halo_compress!r} "
                             f"(expected one of {HALO_COMPRESS_MODES})")
        if config.grad_compress not in GRAD_COMPRESS_MODES:
            raise ValueError(f"unknown grad_compress {config.grad_compress!r} "
                             f"(expected one of {GRAD_COMPRESS_MODES})")
        if config.halo_compress != "none" and config.overlap_halo:
            raise ValueError(
                "halo_compress quantizes the gathered send buffer on the "
                "combined-edge eval forward; the overlap forward has no "
                "compressed spelling — pick one")
        self.halo_compress = config.halo_compress
        self.grad_compress = config.grad_compress
        # wire accounting basis: real halo rows per layer and the payload
        # dtype's itemsize (never a hardcoded 4)
        self._halo_rows_total = int(pg.n_halo.sum())
        self._halo_row_width = pg.features.shape[-1]
        self._halo_itemsize = pg.features.dtype.itemsize

        f = config.dtype
        self.feat_store = bool(config.feat_store)
        # host->device bytes spent staging cold feature rows (counted where
        # the numpy staging buffer is handed to a compiled call); stays 0
        # all-resident and at hot_frac=1.0 (zero-size cold tier)
        self.cold_h2d_bytes = 0
        self._fs = None
        self._cold_host = None
        self._streamer = None
        self.shards = {
            "send_idx": jnp.asarray(pg.send_idx),
            "send_mask": jnp.asarray(pg.send_mask, f),
            "recv_pos": jnp.asarray(pg.recv_pos),
        }
        if self.feat_store:
            entries, self._fs = build_stacked_feat_store(
                pg, config.hot_frac, config.hot_policy, f)
            self.shards.update(entries)
            self._cold_host = self._fs.cold
        else:
            self.shards["features"] = jnp.asarray(pg.features, f)
        check_feat_budget(config.feat_budget_mb, self._feat_peak_bytes(pg),
                          context=f"mode={self.mode}")
        def _as_blk(d: dict) -> dict:
            # one nested pytree per segment_mean_op call site: int arrays
            # stay int32, float structure follows the feature dtype
            return {k: jnp.asarray(v, f) if v.dtype == np.float32
                    else jnp.asarray(v) for k, v in d.items()}

        if config.overlap_halo:
            # split forward state: the per-partition interior row count plus
            # ONE aggregation backend's structures (the other is never read)
            self.shards["n_int"] = jnp.asarray(pg.n_int, jnp.int32)
            if config.use_pallas_agg:
                bi, bb = build_stacked_split_vjp_blocks(pg)
                self.shards["blk_int"] = _as_blk(bi)
                self.shards["blk_bnd"] = _as_blk(bb)
            else:
                self.shards.update({
                    "int_src": jnp.asarray(pg.int_src),
                    "int_dst": jnp.asarray(pg.int_dst),
                    "bnd_src": jnp.asarray(pg.bnd_src),
                    "bnd_dst": jnp.asarray(pg.bnd_dst),
                    "deg": jnp.asarray(pg.deg, f),
                })
        else:
            self.shards.update({
                "edge_src": jnp.asarray(pg.edge_src),
                "edge_dst": jnp.asarray(pg.edge_dst),
                "edge_mask": jnp.asarray(pg.edge_mask, f),
            })
            if config.use_pallas_agg:
                self.shards["blk"] = _as_blk(build_stacked_vjp_blocks(pg))
        self.labels = jnp.asarray(pg.labels)
        self.masks = {
            "train": jnp.asarray(pg.train_mask),
            "val": jnp.asarray(pg.val_mask),
            "test": jnp.asarray(pg.test_mask),
        }

        meta = {"max_nodes": pg.max_nodes, "own_cap": pg.own_cap}
        self._fwd_meta = meta
        if config.overlap_halo:
            if config.halo_cache:
                raise ValueError(
                    "halo_cache and overlap_halo are alternative exchange "
                    "optimisations: the cache removes the very exchange the "
                    "overlap would hide — pick one")
            aggs = (make_pallas_split_agg(pg.own_cap, interpret=config.interpret)
                    if config.use_pallas_agg else make_ref_split_agg(pg.own_cap))
            self.fwd = make_overlap_forward(
                model, meta, axis_name=AXIS, agg_interior=aggs[0],
                agg_boundary=aggs[1], ring_chunks=config.ring_chunks)
        else:
            agg = (make_pallas_mean_agg(pg.max_nodes, interpret=config.interpret)
                   if config.use_pallas_agg else make_ref_mean_agg(pg.max_nodes))
            self._mean_agg = agg
            self.fwd = make_distributed_forward(model, meta, axis_name=AXIS,
                                                agg=agg)
            if config.halo_compress != "none":
                # the compressed eval forward; self.fwd stays uncompressed
                # (full-graph training differentiates through the live
                # exchange, and the serving export needs exact embeddings)
                self._fwd_comp = make_distributed_forward(
                    model, meta, axis_name=AXIS, agg=agg,
                    compress=config.halo_compress,
                    ring_chunks=config.ring_chunks)
        if self.halo_compress != "none":
            self._halo_residual = jax.tree.map(
                lambda x: jnp.asarray(x, f),
                build_stacked_halo_residual(pg, model.layer_input_dims))
        self._grad_res = None   # lazy (P, N) top-k error-feedback state
        self.halo_cache = bool(config.halo_cache)
        self.last_halo_exchange_bytes = 0
        if self.halo_cache:
            self.max_send = pg.send_idx.shape[-1]
            # real (unpadded) rows per send-slot index, for the refreshed-
            # payload accounting; halo_slot_bytes(0, maxS) == the graph's
            # halo_bytes_per_layer
            self._halo_slot_counts = np.asarray(pg.send_mask).sum(axis=(0, 1))
            self._halo_byte_per_slot = wire_row_bytes(
                pg.features.shape[-1], config.halo_compress,
                pg.features.dtype.itemsize)
            self._halo_state = jax.tree.map(
                lambda x: jnp.asarray(x, f),
                build_stacked_halo_cache(pg, model.layer_input_dims))
            self._halo_age = 0
            self._cached_fwds: dict = {}
        # fault injection (DESIGN.md §10): when armed, the next eval
        # forward's freshly exchanged cache payload is "lost in transit" —
        # the stale cache is kept and ages on
        self._drop_next_refresh = False
        self.halo_refresh_drops = 0
        # full-graph phase-0: value_and_grad straight through self.fwd (the
        # halo-exchange forward whose aggregation op carries a custom VJP)
        self._fg_loss = make_fullgraph_loss_fn(self.fwd, loss=config.fg_loss)
        self._pstep = make_personalize_step(loss_fn, optimizer, hp)
        self._device_sampler = None
        self._sampler_gen = 0
        self.last_eval_seconds = 0.0   # execution time of the latest
                                       # separately-compiled evaluate() call
        self._mesh = None
        if self.mode == "spmd":
            from ..launch.mesh import make_partition_mesh
            self._mesh = make_partition_mesh(self.num_parts, AXIS)
        self._cache: dict = {}
        self.compile_count = 0

    # ------------------------------------------------------------ plumbing
    def _shape_key(self, name: str, args) -> tuple:
        # shardings are part of the key: an AOT executable is specialised to
        # its input shardings, and epoch 2's params arrive sharded over the
        # mesh while epoch 1's broadcast-fresh params were replicated.
        # weak_type too: jit specialises on it, and a python-scalar-built
        # array would otherwise collide with a strongly-typed one
        leaves = jax.tree_util.tree_leaves(args)
        return (name,) + tuple(
            (l.shape, str(l.dtype), bool(getattr(l, "weak_type", False)),
             str(getattr(l, "sharding", "")))
            for l in leaves)

    def _compiled(self, name: str, fn: Callable, *args):
        """AOT lower+compile once per input-shape signature, so epoch timing
        in the pipeline never includes XLA compilation.  ``compile_count``
        exposes the misses: identically shaped/sharded fresh inputs must
        reuse the executable (locked by a tier-1 regression test)."""
        key = self._shape_key(name, args)
        if key not in self._cache:
            self.compile_count += 1
            self._cache[key] = jax.jit(fn).lower(*args).compile()
        return self._cache[key]

    def _micro_of(self, preds, labels, mask):
        lab = jnp.where(mask, labels, -1)
        micro, _, _ = f1_scores_jnp(preds, lab, self.num_classes)
        return micro

    # ------------------------------------------- two-tier feature store
    def _feat_peak_bytes(self, pg) -> int:
        d = pg.features.shape[-1]
        b = np.dtype(self.config.dtype).itemsize
        if not self.feat_store:
            return feat_peak_bytes(self.num_parts, pg.max_nodes, d, b)
        return feat_peak_bytes(
            self.num_parts, pg.max_nodes, d, b,
            hot_rows=self._fs.hot.shape[1], cold_rows=self._fs.cold.shape[1],
            groups=self.config.feat_groups)

    def _featurize(self, shard, cold):
        """Reassemble one partition's full feature plane on-trace from the
        resident hot tier and the staged cold rows — bitwise equal to the
        all-resident ``shard["features"]`` (graph/featstore.py invariant),
        so every downstream forward (plain/cached/compressed/overlap) is
        untouched.  Passthrough when the store is off."""
        if not self.feat_store:
            return shard
        s = dict(shard)
        s["features"] = assemble_features(
            s.pop("fs_hot"), s.pop("fs_rows_hot"),
            cold, s.pop("fs_rows_cold"), self.max_nodes)
        return s

    def _stage_cold(self):
        """The (P, C, D) cold staging buffer for ONE compiled call.  Numpy
        on purpose: handing a host array to the executable is the actual
        host->device transfer the store trades residency for, counted here."""
        self.cold_h2d_bytes += self._cold_host.nbytes
        return self._cold_host

    def _fs_args(self) -> tuple:
        """Trailing compiled-call args of any trace that reassembles the
        shard feature plane: ``(cold,)`` under the store, ``()`` otherwise
        (keeping the all-resident call signatures byte-identical)."""
        return (self._stage_cold(),) if self.feat_store else ()

    def _stage_sampler_cold(self):
        """The device sampler's (Nc, D) cold rows for one epoch call."""
        ch = self._device_sampler.cold_host
        self.cold_h2d_bytes += ch.nbytes
        return ch

    @property
    def resident_feature_bytes(self) -> int:
        """Device-resident feature bytes: the engine's stacked plane (or
        hot tier) plus the attached device sampler's gather table (or its
        hot tier) — the footprint the feature store shrinks."""
        arr = self.shards["fs_hot"] if self.feat_store \
            else self.shards["features"]
        total = int(arr.size) * arr.dtype.itemsize
        ds = self._device_sampler
        if ds is not None:
            t = ds.features if ds.features is not None else ds.hot_feats
            total += int(t.size) * t.dtype.itemsize
        return total

    # ------------------------------------------ historical halo cache state
    # The cache ages once per distributed eval forward (standalone evaluate
    # OR the fused async epoch's eval); the refresh slot range is a host-side
    # constant from halo_refresh_plan, so each plan compiles its own
    # executable and the pure-cached one contains no collective at all.

    def _halo_plan(self) -> tuple[int, int]:
        if self._drop_next_refresh:
            self._drop_next_refresh = False
            self.halo_refresh_drops += 1
            return (0, 0)
        return halo_refresh_plan(self._halo_age, self.config.halo_refresh_every,
                                 self.config.halo_cv, self.max_send)

    def _halo_slot_bytes(self, lo: int, hi: int) -> int:
        return int(self._halo_slot_counts[lo:hi].sum()) * self._halo_byte_per_slot

    def _halo_tick(self, plan: tuple[int, int], new_state) -> None:
        self._halo_state = new_state
        # one exchange per SAGE layer, each shipping only the refreshed slots
        self.last_halo_exchange_bytes = (self.model.num_layers
                                         * self._halo_slot_bytes(*plan))
        self._halo_age += 1

    def drop_next_halo_refresh(self) -> None:
        """Arm the dropped-payload fault: the next eval forward runs the
        pure-cached plan (0, 0) — it aggregates fully against the stale
        cache and ships no refresh bytes, exactly as if the scheduled
        payload was lost in transit — while the cache still ages."""
        self._drop_next_refresh = True

    # ---- checkpoint/resume surface (RunCheckpointer) ---------------------
    def halo_cache_state(self):
        """(cache pytree, age) for checkpointing; None without the cache."""
        if not self.halo_cache:
            return None
        return self._halo_state, self._halo_age

    def restore_halo_cache_state(self, state, age: int) -> None:
        if not self.halo_cache:
            raise ValueError("engine built without halo_cache")
        f = self.config.dtype
        self._halo_state = jax.tree.map(lambda x: jnp.asarray(x, f), state)
        self._halo_age = int(age)

    # -------------------------------------- compressed communication state
    @property
    def halo_wire_bytes_per_layer(self) -> int:
        """Real payload bytes ONE layer's halo exchange puts on the wire
        under the configured compression — the dtype-truthful replacement
        for assuming 4-byte rows.  Equals ``pg.halo_bytes_per_layer`` when
        ``halo_compress == "none"``."""
        return self._halo_rows_total * wire_row_bytes(
            self._halo_row_width, self.halo_compress, self._halo_itemsize)

    def _grad_residual(self, params):
        """Lazily-built (P, N) top-k error-feedback state (flat per-partition
        gradient space), zero before the first compressed sync."""
        if self._grad_res is None:
            from jax.flatten_util import ravel_pytree

            flat, _ = ravel_pytree(params)
            self._grad_res = jnp.zeros((self.num_parts, flat.shape[0]),
                                       flat.dtype)
        return self._grad_res

    def comm_residual_state(self):
        """Error-feedback residual pytrees for checkpointing:
        ``(halo_residual, grad_residual)``; each entry is None when the
        matching compression is off (or, for top-k, before the first
        phase-0 step).  None when neither exists."""
        h = self._halo_residual if self.halo_compress != "none" else None
        g = self._grad_res if self.grad_compress == "topk" else None
        if h is None and g is None:
            return None
        return h, g

    def restore_comm_residual_state(self, state) -> None:
        h, g = state
        if h is not None:
            f = self.config.dtype
            self._halo_residual = jax.tree.map(
                lambda x: jnp.asarray(x, f), h)
        if g is not None:
            self._grad_res = jnp.asarray(g)

    def _cached_fwd(self, lo: int, hi: int):
        key = (lo, hi)
        if key not in self._cached_fwds:
            self._cached_fwds[key] = make_cached_forward(
                self.model, self._fwd_meta, axis_name=AXIS,
                agg=self._mean_agg, refresh_lo=lo, refresh_hi=hi,
                ring_chunks=self.config.ring_chunks,
                compress=self.halo_compress)
        return self._cached_fwds[key]

    def _eval_stacked_cached(self, params, cache, split: str,
                             per_partition_params: bool, plan, residual=None,
                             fs=()):
        fwd_c = self._cached_fwd(*plan)

        if residual is not None:
            def one_c(prm, shard, c, r, labels, mask, *cold):
                logits, nc, nr = fwd_c(prm, self._featurize(shard, *cold)
                                       if cold else shard, c, r)
                preds = jnp.argmax(logits, axis=-1)
                return self._micro_of(preds, labels, mask), preds, nc, nr

            return jax.vmap(one_c, axis_name=AXIS,
                            in_axes=(0 if per_partition_params else None,
                                     0, 0, 0, 0, 0) + (0,) * len(fs))(
                params, self.shards, cache, residual, self.labels,
                self.masks[split], *fs)

        def one(prm, shard, c, labels, mask, *cold):
            logits, nc = fwd_c(prm, self._featurize(shard, *cold)
                               if cold else shard, c)
            preds = jnp.argmax(logits, axis=-1)
            return self._micro_of(preds, labels, mask), preds, nc

        return jax.vmap(one, axis_name=AXIS,
                        in_axes=(0 if per_partition_params else None,
                                 0, 0, 0, 0) + (0,) * len(fs))(
            params, self.shards, cache, self.labels, self.masks[split], *fs)

    def _eval_spmd_cached(self, params, cache, split: str,
                          per_partition_params: bool, plan, residual=None,
                          fs=()):
        fwd_c = self._cached_fwd(*plan)
        comp = residual is not None

        def shard_fn(prm, cache_s, shard_s, labels_s, mask_s, *rest_s):
            rest = list(rest_s)
            p = jax.tree.map(lambda x: x[0], prm) if per_partition_params else prm
            sh = jax.tree.map(lambda x: x[0], shard_s)
            c = jax.tree.map(lambda x: x[0], cache_s)
            res_s = rest.pop(0) if comp else None
            if rest:                                  # staged cold rows
                sh = self._featurize(sh, rest[0][0])
            if comp:
                r = jax.tree.map(lambda x: x[0], res_s)
                logits, nc, nr = fwd_c(p, sh, c, r)
            else:
                logits, nc = fwd_c(p, sh, c)
            preds = jnp.argmax(logits, axis=-1)
            micro = self._micro_of(preds, labels_s[0], mask_s[0])
            head = (micro[None], preds[None],
                    jax.tree.map(lambda x: x[None], nc))
            return head + ((jax.tree.map(lambda x: x[None], nr),)
                           if comp else ())

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(AXIS) if per_partition_params else P(),
                      P(AXIS), P(AXIS), P(AXIS), P(AXIS))
                     + ((P(AXIS),) if comp else ())
                     + (P(AXIS),) * len(fs),
            out_specs=(P(AXIS), P(AXIS), P(AXIS))
                      + ((P(AXIS),) if comp else ()))
        args = (params, cache, self.shards, self.labels, self.masks[split])
        if comp:
            args = args + (residual,)
        return fn(*(args + tuple(fs)))

    def _eval_stacked_comp(self, params, residual, split: str,
                           per_partition_params: bool, fs=()):
        def one(prm, shard, r, labels, mask, *cold):
            logits, nr = self._fwd_comp(prm, self._featurize(shard, *cold)
                                        if cold else shard, r)
            preds = jnp.argmax(logits, axis=-1)
            return self._micro_of(preds, labels, mask), preds, nr

        return jax.vmap(one, axis_name=AXIS,
                        in_axes=(0 if per_partition_params else None,
                                 0, 0, 0, 0) + (0,) * len(fs))(
            params, self.shards, residual, self.labels, self.masks[split],
            *fs)

    def _eval_spmd_comp(self, params, residual, split: str,
                        per_partition_params: bool, fs=()):
        def shard_fn(prm, res_s, shard_s, labels_s, mask_s, *cold_s):
            p = jax.tree.map(lambda x: x[0], prm) if per_partition_params else prm
            sh = jax.tree.map(lambda x: x[0], shard_s)
            if cold_s:
                sh = self._featurize(sh, cold_s[0][0])
            r = jax.tree.map(lambda x: x[0], res_s)
            logits, nr = self._fwd_comp(p, sh, r)
            preds = jnp.argmax(logits, axis=-1)
            micro = self._micro_of(preds, labels_s[0], mask_s[0])
            return micro[None], preds[None], jax.tree.map(lambda x: x[None], nr)

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(AXIS) if per_partition_params else P(),
                      P(AXIS), P(AXIS), P(AXIS), P(AXIS))
                     + (P(AXIS),) * len(fs),
            out_specs=(P(AXIS), P(AXIS), P(AXIS)))
        return fn(params, residual, self.shards, self.labels,
                  self.masks[split], *fs)

    # ------------------------------------------------- stacked (vmap) mode
    def _eval_stacked(self, params, split: str, per_partition_params: bool,
                      fs=()):
        def one(prm, shard, *cold):
            return self.fwd(prm, self._featurize(shard, *cold)
                            if cold else shard)

        in_axes = (0 if per_partition_params else None, 0) + (0,) * len(fs)
        logits = jax.vmap(one, axis_name=AXIS, in_axes=in_axes)(
            params, self.shards, *fs)                # (P, maxN, C)
        preds = jnp.argmax(logits, axis=-1)
        micro = jax.vmap(self._micro_of)(preds, self.labels, self.masks[split])
        return micro, preds

    def _grad_reduce_stacked(self):
        """Stacked-mode gradient reducer for the configured grad_compress
        mode: ``reduce(grads_stacked) -> grads`` (none / bucketed) or
        ``reduce(grads_stacked, residual) -> (grads, residual)`` (topk)."""
        num_parts = self.num_parts
        if self.grad_compress == "bucketed":
            return make_bucketed_reduce_stacked(
                num_parts, self.config.grad_bucket_kb * 1024)
        if self.grad_compress == "topk":
            return make_topk_reduce_stacked(num_parts,
                                            self.config.grad_topk_frac)
        # the all-reduce: stacked-axis mean == lax.pmean on the mesh
        return lambda grads: jax.tree.map(
            lambda g: jnp.sum(g, axis=0) / num_parts, grads)

    def _grad_reduce_shard(self):
        """Per-shard (collective) reducer for grad_compress; mode "none"
        returns None — the caller keeps its existing spelling untouched."""
        if self.grad_compress == "bucketed":
            return make_bucketed_reduce_shard(
                self.num_parts, AXIS, self.config.grad_bucket_kb * 1024)
        if self.grad_compress == "topk":
            return make_topk_reduce_shard(self.num_parts, AXIS,
                                          self.config.grad_topk_frac)
        return None

    def _phase0_stacked(self, params, opt_state, batches, grad_res=None):
        reduce = self._grad_reduce_stacked()

        if self.grad_compress == "topk":
            def one_iter_t(carry, b_it):
                params, opt_state, res = carry
                losses, grads = jax.vmap(
                    jax.value_and_grad(self.loss_fn),
                    in_axes=(None, 0))(params, b_it)
                grads, res = reduce(grads, res)
                updates, opt_state = self.optimizer.update(grads, opt_state,
                                                           params)
                return (apply_updates(params, updates), opt_state, res), losses

            (params, opt_state, grad_res), losses = jax.lax.scan(
                one_iter_t, (params, opt_state, grad_res), batches)
            return params, opt_state, losses, grad_res

        def one_iter(carry, b_it):
            params, opt_state = carry
            losses, grads = jax.vmap(
                jax.value_and_grad(self.loss_fn), in_axes=(None, 0))(params, b_it)
            grads = reduce(grads)
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state), losses

        (params, opt_state), losses = jax.lax.scan(
            one_iter, (params, opt_state), batches)
        return params, opt_state, losses

    def _fg_batch(self):
        """The full-graph 'batch': every partition's graph shard + labels +
        train mask, (P, ...)-stacked like any minibatch pytree."""
        return {"shard": self.shards, "labels": self.labels,
                "train_mask": self.masks["train"]}

    def _phase0_fullgraph_stacked(self, params, opt_state, iters: int):
        batch = self._fg_batch()
        reduce = self._grad_reduce_stacked()

        def one_iter(carry, _):
            params, opt_state = carry
            # vmap with the collective axis bound: each partition's loss
            # differentiates THROUGH the halo exchange, so grads[p] includes
            # the paths via embeddings p shipped to other partitions
            losses, grads = jax.vmap(
                jax.value_and_grad(self._fg_loss), in_axes=(None, 0),
                axis_name=AXIS)(params, batch)
            grads = reduce(grads)
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state), losses

        (params, opt_state), losses = jax.lax.scan(
            one_iter, (params, opt_state), None, length=iters)
        return params, opt_state, losses

    def _phase0_fullgraph_spmd(self, params, opt_state, iters: int):
        g_reduce = self._grad_reduce_shard()

        def shard_fn(params, opt_state, shard_s, labels_s, mask_s):
            batch = {"shard": jax.tree.map(lambda x: x[0], shard_s),
                     "labels": labels_s[0], "train_mask": mask_s[0]}

            def one(carry, _):
                p, o = carry
                loss, grads = jax.value_and_grad(self._fg_loss)(p, batch)
                grads = (jax.lax.pmean(grads, AXIS) if g_reduce is None
                         else g_reduce(grads))
                updates, o = self.optimizer.update(grads, o, p)
                return (apply_updates(p, updates), o), loss

            (params, opt_state), losses = jax.lax.scan(
                one, (params, opt_state), None, length=iters)
            return params, opt_state, losses[:, None]

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(), P(), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(), P(), P(None, AXIS)))
        return fn(params, opt_state, self.shards, self.labels,
                  self.masks["train"])

    def _phase0_async_partition_program(self, plan=None):
        """ONE partition's fused generalization epoch: epoch draw (uniform
        shuffle, or the CBS-weighted Eq. 3 mini-epoch when the sampler is
        class-balanced), per-iteration batch materialisation, the train scan
        with the cross-partition gradient mean, and the validation eval
        forward — all on a single trace (DESIGN.md §7).  The SINGLE body both
        modes execute, so PRNG consumption order cannot drift between them.

        The default gradient all-reduce is spelled ``all_gather`` + a local
        stack-axis sum: pure data movement followed by the SAME deterministic
        reduction the sequential oracle performs, which is what makes the
        spmd mesh mode bit-for-bit with the reference (a ``pmean``'s
        reduction order is the collective implementation's choice).
        ``grad_compress`` swaps in the bucketed-psum or top-k spelling.

        ``*state`` carries the eval/EF pytrees in a fixed order — halo
        cache (when ``plan`` is set), halo residual (``halo_compress``),
        flat gradient residual (``grad_compress == "topk"``) — and the
        return tuple appends their updated values in the same order after
        ``(params, opt_state, losses, micro)``.  Under the feature store
        two staged cold buffers follow the state (the sampler's (Nc, D)
        rows for the batch gathers, this partition's (C, D) rows for the
        fused eval's plane); they are inputs only, never returned.
        """
        ds = self._device_sampler
        num_parts = self.num_parts
        comp = self.halo_compress != "none"
        topk = self.grad_compress == "topk"
        fs_on = self.feat_store
        fwd_c = self._cached_fwd(*plan) if plan is not None else None
        g_reduce = self._grad_reduce_shard()

        def per_part(params, opt_state, key, logp_row, train_row, k_row,
                     shard, labels, val_mask, *state):
            st = list(state)
            cache = st.pop(0) if fwd_c is not None else None
            h_res = st.pop(0) if comp else None
            g_res = st.pop(0) if topk else None
            ck = {"cold": st.pop(0)} if fs_on else {}
            sh_cold = st.pop(0) if fs_on else None
            kd, ke = jax.random.split(key)
            nodes, valid = ds.draw_epoch(kd, logp_row, train_row, k_row)
            iter_keys = jax.random.split(ke, ds.num_batches)

            if topk:
                def one_t(carry, xs):
                    n_i, v_i, k_i = xs
                    p, o, r = carry
                    batch = ds.make_batch(k_i, n_i, v_i, **ck)
                    loss, grads = jax.value_and_grad(self.loss_fn)(p, batch)
                    grads, r = g_reduce(grads, r)
                    updates, o = self.optimizer.update(grads, o, p)
                    return (apply_updates(p, updates), o, r), loss

                (params, opt_state, g_res), losses = jax.lax.scan(
                    one_t, (params, opt_state, g_res),
                    (nodes, valid, iter_keys))
            else:
                def one(carry, xs):
                    n_i, v_i, k_i = xs
                    p, o = carry
                    batch = ds.make_batch(k_i, n_i, v_i, **ck)
                    loss, grads = jax.value_and_grad(self.loss_fn)(p, batch)
                    if g_reduce is not None:              # bucketed psum
                        grads = g_reduce(grads)
                    else:
                        g_all = jax.lax.all_gather(grads, AXIS)   # (P, ...)
                        grads = jax.tree.map(
                            lambda g: jnp.sum(g, axis=0) / num_parts, g_all)
                    updates, o = self.optimizer.update(grads, o, p)
                    return (apply_updates(p, updates), o), loss

                (params, opt_state), losses = jax.lax.scan(
                    one, (params, opt_state), (nodes, valid, iter_keys))
            if fs_on:
                # reassemble the shard plane only now, after the (feature-
                # free) train scan, so the assembled array's live range is
                # just the fused eval
                shard = self._featurize(shard, sh_cold)
            # fused eval: the validation forward (halo exchange + blocked
            # aggregation + on-device F1) on the epoch's final params, in
            # the SAME device program as the train scan
            extras = []
            if fwd_c is not None:
                if comp:
                    logits, new_cache, new_hres = fwd_c(params, shard,
                                                        cache, h_res)
                    extras += [new_cache, new_hres]
                else:
                    logits, new_cache = fwd_c(params, shard, cache)
                    extras += [new_cache]
            elif comp:
                logits, new_hres = self._fwd_comp(params, shard, h_res)
                extras += [new_hres]
            else:
                logits = self.fwd(params, shard)
            preds = jnp.argmax(logits, axis=-1)
            micro = self._micro_of(preds, labels, val_mask)
            if topk:
                extras += [g_res]
            return (params, opt_state, losses, micro) + tuple(extras)

        return per_part

    def _phase0_async_stacked(self, params, opt_state, keys, state=(),
                              plan=None, fs=()):
        ds = self._device_sampler
        per_part = self._phase0_async_partition_program(plan)
        # fs = (sampler cold (Nc, D) — replicated, shard cold (P, C, D))
        extra_axes = (0,) * len(state) + ((None, 0) if fs else ())
        out = jax.vmap(
            per_part, axis_name=AXIS,
            in_axes=(None, None, 0, 0, 0, 0, 0, 0, 0) + extra_axes)(
                params, opt_state, keys, ds.logp, ds.train_idx, ds.k,
                self.shards, self.labels, self.masks["val"], *state, *fs)
        params, opt_state, losses, micro = out[:4]
        # every partition applies the identical mean update to the identical
        # replica: return one copy (bitwise equal across the stacked axis)
        head = (jax.tree.map(lambda x: x[0], params),
                jax.tree.map(lambda x: x[0], opt_state),
                losses.T, micro)                    # (I, P), (P,)
        return head + tuple(out[4:])

    def _phase0_async_spmd(self, params, opt_state, keys, state=(),
                           plan=None, fs=()):
        ds = self._device_sampler
        n_st = len(state)

        def shard_fn(params, opt_state, key_s, logp_s, train_s, k_s,
                     shard_s, labels_s, mask_s, *rest_s):
            per_part = self._phase0_async_partition_program(plan)
            sh = jax.tree.map(lambda x: x[0], shard_s)
            extra = tuple(jax.tree.map(lambda x: x[0], c)
                          for c in rest_s[:n_st])
            if fs:
                # sampler cold is replicated (P() spec — arrives whole);
                # the per-partition shard cold is sharded like the shards
                extra += (rest_s[n_st], rest_s[n_st + 1][0])
            out = per_part(
                params, opt_state, key_s[0], logp_s[0], train_s[0], k_s[0],
                sh, labels_s[0], mask_s[0], *extra)
            params, opt_state, losses, micro = out[:4]
            head = (params, opt_state, losses[:, None], micro[None])
            return head + tuple(jax.tree.map(lambda x: x[None], c)
                                for c in out[4:])

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(), P(), P(AXIS), P(AXIS), P(AXIS), P(AXIS),
                      P(AXIS), P(AXIS), P(AXIS)) + (P(AXIS),) * n_st
                     + ((P(), P(AXIS)) if fs else ()),
            out_specs=(P(), P(), P(None, AXIS), P(AXIS)) + (P(AXIS),) * n_st)
        args = (params, opt_state, keys, ds.logp, ds.train_idx, ds.k,
                self.shards, self.labels, self.masks["val"]) \
            + tuple(state) + tuple(fs)
        return fn(*args)

    def _phase1_stacked(self, pparams, popt, batches, global_params, budgets):
        def one_iter(carry, xs):
            i, b_it = xs
            pp, po = carry
            # masked variable-length scan: partition p trains while i < its
            # budget, rides through bitwise-frozen afterwards
            pp, po, losses = self._pstep(pp, po, b_it, global_params,
                                         i < budgets)
            return (pp, po), losses

        iters = jax.tree_util.tree_leaves(batches)[0].shape[0]
        (pparams, popt), losses = jax.lax.scan(
            one_iter, (pparams, popt), (jnp.arange(iters), batches))
        return pparams, popt, losses

    def _async_partition_program(self, global_params, i_run: int):
        """ONE partition's async epoch: mini-epoch draw, per-iteration batch
        materialisation, masked training scan.  The SINGLE body both modes
        execute — stacked vmaps it, spmd runs it per shard — so the PRNG
        consumption order (and with it stacked/spmd bit-parity) cannot
        drift between them."""
        ds = self._device_sampler
        pstep1 = make_personalize_partition_step(self.loss_fn, self.optimizer,
                                                 self.hp)

        def per_part(pp, po, key, budget, logp_row, train_row, k_row, *fs):
            ck = {"cold": fs[0]} if fs else {}
            kd, ke = jax.random.split(key)
            nodes, valid = ds.draw_epoch(kd, logp_row, train_row, k_row)
            iter_keys = jax.random.split(ke, ds.num_batches)

            def one(carry, xs):
                i, n_i, v_i, k_i = xs
                p, o = carry
                batch = ds.make_batch(k_i, n_i, v_i, **ck)
                p, o, l = pstep1(p, o, batch, global_params, i < budget)
                return (p, o), l

            (pp, po), losses = jax.lax.scan(
                one, (pp, po),
                (jnp.arange(i_run), nodes[:i_run], valid[:i_run],
                 iter_keys[:i_run]))
            return pp, po, losses

        return per_part

    def _phase1_async_stacked(self, pparams, popt, keys, budgets,
                              global_params, i_run: int, fs=()):
        ds = self._device_sampler
        per_part = self._async_partition_program(global_params, i_run)
        pparams, popt, losses = jax.vmap(
            per_part, in_axes=(0, 0, 0, 0, 0, 0, 0)
            + (None,) * len(fs))(
                pparams, popt, keys, budgets,
                ds.logp, ds.train_idx, ds.k, *fs)
        return pparams, popt, losses.T              # (i_run, P)

    # --------------------------------------------------- spmd (mesh) mode
    def _phase0_spmd(self, params, opt_state, batches, grad_res=None):
        g_reduce = self._grad_reduce_shard()

        if self.grad_compress == "topk":
            def shard_fn_t(params, opt_state, b_s, res_s):
                b = jax.tree.map(lambda x: x[:, 0], b_s)   # (I, ...)

                def one(carry, bi):
                    p, o, r = carry
                    loss, grads = jax.value_and_grad(self.loss_fn)(p, bi)
                    grads, r = g_reduce(grads, r)
                    updates, o = self.optimizer.update(grads, o, p)
                    return (apply_updates(p, updates), o, r), loss

                (params, opt_state, res), losses = jax.lax.scan(
                    one, (params, opt_state, res_s[0]), b)
                return params, opt_state, losses[:, None], res[None]

            fn = shard_map_compat(
                shard_fn_t, self._mesh,
                in_specs=(P(), P(), P(None, AXIS), P(AXIS)),
                out_specs=(P(), P(), P(None, AXIS), P(AXIS)))
            return fn(params, opt_state, batches, grad_res)

        # like make_generalize_step(axis_names=(AXIS,)) but reporting the
        # LOCAL loss: the stacked/sequential paths record per-host losses, so
        # the engine's (I, P) loss matrix must stay per-host for parity
        def gen_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            grads = (jax.lax.pmean(grads, AXIS) if g_reduce is None
                     else g_reduce(grads))
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, loss

        def shard_fn(params, opt_state, b_s):
            b = jax.tree.map(lambda x: x[:, 0], b_s)       # (I, ...)

            def one(carry, bi):
                p, o = carry
                p, o, l = gen_step(p, o, bi)
                return (p, o), l

            (params, opt_state), losses = jax.lax.scan(one, (params, opt_state), b)
            return params, opt_state, losses[:, None]

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(), P(), P(None, AXIS)),
            out_specs=(P(), P(), P(None, AXIS)))
        return fn(params, opt_state, batches)

    def _phase1_spmd(self, pparams, popt, batches, global_params, budgets):
        pstep1 = make_personalize_partition_step(self.loss_fn, self.optimizer,
                                                 self.hp)

        def shard_fn(pp_s, po_s, b_s, gp, bud_s):
            pp = jax.tree.map(lambda x: x[0], pp_s)
            po = jax.tree.map(lambda x: x[0], po_s)
            b = jax.tree.map(lambda x: x[:, 0], b_s)
            bud = bud_s[0]
            iters = jax.tree_util.tree_leaves(b)[0].shape[0]

            def one(carry, xs):
                i, bi = xs
                p, o = carry
                p, o, l = pstep1(p, o, bi, gp, i < bud)
                return (p, o), l

            (pp, po), losses = jax.lax.scan(one, (pp, po),
                                            (jnp.arange(iters), b))
            return (jax.tree.map(lambda x: x[None], pp),
                    jax.tree.map(lambda x: x[None], po),
                    losses[:, None])

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(AXIS), P(AXIS), P(None, AXIS), P(), P(AXIS)),
            out_specs=(P(AXIS), P(AXIS), P(None, AXIS)))
        return fn(pparams, popt, batches, global_params, budgets)

    def _phase1_async_spmd(self, pparams, popt, keys, budgets, global_params,
                           i_run: int, fs=()):
        ds = self._device_sampler

        def shard_fn(pp_s, po_s, key_s, bud_s, gp, logp_s, train_s, k_s,
                     *fs_s):
            per_part = self._async_partition_program(gp, i_run)
            pp = jax.tree.map(lambda x: x[0], pp_s)
            po = jax.tree.map(lambda x: x[0], po_s)
            pp, po, losses = per_part(pp, po, key_s[0], bud_s[0],
                                      logp_s[0], train_s[0], k_s[0], *fs_s)
            return (jax.tree.map(lambda x: x[None], pp),
                    jax.tree.map(lambda x: x[None], po),
                    losses[:, None])

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(),
                      P(AXIS), P(AXIS), P(AXIS)) + (P(),) * len(fs),
            out_specs=(P(AXIS), P(AXIS), P(None, AXIS)))
        return fn(pparams, popt, keys, budgets, global_params,
                  ds.logp, ds.train_idx, ds.k, *fs)

    def _eval_spmd(self, params, split: str, per_partition_params: bool,
                   fs=()):
        def shard_fn(prm, shard_s, labels_s, mask_s, *cold_s):
            p = jax.tree.map(lambda x: x[0], prm) if per_partition_params else prm
            sh = jax.tree.map(lambda x: x[0], shard_s)
            if cold_s:
                sh = self._featurize(sh, cold_s[0][0])
            preds = jnp.argmax(self.fwd(p, sh), axis=-1)
            micro = self._micro_of(preds, labels_s[0], mask_s[0])
            return micro[None], preds[None]

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(AXIS) if per_partition_params else P(),
                      P(AXIS), P(AXIS), P(AXIS)) + (P(AXIS),) * len(fs),
            out_specs=(P(AXIS), P(AXIS)))
        return fn(params, self.shards, self.labels, self.masks[split], *fs)

    # ------------------------------------------------------- public surface
    # Epoch methods return a trailing ``device_seconds``: wall time of the
    # compiled TRAIN scan only.  The validation forward is a separately
    # compiled (still internally fused: halo all_to_all + aggregation +
    # on-device F1) call whose cost is identical across sampler/partition
    # ablations, so excluding it — like the original per-batch driver did —
    # keeps epoch-time comparisons about training.  AOT compilation happens
    # outside every timed window.

    def _timed(self, fn, *args):
        import time

        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        return out, time.perf_counter() - t0

    def phase0_epoch(self, params, opt_state, batches):
        impl = self._phase0_spmd if self.mode == "spmd" else self._phase0_stacked
        if self.grad_compress == "topk":
            res = self._grad_residual(params)
            fn = self._compiled("phase0", impl, params, opt_state, batches,
                                res)
            (params, opt_state, losses, new_res), dt = self._timed(
                fn, params, opt_state, batches, res)
            self._grad_res = new_res
        else:
            fn = self._compiled("phase0", impl, params, opt_state, batches)
            (params, opt_state, losses), dt = self._timed(
                fn, params, opt_state, batches)
        val_micro, _ = self.evaluate(params, "val", per_partition_params=False)
        return params, opt_state, losses, val_micro, dt

    def phase0_epoch_async(self, params, opt_state, keys):
        """One fused generalization epoch: the on-device epoch draw (uniform
        shuffle of the local train set, or the CBS mini-epoch when the
        attached sampler is class-balanced), batch materialisation, the
        synchronous train scan with the cross-partition gradient mean, AND
        the validation eval forward — all in ONE compiled device program, so
        an epoch costs one host→device round-trip instead of shipping
        ``iters`` host-built batches plus a separate eval call.

        ``keys`` is (P, 2) uint32 per-partition PRNG state (fold the epoch
        index into a per-partition base key).  Unlike phase-1 there are no
        budgets: generalization is synchronous data-parallel SGD, every
        partition scans all ``num_batches`` iterations.  Returns
        ``(params, opt_state, losses (I, P), val_micro (P,), device_seconds)``
        where the timing, unlike :meth:`phase0_epoch`, INCLUDES the fused
        eval (it is part of the one device call; the pipeline's epoch-time
        attribution accounts for that).
        """
        if self._device_sampler is None:
            raise ValueError("phase0_epoch_async needs set_device_sampler()")
        if self.config.feat_groups:
            raise ValueError(
                "feat_groups streams the eval forward on the host; the "
                "fused async epoch is one device program — run the host-"
                "batch phase-0 path (async_generalize=False) when streaming")
        base = (self._phase0_async_spmd if self.mode == "spmd"
                else self._phase0_async_stacked)
        comp = self.halo_compress != "none"
        topk = self.grad_compress == "topk"
        plan = self._halo_plan() if self.halo_cache else None
        # carried state, in the partition program's fixed order
        state = ()
        if plan is not None:
            state += (self._halo_state,)
        if comp:
            state += (self._halo_residual,)
        if topk:
            state += (self._grad_residual(params),)
        # staged cold rows (feature store): the sampler's global cold tier
        # feeds the batch gathers, the shard cold tier feeds the fused eval
        fs = ((self._stage_sampler_cold(), self._stage_cold())
              if self.feat_store else ())
        if state or fs:
            n_st = len(state)
            impl = lambda p, o, k, *st: base(p, o, k, st[:n_st], plan,
                                             st[n_st:])
            name = f"phase0_async-g{self._sampler_gen}"
            if plan is not None:
                name += f"-c{plan[0]}-{plan[1]}"
            fn = self._compiled(name, impl, params, opt_state, keys,
                                *state, *fs)
            out, dt = self._timed(fn, params, opt_state, keys, *state, *fs)
            params, opt_state, losses, val_micro = out[:4]
            rest = list(out[4:])
            if plan is not None:
                self._halo_tick(plan, rest.pop(0))
            if comp:
                self._halo_residual = rest.pop(0)
                if plan is None:
                    self.last_halo_exchange_bytes = (
                        self.model.num_layers * self.halo_wire_bytes_per_layer)
            if topk:
                self._grad_res = rest.pop(0)
        else:
            fn = self._compiled(f"phase0_async-g{self._sampler_gen}", base,
                                params, opt_state, keys)
            (params, opt_state, losses, val_micro), dt = self._timed(
                fn, params, opt_state, keys)
        self.last_eval_seconds = 0.0    # eval is inside dt on this path
        return params, opt_state, losses, val_micro, dt

    def phase0_fullgraph_epoch(self, params, opt_state, iters: int = 1):
        """Full-graph phase-0 epoch: ``iters`` full-batch steps whose
        ``value_and_grad`` runs straight through the distributed forward —
        per-layer halo exchange, the differentiable Pallas aggregation op
        (forward AND transpose kernels on the traced path when
        ``use_pallas_agg=True``) and the cross-partition gradient mean.  The
        centralized (P=1) configuration is the paper's Table IV baseline at
        full-graph scale; P>1 is per-partition full-graph training."""
        if self.feat_store:
            raise ValueError(
                "full-graph training differentiates through the resident "
                "feature stack on every iteration; the feature store "
                "serves features per compiled call — run full_graph_train "
                "all-resident")
        if self.halo_cache:
            raise ValueError(
                "halo_cache is an eval-forward optimisation; full-graph "
                "training differentiates through the live halo exchange "
                "and cannot train against stale cached embeddings")
        if self.grad_compress == "topk":
            raise ValueError(
                "top-k gradient sparsification is a sampled phase-0 feature; "
                "full-graph training keeps the exact (or bucketed) all-reduce")
        impl = (self._phase0_fullgraph_spmd if self.mode == "spmd"
                else self._phase0_fullgraph_stacked)
        fn = self._compiled(f"phase0_fg-{iters}",
                            lambda p, o: impl(p, o, iters), params, opt_state)
        (params, opt_state, losses), dt = self._timed(fn, params, opt_state)
        val_micro, _ = self.evaluate(params, "val", per_partition_params=False)
        return params, opt_state, losses, val_micro, dt

    @staticmethod
    def _as_budgets(active_or_budgets, iters: int):
        """Phase-1 gating is expressed as per-partition iteration BUDGETS;
        a bool `active` vector (the pre-async API) means full-epoch-or-zero."""
        b = jnp.asarray(active_or_budgets)
        if b.dtype == jnp.bool_:
            b = jnp.where(b, iters, 0)
        return b.astype(jnp.int32)

    def phase1_epoch(self, pparams, popt, batches, global_params, budgets):
        iters = jax.tree_util.tree_leaves(batches)[0].shape[0]
        budgets = self._as_budgets(budgets, iters)
        impl = self._phase1_spmd if self.mode == "spmd" else self._phase1_stacked
        fn = self._compiled("phase1", impl, pparams, popt, batches,
                            global_params, budgets)
        (pparams, popt, losses), dt = self._timed(
            fn, pparams, popt, batches, global_params, budgets)
        val_micro, _ = self.evaluate(pparams, "val", per_partition_params=True)
        return pparams, popt, losses, val_micro, dt

    # ----------------------------------------------- async personalization
    def set_device_sampler(self, sampler) -> None:
        """Attach a :class:`DeviceEpochSampler`; required by
        :meth:`phase0_epoch_async` and :meth:`phase1_epoch_async` (the
        fully-on-device epoch paths)."""
        if self.feat_store != (getattr(sampler, "cold_host", None)
                               is not None):
            raise ValueError(
                "feat-store mismatch: the engine and its device sampler "
                "must agree — build the sampler with feat_store matching "
                "EngineConfig.feat_store")
        self._device_sampler = sampler
        # the sampler's arrays are baked into the async trace as constants,
        # so a new sampler must never hit an old executable (shapes alone
        # can't distinguish two same-sized graphs) — and the superseded
        # executables pin those arrays in device memory, so evict them
        self._sampler_gen += 1
        self._cache = {k: v for k, v in self._cache.items()
                       if not str(k[0]).startswith(("phase0_async-",
                                                    "phase1_async-"))}

    def phase1_epoch_async(self, pparams, popt, keys, budgets, global_params):
        """One asynchronous personalization step: mini-epoch resample, batch
        shuffle, fanout sampling, feature gather AND the masked training scan
        all inside ONE device program — no host NumPy on the mini-epoch path.

        ``keys`` is (P, 2) uint32 per-partition PRNG state; ``budgets`` (P,)
        int32 from :meth:`GPController.phase1_budgets`.  The scan's static
        trip count is max(budgets) rounded up to a power of two (bounding
        recompiles to log2(I) shapes), so converged partitions stop paying
        for the stragglers' full epochs.
        """
        if self._device_sampler is None:
            raise ValueError("phase1_epoch_async needs set_device_sampler()")
        budgets = self._as_budgets(budgets, self._device_sampler.num_batches)
        cap = self._device_sampler.num_batches
        need = int(np.asarray(budgets).max())
        i_run = 1
        while i_run < min(need, cap):
            i_run *= 2
        i_run = min(i_run, cap)
        impl = (self._phase1_async_spmd if self.mode == "spmd"
                else self._phase1_async_stacked)
        # the phase-1 scan only gathers batch features (no fused eval), so
        # the feature store stages just the sampler's cold tier here
        fs = (self._stage_sampler_cold(),) if self.feat_store else ()
        fn = self._compiled(
            f"phase1_async-{i_run}-g{self._sampler_gen}",
            lambda pp, po, k, b, gp, *c: impl(pp, po, k, b, gp, i_run, c),
            pparams, popt, keys, budgets, global_params, *fs)
        (pparams, popt, losses), dt = self._timed(
            fn, pparams, popt, keys, budgets, global_params, *fs)
        val_micro, _ = self.evaluate(pparams, "val", per_partition_params=True)
        return pparams, popt, losses, val_micro, dt

    def evaluate(self, params, split: str = "test",
                 per_partition_params: bool = True):
        if self.config.feat_groups:
            return self._evaluate_streamed(params, split,
                                           per_partition_params)
        comp = self.halo_compress != "none"
        fs = self._fs_args()
        if self.halo_cache:
            # the refresh slot range is a static host-side plan, so every
            # plan gets its own executable (the pure-cached one has no
            # collective at all); the cache rides through as carried state,
            # and under halo_compress so does the quantization residual
            plan = self._halo_plan()
            res = (self._halo_residual,) if comp else ()
            if self.mode == "spmd":
                impl = lambda prm, c, *r: self._eval_spmd_cached(
                    prm, c, split, per_partition_params, plan,
                    *r[:len(res)], fs=r[len(res):])
            else:
                impl = lambda prm, c, *r: self._eval_stacked_cached(
                    prm, c, split, per_partition_params, plan,
                    *r[:len(res)], fs=r[len(res):])
            fn = self._compiled(
                f"eval-{split}-{per_partition_params}-c{plan[0]}-{plan[1]}",
                impl, params, self._halo_state, *res, *fs)
            out, self.last_eval_seconds = self._timed(
                fn, params, self._halo_state, *res, *fs)
            if comp:
                micro, preds, new_state, new_res = out
                self._halo_residual = new_res
            else:
                micro, preds, new_state = out
            self._halo_tick(plan, new_state)
            return micro, preds
        if comp:
            if self.mode == "spmd":
                impl = lambda prm, r, *c: self._eval_spmd_comp(
                    prm, r, split, per_partition_params, fs=c)
            else:
                impl = lambda prm, r, *c: self._eval_stacked_comp(
                    prm, r, split, per_partition_params, fs=c)
            fn = self._compiled(f"eval-{split}-{per_partition_params}",
                                impl, params, self._halo_residual, *fs)
            (micro, preds, new_res), self.last_eval_seconds = self._timed(
                fn, params, self._halo_residual, *fs)
            self._halo_residual = new_res
            self.last_halo_exchange_bytes = (self.model.num_layers
                                             * self.halo_wire_bytes_per_layer)
            return micro, preds
        if self.mode == "spmd":
            impl = lambda prm, *c: self._eval_spmd(
                prm, split, per_partition_params, fs=c)
        else:
            impl = lambda prm, *c: self._eval_stacked(
                prm, split, per_partition_params, fs=c)
        fn = self._compiled(f"eval-{split}-{per_partition_params}", impl,
                            params, *fs)
        # execution time of the compiled eval (AOT compile excluded), so the
        # pipeline can compare host-path epochs, whose eval is a separate
        # call, against the fused async epoch whose timing includes eval
        out, self.last_eval_seconds = self._timed(fn, params, *fs)
        return out

    def _evaluate_streamed(self, params, split: str,
                           per_partition_params: bool):
        """Partition-group streaming eval (DESIGN.md §12): host-orchestrated
        eager forward over groups of ``feat_groups`` partitions, so at most
        G assembled feature planes exist at once — the bigger-than-device
        path.  Op-for-op the sequential reference forward, hence bitwise
        locked against it in tests/test_engine_parity.py."""
        import time

        from .streaming import StreamedEvaluator

        if self._streamer is None:
            self._streamer = StreamedEvaluator(self)
        t0 = time.perf_counter()
        micro, preds, cold_bytes = self._streamer.evaluate(
            params, split, per_partition_params)
        jax.block_until_ready((micro, preds))
        self.cold_h2d_bytes += cold_bytes
        self.last_eval_seconds = time.perf_counter() - t0
        return micro, preds

    def export_serving_state(self, params) -> dict:
        """One full-refresh forward materializing the serving handoff
        (DESIGN.md §9): ``{"layers": [(P, maxN, D_i) per layer],
        "logits": (P, maxN, C), "cache": {"h{i}": (P, P, maxS, D_i)}}``
        as host numpy arrays.  The logits are bit-for-bit ``evaluate()``'s
        forward (same spelling), the cache is the recv-layout snapshot a
        full-refresh cached forward would have written — when the engine
        runs with ``halo_cache`` the freshly exchanged buffers are handed
        back to it, so the export doubles as a cache refresh.

        Global (replicated) params only; the overlap forward never
        materializes post-exchange layer inputs, so build the engine
        without ``overlap_halo`` to serve from it.
        """
        if self.config.overlap_halo:
            raise ValueError(
                "export_serving_state needs the combined-edge forward; "
                "build the engine without overlap_halo")
        shards = self.shards
        if self.feat_store:
            # the export forward wants the resident plane; reconstruct it
            # host-side (bitwise the all-resident stack) and hand it in as
            # the call argument — a one-shot transfer for the serving
            # handoff, not part of the per-epoch cold-row accounting
            shards = {k: v for k, v in self.shards.items()
                      if not k.startswith("fs_")}
            shards["features"] = jnp.asarray(
                reconstruct_features(self._fs, self.max_nodes),
                self.config.dtype)
        fwd_e = make_export_forward(self.model, self._fwd_meta,
                                    axis_name=AXIS, agg=self._mean_agg)
        if self.mode == "spmd":
            def shard_fn(prm, shard_s):
                sh = jax.tree.map(lambda x: x[0], shard_s)
                return jax.tree.map(lambda x: x[None], fwd_e(prm, sh))
            L = self.model.num_layers
            out_specs = {"layers": tuple(P(AXIS) for _ in range(L)),
                         "logits": P(AXIS),
                         "cache": {f"h{i}": P(AXIS) for i in range(L)}}
            impl = shard_map_compat(shard_fn, self._mesh,
                                    in_specs=(P(), P(AXIS)),
                                    out_specs=out_specs)
        else:
            impl = jax.vmap(fwd_e, axis_name=AXIS, in_axes=(None, 0))
        fn = self._compiled("export_serving", impl, params, shards)
        out = fn(params, shards)
        if self.halo_cache:
            # the snapshot is exactly a full refresh: hand it to the cache
            self._halo_state = jax.tree.map(
                lambda x: x.astype(self.config.dtype), out["cache"])
        return jax.tree.map(np.asarray, out)
