"""SPMD execution engine for the EAT pipeline (DESIGN.md §3).

Fused epoch steps instead of a Python loop over partitions: every
partition's graph shard, blocked aggregation structure and minibatch stream
is stacked into ``(P, ...)`` arrays, and each epoch executes as two
compiled calls — one trace scanning ALL training iterations (with the
cross-partition gradient mean inside the scan), one trace for the
full-graph validation forward with its per-layer halo ``all_to_all``
(compiled separately so the pipeline can time training without eval cost;
see DESIGN.md §3).

Three execution modes share one per-shard program:

  spmd        ``shard_map`` over a 1-D partition mesh — one partition per
              device, real collectives.  Picked by ``auto`` when the host
              exposes >= P devices.
  stacked     single-device fallback: the SAME per-shard function under
              ``vmap(axis_name=...)``; jax batches ``lax.all_to_all`` /
              ``lax.pmean`` across the vmapped axis with identical
              semantics, so the program is bit-compatible with the mesh
              version while running on one chip.
  sequential  legible Python-loop reference (sequential.py) — the parity
              oracle for tests/test_engine_parity.py and the numerically
              faithful descendant of the original per-partition driver.

GraphSAGE's full-graph mean aggregation routes through the Pallas
``segment_agg`` kernel (``use_pallas_agg=True``) with the jnp segment-op
reference as interpret-mode fallback.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.gp.trainer import (GPHyperParams, make_fullgraph_loss_fn,
                               make_personalize_partition_step,
                               make_personalize_step)
from ..graph.distributed import (PartitionedGraph, make_distributed_forward,
                                 make_overlap_forward, make_pallas_mean_agg,
                                 make_pallas_split_agg, make_ref_mean_agg,
                                 make_ref_split_agg)
from ..train.metrics import f1_scores_jnp
from ..train.optim import apply_updates
from .compat import shard_map_compat
from .stacking import (build_stacked_split_vjp_blocks,
                       build_stacked_vjp_blocks, stack_pytrees)

__all__ = ["AXIS", "EngineConfig", "SPMDEngine", "stack_epoch_batches"]

AXIS = "parts"


@dataclass(frozen=True)
class EngineConfig:
    mode: str = "auto"              # auto | spmd | stacked | sequential
    use_pallas_agg: bool = True     # route eval aggregation through Pallas
    interpret: bool = True          # Pallas interpret mode (CPU container)
    dtype: Any = jnp.float32        # float dtype of graph features
    # boundary/interior split forward: overlap the halo exchange with
    # interior aggregation + the self-term matmul, and restrict dense
    # compute to owned rows (DESIGN.md §5)
    overlap_halo: bool = False
    # 0 = one all_to_all; >= 1 = ppermute ring with that many chunks per
    # step (per-chunk sends interleave on a real mesh; bit-identical data)
    ring_chunks: int = 0
    # objective of the FULL-GRAPH phase-0 mode (the sampled path's loss is
    # the loss_fn the engine is constructed with): "ce" | "focal"
    fg_loss: str = "ce"


def _resolve_mode(mode: str, num_parts: int) -> str:
    if mode != "auto":
        return mode
    if num_parts > 1 and len(jax.devices()) >= num_parts:
        return "spmd"
    return "stacked"


def stack_epoch_batches(samplers, make_batch: Callable, num_parts: int):
    """Draw one epoch of minibatches from every host's sampler and stack them
    into ``(iters, P, ...)`` arrays for the fused epoch step.

    Mirrors the original driver's schedule exactly: ``iters`` is the longest
    host's batch count and shorter hosts wrap around (``it % len``).  Returns
    ``(batches, host_seconds, iters)`` where ``host_seconds[p]`` is the
    host-side sampling/gather time attributed to partition p (the DistDGL
    CPU-worker cost the paper's epoch times include).
    """
    import time

    host_batches = [s.batches() for s in samplers]
    iters = max(len(b) for b in host_batches)
    t_host = np.zeros(num_parts)
    rows = []
    for it in range(iters):
        per_p = []
        for p in range(num_parts):
            hb = host_batches[p]
            nodes = hb[it % len(hb)]
            t0 = time.perf_counter()
            per_p.append(make_batch(nodes))
            t_host[p] += time.perf_counter() - t0
        rows.append(stack_pytrees(per_p))          # (P, ...)
    return stack_pytrees(rows), t_host, iters      # (iters, P, ...)


class SPMDEngine:
    """Fused-epoch executor over a stacked :class:`PartitionedGraph`.

    Public surface (identical across modes; see sequential.py for the
    reference implementation):

      phase0_epoch(params, opt_state, batches) ->
          (params, opt_state, losses (I, P), val_micro (P,))
      phase0_epoch_async(params, opt_state, keys) ->
          (params, opt_state, losses (I, P), val_micro (P,))
      phase1_epoch(pparams, popt, batches, global_params, budgets) ->
          (pparams, popt, losses (I, P), val_micro (P,))
      phase1_epoch_async(pparams, popt, keys, budgets, global_params) ->
          (pparams, popt, losses (i_run, P), val_micro (P,))
      evaluate(params_or_pparams, split) -> (micro (P,), preds (P, maxN))

    ``budgets`` is a per-partition iteration budget (int32, (P,)); a bool
    ``active`` vector is accepted and promoted to full-epoch-or-zero.  The
    async variants need :meth:`set_device_sampler` and run the epoch draw +
    fanout sampling + feature gather on the epoch trace (DESIGN.md §4, §7);
    ``phase0_epoch_async`` additionally fuses the validation eval forward
    into the SAME compiled call, so a generalization epoch is one
    host→device round-trip.
    """

    def __init__(self, model, loss_fn, optimizer, pg: PartitionedGraph,
                 hp: GPHyperParams = GPHyperParams(),
                 config: EngineConfig = EngineConfig()):
        self.model = model
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.hp = hp
        self.config = config
        self.num_parts = pg.num_parts
        self.num_classes = model.num_classes
        self.max_nodes = pg.max_nodes
        self.mode = _resolve_mode(config.mode, pg.num_parts)

        f = config.dtype
        self.shards = {
            "features": jnp.asarray(pg.features, f),
            "send_idx": jnp.asarray(pg.send_idx),
            "send_mask": jnp.asarray(pg.send_mask, f),
            "recv_pos": jnp.asarray(pg.recv_pos),
        }
        def _as_blk(d: dict) -> dict:
            # one nested pytree per segment_mean_op call site: int arrays
            # stay int32, float structure follows the feature dtype
            return {k: jnp.asarray(v, f) if v.dtype == np.float32
                    else jnp.asarray(v) for k, v in d.items()}

        if config.overlap_halo:
            # split forward state: the per-partition interior row count plus
            # ONE aggregation backend's structures (the other is never read)
            self.shards["n_int"] = jnp.asarray(pg.n_int, jnp.int32)
            if config.use_pallas_agg:
                bi, bb = build_stacked_split_vjp_blocks(pg)
                self.shards["blk_int"] = _as_blk(bi)
                self.shards["blk_bnd"] = _as_blk(bb)
            else:
                self.shards.update({
                    "int_src": jnp.asarray(pg.int_src),
                    "int_dst": jnp.asarray(pg.int_dst),
                    "bnd_src": jnp.asarray(pg.bnd_src),
                    "bnd_dst": jnp.asarray(pg.bnd_dst),
                    "deg": jnp.asarray(pg.deg, f),
                })
        else:
            self.shards.update({
                "edge_src": jnp.asarray(pg.edge_src),
                "edge_dst": jnp.asarray(pg.edge_dst),
                "edge_mask": jnp.asarray(pg.edge_mask, f),
            })
            if config.use_pallas_agg:
                self.shards["blk"] = _as_blk(build_stacked_vjp_blocks(pg))
        self.labels = jnp.asarray(pg.labels)
        self.masks = {
            "train": jnp.asarray(pg.train_mask),
            "val": jnp.asarray(pg.val_mask),
            "test": jnp.asarray(pg.test_mask),
        }

        meta = {"max_nodes": pg.max_nodes, "own_cap": pg.own_cap}
        if config.overlap_halo:
            aggs = (make_pallas_split_agg(pg.own_cap, interpret=config.interpret)
                    if config.use_pallas_agg else make_ref_split_agg(pg.own_cap))
            self.fwd = make_overlap_forward(
                model, meta, axis_name=AXIS, agg_interior=aggs[0],
                agg_boundary=aggs[1], ring_chunks=config.ring_chunks)
        else:
            agg = (make_pallas_mean_agg(pg.max_nodes, interpret=config.interpret)
                   if config.use_pallas_agg else make_ref_mean_agg(pg.max_nodes))
            self.fwd = make_distributed_forward(model, meta, axis_name=AXIS,
                                                agg=agg)
        # full-graph phase-0: value_and_grad straight through self.fwd (the
        # halo-exchange forward whose aggregation op carries a custom VJP)
        self._fg_loss = make_fullgraph_loss_fn(self.fwd, loss=config.fg_loss)
        self._pstep = make_personalize_step(loss_fn, optimizer, hp)
        self._device_sampler = None
        self._sampler_gen = 0
        self.last_eval_seconds = 0.0   # execution time of the latest
                                       # separately-compiled evaluate() call
        self._mesh = None
        if self.mode == "spmd":
            from ..launch.mesh import make_partition_mesh
            self._mesh = make_partition_mesh(self.num_parts, AXIS)
        self._cache: dict = {}

    # ------------------------------------------------------------ plumbing
    def _shape_key(self, name: str, args) -> tuple:
        # shardings are part of the key: an AOT executable is specialised to
        # its input shardings, and epoch 2's params arrive sharded over the
        # mesh while epoch 1's broadcast-fresh params were replicated
        leaves = jax.tree_util.tree_leaves(args)
        return (name,) + tuple(
            (l.shape, str(l.dtype), str(getattr(l, "sharding", "")))
            for l in leaves)

    def _compiled(self, name: str, fn: Callable, *args):
        """AOT lower+compile once per input-shape signature, so epoch timing
        in the pipeline never includes XLA compilation."""
        key = self._shape_key(name, args)
        if key not in self._cache:
            self._cache[key] = jax.jit(fn).lower(*args).compile()
        return self._cache[key]

    def _micro_of(self, preds, labels, mask):
        lab = jnp.where(mask, labels, -1)
        micro, _, _ = f1_scores_jnp(preds, lab, self.num_classes)
        return micro

    # ------------------------------------------------- stacked (vmap) mode
    def _eval_stacked(self, params, split: str, per_partition_params: bool):
        in_axes = (0 if per_partition_params else None, 0)
        logits = jax.vmap(self.fwd, axis_name=AXIS, in_axes=in_axes)(
            params, self.shards)                     # (P, maxN, C)
        preds = jnp.argmax(logits, axis=-1)
        micro = jax.vmap(self._micro_of)(preds, self.labels, self.masks[split])
        return micro, preds

    def _phase0_stacked(self, params, opt_state, batches):
        num_parts = self.num_parts

        def one_iter(carry, b_it):
            params, opt_state = carry
            losses, grads = jax.vmap(
                jax.value_and_grad(self.loss_fn), in_axes=(None, 0))(params, b_it)
            # the all-reduce: stacked-axis mean == lax.pmean on the mesh
            grads = jax.tree.map(lambda g: jnp.sum(g, axis=0) / num_parts, grads)
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state), losses

        (params, opt_state), losses = jax.lax.scan(
            one_iter, (params, opt_state), batches)
        return params, opt_state, losses

    def _fg_batch(self):
        """The full-graph 'batch': every partition's graph shard + labels +
        train mask, (P, ...)-stacked like any minibatch pytree."""
        return {"shard": self.shards, "labels": self.labels,
                "train_mask": self.masks["train"]}

    def _phase0_fullgraph_stacked(self, params, opt_state, iters: int):
        num_parts = self.num_parts
        batch = self._fg_batch()

        def one_iter(carry, _):
            params, opt_state = carry
            # vmap with the collective axis bound: each partition's loss
            # differentiates THROUGH the halo exchange, so grads[p] includes
            # the paths via embeddings p shipped to other partitions
            losses, grads = jax.vmap(
                jax.value_and_grad(self._fg_loss), in_axes=(None, 0),
                axis_name=AXIS)(params, batch)
            grads = jax.tree.map(lambda g: jnp.sum(g, axis=0) / num_parts, grads)
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state), losses

        (params, opt_state), losses = jax.lax.scan(
            one_iter, (params, opt_state), None, length=iters)
        return params, opt_state, losses

    def _phase0_fullgraph_spmd(self, params, opt_state, iters: int):
        def shard_fn(params, opt_state, shard_s, labels_s, mask_s):
            batch = {"shard": jax.tree.map(lambda x: x[0], shard_s),
                     "labels": labels_s[0], "train_mask": mask_s[0]}

            def one(carry, _):
                p, o = carry
                loss, grads = jax.value_and_grad(self._fg_loss)(p, batch)
                grads = jax.lax.pmean(grads, AXIS)
                updates, o = self.optimizer.update(grads, o, p)
                return (apply_updates(p, updates), o), loss

            (params, opt_state), losses = jax.lax.scan(
                one, (params, opt_state), None, length=iters)
            return params, opt_state, losses[:, None]

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(), P(), P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(), P(), P(None, AXIS)))
        return fn(params, opt_state, self.shards, self.labels,
                  self.masks["train"])

    def _phase0_async_partition_program(self):
        """ONE partition's fused generalization epoch: epoch draw (uniform
        shuffle, or the CBS-weighted Eq. 3 mini-epoch when the sampler is
        class-balanced), per-iteration batch materialisation, the train scan
        with the cross-partition gradient mean, and the validation eval
        forward — all on a single trace (DESIGN.md §7).  The SINGLE body both
        modes execute, so PRNG consumption order cannot drift between them.

        The gradient all-reduce is spelled ``all_gather`` + a local
        stack-axis sum: pure data movement followed by the SAME deterministic
        reduction the sequential oracle performs, which is what makes the
        spmd mesh mode bit-for-bit with the reference (a ``pmean``'s
        reduction order is the collective implementation's choice).
        """
        ds = self._device_sampler
        num_parts = self.num_parts

        def per_part(params, opt_state, key, logp_row, train_row, k_row,
                     shard, labels, val_mask):
            kd, ke = jax.random.split(key)
            nodes, valid = ds.draw_epoch(kd, logp_row, train_row, k_row)
            iter_keys = jax.random.split(ke, ds.num_batches)

            def one(carry, xs):
                n_i, v_i, k_i = xs
                p, o = carry
                batch = ds.make_batch(k_i, n_i, v_i)
                loss, grads = jax.value_and_grad(self.loss_fn)(p, batch)
                g_all = jax.lax.all_gather(grads, AXIS)        # (P, ...)
                grads = jax.tree.map(
                    lambda g: jnp.sum(g, axis=0) / num_parts, g_all)
                updates, o = self.optimizer.update(grads, o, p)
                return (apply_updates(p, updates), o), loss

            (params, opt_state), losses = jax.lax.scan(
                one, (params, opt_state), (nodes, valid, iter_keys))
            # fused eval: the validation forward (halo exchange + blocked
            # aggregation + on-device F1) on the epoch's final params, in
            # the SAME device program as the train scan
            preds = jnp.argmax(self.fwd(params, shard), axis=-1)
            micro = self._micro_of(preds, labels, val_mask)
            return params, opt_state, losses, micro

        return per_part

    def _phase0_async_stacked(self, params, opt_state, keys):
        ds = self._device_sampler
        per_part = self._phase0_async_partition_program()
        params, opt_state, losses, micro = jax.vmap(
            per_part, axis_name=AXIS,
            in_axes=(None, None, 0, 0, 0, 0, 0, 0, 0))(
                params, opt_state, keys, ds.logp, ds.train_idx, ds.k,
                self.shards, self.labels, self.masks["val"])
        # every partition applies the identical mean update to the identical
        # replica: return one copy (bitwise equal across the stacked axis)
        return (jax.tree.map(lambda x: x[0], params),
                jax.tree.map(lambda x: x[0], opt_state),
                losses.T, micro)                    # (I, P), (P,)

    def _phase0_async_spmd(self, params, opt_state, keys):
        ds = self._device_sampler

        def shard_fn(params, opt_state, key_s, logp_s, train_s, k_s,
                     shard_s, labels_s, mask_s):
            per_part = self._phase0_async_partition_program()
            sh = jax.tree.map(lambda x: x[0], shard_s)
            params, opt_state, losses, micro = per_part(
                params, opt_state, key_s[0], logp_s[0], train_s[0], k_s[0],
                sh, labels_s[0], mask_s[0])
            return params, opt_state, losses[:, None], micro[None]

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(), P(), P(AXIS), P(AXIS), P(AXIS), P(AXIS),
                      P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(), P(), P(None, AXIS), P(AXIS)))
        return fn(params, opt_state, keys, ds.logp, ds.train_idx, ds.k,
                  self.shards, self.labels, self.masks["val"])

    def _phase1_stacked(self, pparams, popt, batches, global_params, budgets):
        def one_iter(carry, xs):
            i, b_it = xs
            pp, po = carry
            # masked variable-length scan: partition p trains while i < its
            # budget, rides through bitwise-frozen afterwards
            pp, po, losses = self._pstep(pp, po, b_it, global_params,
                                         i < budgets)
            return (pp, po), losses

        iters = jax.tree_util.tree_leaves(batches)[0].shape[0]
        (pparams, popt), losses = jax.lax.scan(
            one_iter, (pparams, popt), (jnp.arange(iters), batches))
        return pparams, popt, losses

    def _async_partition_program(self, global_params, i_run: int):
        """ONE partition's async epoch: mini-epoch draw, per-iteration batch
        materialisation, masked training scan.  The SINGLE body both modes
        execute — stacked vmaps it, spmd runs it per shard — so the PRNG
        consumption order (and with it stacked/spmd bit-parity) cannot
        drift between them."""
        ds = self._device_sampler
        pstep1 = make_personalize_partition_step(self.loss_fn, self.optimizer,
                                                 self.hp)

        def per_part(pp, po, key, budget, logp_row, train_row, k_row):
            kd, ke = jax.random.split(key)
            nodes, valid = ds.draw_epoch(kd, logp_row, train_row, k_row)
            iter_keys = jax.random.split(ke, ds.num_batches)

            def one(carry, xs):
                i, n_i, v_i, k_i = xs
                p, o = carry
                batch = ds.make_batch(k_i, n_i, v_i)
                p, o, l = pstep1(p, o, batch, global_params, i < budget)
                return (p, o), l

            (pp, po), losses = jax.lax.scan(
                one, (pp, po),
                (jnp.arange(i_run), nodes[:i_run], valid[:i_run],
                 iter_keys[:i_run]))
            return pp, po, losses

        return per_part

    def _phase1_async_stacked(self, pparams, popt, keys, budgets,
                              global_params, i_run: int):
        ds = self._device_sampler
        per_part = self._async_partition_program(global_params, i_run)
        pparams, popt, losses = jax.vmap(
            per_part, in_axes=(0, 0, 0, 0, 0, 0, 0))(
                pparams, popt, keys, budgets,
                ds.logp, ds.train_idx, ds.k)
        return pparams, popt, losses.T              # (i_run, P)

    # --------------------------------------------------- spmd (mesh) mode
    def _phase0_spmd(self, params, opt_state, batches):
        # like make_generalize_step(axis_names=(AXIS,)) but reporting the
        # LOCAL loss: the stacked/sequential paths record per-host losses, so
        # the engine's (I, P) loss matrix must stay per-host for parity
        def gen_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            grads = jax.lax.pmean(grads, AXIS)
            updates, opt_state = self.optimizer.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, loss

        def shard_fn(params, opt_state, b_s):
            b = jax.tree.map(lambda x: x[:, 0], b_s)       # (I, ...)

            def one(carry, bi):
                p, o = carry
                p, o, l = gen_step(p, o, bi)
                return (p, o), l

            (params, opt_state), losses = jax.lax.scan(one, (params, opt_state), b)
            return params, opt_state, losses[:, None]

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(), P(), P(None, AXIS)),
            out_specs=(P(), P(), P(None, AXIS)))
        return fn(params, opt_state, batches)

    def _phase1_spmd(self, pparams, popt, batches, global_params, budgets):
        pstep1 = make_personalize_partition_step(self.loss_fn, self.optimizer,
                                                 self.hp)

        def shard_fn(pp_s, po_s, b_s, gp, bud_s):
            pp = jax.tree.map(lambda x: x[0], pp_s)
            po = jax.tree.map(lambda x: x[0], po_s)
            b = jax.tree.map(lambda x: x[:, 0], b_s)
            bud = bud_s[0]
            iters = jax.tree_util.tree_leaves(b)[0].shape[0]

            def one(carry, xs):
                i, bi = xs
                p, o = carry
                p, o, l = pstep1(p, o, bi, gp, i < bud)
                return (p, o), l

            (pp, po), losses = jax.lax.scan(one, (pp, po),
                                            (jnp.arange(iters), b))
            return (jax.tree.map(lambda x: x[None], pp),
                    jax.tree.map(lambda x: x[None], po),
                    losses[:, None])

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(AXIS), P(AXIS), P(None, AXIS), P(), P(AXIS)),
            out_specs=(P(AXIS), P(AXIS), P(None, AXIS)))
        return fn(pparams, popt, batches, global_params, budgets)

    def _phase1_async_spmd(self, pparams, popt, keys, budgets, global_params,
                           i_run: int):
        ds = self._device_sampler

        def shard_fn(pp_s, po_s, key_s, bud_s, gp, logp_s, train_s, k_s):
            per_part = self._async_partition_program(gp, i_run)
            pp = jax.tree.map(lambda x: x[0], pp_s)
            po = jax.tree.map(lambda x: x[0], po_s)
            pp, po, losses = per_part(pp, po, key_s[0], bud_s[0],
                                      logp_s[0], train_s[0], k_s[0])
            return (jax.tree.map(lambda x: x[None], pp),
                    jax.tree.map(lambda x: x[None], po),
                    losses[:, None])

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(),
                      P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(AXIS), P(AXIS), P(None, AXIS)))
        return fn(pparams, popt, keys, budgets, global_params,
                  ds.logp, ds.train_idx, ds.k)

    def _eval_spmd(self, params, split: str, per_partition_params: bool):
        def shard_fn(prm, shard_s, labels_s, mask_s):
            p = jax.tree.map(lambda x: x[0], prm) if per_partition_params else prm
            sh = jax.tree.map(lambda x: x[0], shard_s)
            preds = jnp.argmax(self.fwd(p, sh), axis=-1)
            micro = self._micro_of(preds, labels_s[0], mask_s[0])
            return micro[None], preds[None]

        fn = shard_map_compat(
            shard_fn, self._mesh,
            in_specs=(P(AXIS) if per_partition_params else P(),
                      P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(AXIS), P(AXIS)))
        return fn(params, self.shards, self.labels, self.masks[split])

    # ------------------------------------------------------- public surface
    # Epoch methods return a trailing ``device_seconds``: wall time of the
    # compiled TRAIN scan only.  The validation forward is a separately
    # compiled (still internally fused: halo all_to_all + aggregation +
    # on-device F1) call whose cost is identical across sampler/partition
    # ablations, so excluding it — like the original per-batch driver did —
    # keeps epoch-time comparisons about training.  AOT compilation happens
    # outside every timed window.

    def _timed(self, fn, *args):
        import time

        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        return out, time.perf_counter() - t0

    def phase0_epoch(self, params, opt_state, batches):
        impl = self._phase0_spmd if self.mode == "spmd" else self._phase0_stacked
        fn = self._compiled("phase0", impl, params, opt_state, batches)
        (params, opt_state, losses), dt = self._timed(
            fn, params, opt_state, batches)
        val_micro, _ = self.evaluate(params, "val", per_partition_params=False)
        return params, opt_state, losses, val_micro, dt

    def phase0_epoch_async(self, params, opt_state, keys):
        """One fused generalization epoch: the on-device epoch draw (uniform
        shuffle of the local train set, or the CBS mini-epoch when the
        attached sampler is class-balanced), batch materialisation, the
        synchronous train scan with the cross-partition gradient mean, AND
        the validation eval forward — all in ONE compiled device program, so
        an epoch costs one host→device round-trip instead of shipping
        ``iters`` host-built batches plus a separate eval call.

        ``keys`` is (P, 2) uint32 per-partition PRNG state (fold the epoch
        index into a per-partition base key).  Unlike phase-1 there are no
        budgets: generalization is synchronous data-parallel SGD, every
        partition scans all ``num_batches`` iterations.  Returns
        ``(params, opt_state, losses (I, P), val_micro (P,), device_seconds)``
        where the timing, unlike :meth:`phase0_epoch`, INCLUDES the fused
        eval (it is part of the one device call; the pipeline's epoch-time
        attribution accounts for that).
        """
        if self._device_sampler is None:
            raise ValueError("phase0_epoch_async needs set_device_sampler()")
        impl = (self._phase0_async_spmd if self.mode == "spmd"
                else self._phase0_async_stacked)
        fn = self._compiled(f"phase0_async-g{self._sampler_gen}", impl,
                            params, opt_state, keys)
        (params, opt_state, losses, val_micro), dt = self._timed(
            fn, params, opt_state, keys)
        self.last_eval_seconds = 0.0    # eval is inside dt on this path
        return params, opt_state, losses, val_micro, dt

    def phase0_fullgraph_epoch(self, params, opt_state, iters: int = 1):
        """Full-graph phase-0 epoch: ``iters`` full-batch steps whose
        ``value_and_grad`` runs straight through the distributed forward —
        per-layer halo exchange, the differentiable Pallas aggregation op
        (forward AND transpose kernels on the traced path when
        ``use_pallas_agg=True``) and the cross-partition gradient mean.  The
        centralized (P=1) configuration is the paper's Table IV baseline at
        full-graph scale; P>1 is per-partition full-graph training."""
        impl = (self._phase0_fullgraph_spmd if self.mode == "spmd"
                else self._phase0_fullgraph_stacked)
        fn = self._compiled(f"phase0_fg-{iters}",
                            lambda p, o: impl(p, o, iters), params, opt_state)
        (params, opt_state, losses), dt = self._timed(fn, params, opt_state)
        val_micro, _ = self.evaluate(params, "val", per_partition_params=False)
        return params, opt_state, losses, val_micro, dt

    @staticmethod
    def _as_budgets(active_or_budgets, iters: int):
        """Phase-1 gating is expressed as per-partition iteration BUDGETS;
        a bool `active` vector (the pre-async API) means full-epoch-or-zero."""
        b = jnp.asarray(active_or_budgets)
        if b.dtype == jnp.bool_:
            b = jnp.where(b, iters, 0)
        return b.astype(jnp.int32)

    def phase1_epoch(self, pparams, popt, batches, global_params, budgets):
        iters = jax.tree_util.tree_leaves(batches)[0].shape[0]
        budgets = self._as_budgets(budgets, iters)
        impl = self._phase1_spmd if self.mode == "spmd" else self._phase1_stacked
        fn = self._compiled("phase1", impl, pparams, popt, batches,
                            global_params, budgets)
        (pparams, popt, losses), dt = self._timed(
            fn, pparams, popt, batches, global_params, budgets)
        val_micro, _ = self.evaluate(pparams, "val", per_partition_params=True)
        return pparams, popt, losses, val_micro, dt

    # ----------------------------------------------- async personalization
    def set_device_sampler(self, sampler) -> None:
        """Attach a :class:`DeviceEpochSampler`; required by
        :meth:`phase0_epoch_async` and :meth:`phase1_epoch_async` (the
        fully-on-device epoch paths)."""
        self._device_sampler = sampler
        # the sampler's arrays are baked into the async trace as constants,
        # so a new sampler must never hit an old executable (shapes alone
        # can't distinguish two same-sized graphs) — and the superseded
        # executables pin those arrays in device memory, so evict them
        self._sampler_gen += 1
        self._cache = {k: v for k, v in self._cache.items()
                       if not str(k[0]).startswith(("phase0_async-",
                                                    "phase1_async-"))}

    def phase1_epoch_async(self, pparams, popt, keys, budgets, global_params):
        """One asynchronous personalization step: mini-epoch resample, batch
        shuffle, fanout sampling, feature gather AND the masked training scan
        all inside ONE device program — no host NumPy on the mini-epoch path.

        ``keys`` is (P, 2) uint32 per-partition PRNG state; ``budgets`` (P,)
        int32 from :meth:`GPController.phase1_budgets`.  The scan's static
        trip count is max(budgets) rounded up to a power of two (bounding
        recompiles to log2(I) shapes), so converged partitions stop paying
        for the stragglers' full epochs.
        """
        if self._device_sampler is None:
            raise ValueError("phase1_epoch_async needs set_device_sampler()")
        budgets = self._as_budgets(budgets, self._device_sampler.num_batches)
        cap = self._device_sampler.num_batches
        need = int(np.asarray(budgets).max())
        i_run = 1
        while i_run < min(need, cap):
            i_run *= 2
        i_run = min(i_run, cap)
        impl = (self._phase1_async_spmd if self.mode == "spmd"
                else self._phase1_async_stacked)
        fn = self._compiled(
            f"phase1_async-{i_run}-g{self._sampler_gen}",
            lambda pp, po, k, b, gp: impl(pp, po, k, b, gp, i_run),
            pparams, popt, keys, budgets, global_params)
        (pparams, popt, losses), dt = self._timed(
            fn, pparams, popt, keys, budgets, global_params)
        val_micro, _ = self.evaluate(pparams, "val", per_partition_params=True)
        return pparams, popt, losses, val_micro, dt

    def evaluate(self, params, split: str = "test",
                 per_partition_params: bool = True):
        if self.mode == "spmd":
            impl = lambda prm: self._eval_spmd(prm, split, per_partition_params)
        else:
            impl = lambda prm: self._eval_stacked(prm, split, per_partition_params)
        fn = self._compiled(f"eval-{split}-{per_partition_params}", impl, params)
        # execution time of the compiled eval (AOT compile excluded), so the
        # pipeline can compare host-path epochs, whose eval is a separate
        # call, against the fused async epoch whose timing includes eval
        out, self.last_eval_seconds = self._timed(fn, params)
        return out
