"""Host-side preprocessing for the SPMD engine: stack every partition's
blocked-CSR aggregation structure (and per-epoch minibatches) into uniform
``(P, ...)`` arrays.

The Pallas ``segment_agg`` kernel needs a static block layout; partitions
have ragged edge counts, so each partition's :class:`EdgeBlocks` is padded to
the fleet-wide maximum ``(num_blocks, edges_per_block)``.  Padding edges
carry ``mask == 0`` and source id 0, so they gather a real row but contribute
nothing to the reduction — the same trick the kernel already uses for
intra-block padding.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..graph.distributed import PartitionedGraph
from ..kernels.segment_agg import BEC, BN, build_edge_blocks

__all__ = ["StackedBlocks", "build_stacked_blocks", "build_stacked_split_blocks",
           "stack_pytrees"]


@dataclass(frozen=True)
class StackedBlocks:
    """Per-partition blocked CSR, padded to common shapes (leading axis P)."""

    num_blocks: int            # nb (common across partitions)
    edges_per_block: int       # BE (fleet-wide max, multiple of BEC)
    src: np.ndarray            # (P, nb, BE) int32 local source ids, pad -> 0
    local_dst: np.ndarray      # (P, nb, BE) int32 in [0, BN)
    mask: np.ndarray           # (P, nb, BE) float32
    deg: np.ndarray            # (P, nb, BN) float32 (>=1 where real)


def _local_csr(pg: PartitionedGraph, p: int) -> tuple[np.ndarray, np.ndarray]:
    """Rebuild partition p's local CSR (dst-major, ascending — the order
    build_partitioned_graph emits) from its padded edge arrays."""
    real = pg.edge_mask[p] > 0
    src = pg.edge_src[p][real].astype(np.int64)
    dst = pg.edge_dst[p][real].astype(np.int64)
    counts = np.bincount(dst, minlength=pg.max_nodes)
    indptr = np.zeros(pg.max_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, src


def build_stacked_blocks(pg: PartitionedGraph, bn: int = BN,
                         bec: int = BEC) -> StackedBlocks:
    per_part = []
    for p in range(pg.num_parts):
        indptr, indices = _local_csr(pg, p)
        per_part.append(build_edge_blocks(indptr, indices, bn=bn, bec=bec))

    nb = max(b.num_blocks for b in per_part)
    be = max(b.edges_per_block for b in per_part)
    P = pg.num_parts
    src = np.zeros((P, nb, be), dtype=np.int32)
    ldst = np.zeros((P, nb, be), dtype=np.int32)
    mask = np.zeros((P, nb, be), dtype=np.float32)
    deg = np.ones((P, nb, bn), dtype=np.float32)
    for p, b in enumerate(per_part):
        src[p, : b.num_blocks, : b.edges_per_block] = b.src
        ldst[p, : b.num_blocks, : b.edges_per_block] = b.local_dst
        mask[p, : b.num_blocks, : b.edges_per_block] = b.mask
        deg[p, : b.num_blocks] = b.deg
    return StackedBlocks(num_blocks=nb, edges_per_block=be,
                         src=src, local_dst=ldst, mask=mask, deg=deg)


def _stack_blocks(per_part, num_parts: int, bn: int) -> StackedBlocks:
    """Pad a list of per-partition EdgeBlocks to fleet-common shapes
    (at least one block so an all-empty fleet still yields a valid grid)."""
    nb = max(1, max(b.num_blocks for b in per_part))
    be = max(b.edges_per_block for b in per_part)
    P = num_parts
    src = np.zeros((P, nb, be), dtype=np.int32)
    ldst = np.zeros((P, nb, be), dtype=np.int32)
    mask = np.zeros((P, nb, be), dtype=np.float32)
    deg = np.ones((P, nb, bn), dtype=np.float32)
    for p, b in enumerate(per_part):
        src[p, : b.num_blocks, : b.edges_per_block] = b.src
        ldst[p, : b.num_blocks, : b.edges_per_block] = b.local_dst
        mask[p, : b.num_blocks, : b.edges_per_block] = b.mask
        deg[p, : b.num_blocks] = b.deg
    return StackedBlocks(num_blocks=nb, edges_per_block=be,
                         src=src, local_dst=ldst, mask=mask, deg=deg)


def _sub_csr(src: np.ndarray, dst: np.ndarray, mask: np.ndarray,
             num_rows: int, row_base: int = 0):
    """CSR over a destination sub-range rebased to start at row 0 (edges
    must already be dst-major ascending, as build_partitioned_graph emits)."""
    real = mask > 0
    s = src[real].astype(np.int64)
    d = dst[real].astype(np.int64) - row_base
    counts = np.bincount(d, minlength=num_rows) if num_rows else np.zeros(0, np.int64)
    indptr = np.zeros(num_rows + 1, dtype=np.int64)
    np.cumsum(counts[:num_rows], out=indptr[1:])
    return indptr, s


def build_stacked_split_blocks(pg: PartitionedGraph, bn: int = BN,
                               bec: int = BEC):
    """Blocked structures for the overlapped forward's interior/boundary
    aggregation split (DESIGN.md §5).

    Returns ``(interior, boundary)`` :class:`StackedBlocks`.  Each half
    blocks ONLY its own row range — interior rows ``[0, n_int)``, boundary
    rows rebased to ``[0, n_own - n_int)`` — so each kernel grid scales
    with its row count, and ``segment_agg_rows`` places the halves at row
    0 and at the partition's ``n_int`` offset respectively.  A
    zero-boundary (or zero-interior) partition contributes all-pad blocks
    that aggregate to exact zeros.
    """
    ints, bnds = [], []
    for p in range(pg.num_parts):
        ip, isrc = _sub_csr(pg.int_src[p], pg.int_dst[p], pg.int_mask[p],
                            int(pg.n_int[p]))
        ints.append(build_edge_blocks(ip, isrc, bn=bn, bec=bec))
        n_bnd = int(pg.n_own[p] - pg.n_int[p])
        bp, bsrc = _sub_csr(pg.bnd_src[p], pg.bnd_dst[p], pg.bnd_mask[p],
                            n_bnd, row_base=int(pg.n_int[p]))
        bnds.append(build_edge_blocks(bp, bsrc, bn=bn, bec=bec))
    return (_stack_blocks(ints, pg.num_parts, bn),
            _stack_blocks(bnds, pg.num_parts, bn))


def stack_pytrees(trees):
    """Stack a list of identical-structure pytrees along a new leading axis."""
    import jax
    import jax.numpy as jnp

    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
