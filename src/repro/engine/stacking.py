"""Host-side preprocessing for the SPMD engine: stack every partition's
blocked-CSR aggregation structure (and per-epoch minibatches) into uniform
``(P, ...)`` arrays.

The Pallas ``segment_agg`` kernel needs a static block layout; partitions
have ragged edge counts, so each partition's :class:`EdgeBlocks` is padded to
the fleet-wide maximum ``(num_blocks, edges_per_block)``.  Padding edges
carry ``mask == 0`` and source id 0, so they gather a real row but contribute
nothing to the reduction — the same trick the kernel already uses for
intra-block padding.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..graph.distributed import PartitionedGraph
from ..graph.featstore import PartitionFeatStore, build_partition_feat_store
from ..kernels.segment_agg import (BEC, BN, build_edge_blocks,
                                   build_transpose_blocks)

__all__ = ["StackedBlocks", "build_stacked_vjp_blocks",
           "build_stacked_split_vjp_blocks", "build_stacked_feat_store",
           "build_stacked_halo_cache", "build_stacked_halo_residual",
           "stack_pytrees"]


def build_stacked_feat_store(pg: PartitionedGraph, hot_frac: float,
                             policy: str, dtype) -> tuple[dict, PartitionFeatStore]:
    """Stacked device/host split of the feature plane (DESIGN.md §12).

    Returns ``(device_entries, fs)``: ``device_entries`` holds the
    shard-dict additions — ``fs_hot`` (P, H, D) resident hot rows plus the
    ``fs_rows_hot``/``fs_rows_cold`` (P, H)/(P, C) int32 scatter maps —
    ready to merge into the engine's stacked shards in place of
    ``features``; ``fs`` is the underlying :class:`PartitionFeatStore`
    whose ``cold`` (P, C, D) numpy array is the per-call host staging
    buffer (it must stay OFF device — shipping it as a compiled-call
    argument is the whole point of the store).
    """
    import jax.numpy as jnp

    fs = build_partition_feat_store(pg, hot_frac, policy, np.dtype(dtype))
    entries = {"fs_hot": jnp.asarray(fs.hot, dtype),
               "fs_rows_hot": jnp.asarray(fs.rows_hot),
               "fs_rows_cold": jnp.asarray(fs.rows_cold)}
    return entries, fs


def build_stacked_halo_cache(pg: PartitionedGraph,
                             layer_dims: tuple[int, ...]) -> dict:
    """Zero-initialised historical-embedding halo cache, stacked ``(P, ...)``
    for the fused epoch programs (one leading axis per partition, carried
    through the cached eval as state).

    Per partition the cache keeps each layer's last-received exchange
    buffers in recv layout ``(P, maxS, D_layer)``; ``layer_dims`` is the
    width each layer's exchange ships (``model.layer_input_dims``: raw
    features first, then hidden embeddings).  All-zero is the correct empty
    state: pad slots must stay zero forever (trash-row hygiene), and
    :func:`halo_refresh_plan` always schedules a FULL refresh at age 0, so
    no real cached row is ever read before it has been received once.
    """
    P = pg.num_parts
    max_s = pg.send_idx.shape[-1]
    return {f"h{i}": np.zeros((P, P, max_s, d), dtype=np.float32)
            for i, d in enumerate(layer_dims)}


def build_stacked_halo_residual(pg: PartitionedGraph,
                                layer_dims: tuple[int, ...]) -> dict:
    """Zero-initialised error-feedback residual for the quantized halo
    exchange (DESIGN.md §11), stacked ``(P, ...)`` like the halo cache.

    Per partition, ``r{i}`` holds layer i's SEND-side quantization error in
    send-list layout ``(P, maxS, D_layer)`` — ``r{i}[q, s]`` is the error
    left behind the last time send slot s's row was quantized for peer q.
    Zero is the exact empty state: before the first exchange nothing has
    been rounded away, and pad slots (``send_mask == 0``) are kept zero by
    the masked residual update so they never leak into the trash row.
    """
    P = pg.num_parts
    max_s = pg.send_idx.shape[-1]
    return {f"r{i}": np.zeros((P, P, max_s, d), dtype=np.float32)
            for i, d in enumerate(layer_dims)}


@dataclass(frozen=True)
class StackedBlocks:
    """Per-partition blocked CSR, padded to common shapes (leading axis P)."""

    num_blocks: int            # nb (common across partitions)
    edges_per_block: int       # BE (fleet-wide max, multiple of BEC)
    src: np.ndarray            # (P, nb, BE) int32 local source ids, pad -> 0
    local_dst: np.ndarray      # (P, nb, BE) int32 in [0, BN)
    mask: np.ndarray           # (P, nb, BE) float32
    deg: np.ndarray            # (P, nb, BN) float32 (>=1 where real)


def _local_csr(pg: PartitionedGraph, p: int) -> tuple[np.ndarray, np.ndarray]:
    """Rebuild partition p's local CSR (dst-major, ascending — the order
    build_partitioned_graph emits) from its padded edge arrays."""
    real = pg.edge_mask[p] > 0
    src = pg.edge_src[p][real].astype(np.int64)
    dst = pg.edge_dst[p][real].astype(np.int64)
    counts = np.bincount(dst, minlength=pg.max_nodes)
    indptr = np.zeros(pg.max_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, src


def _stack_blocks(per_part, num_parts: int, bn: int) -> StackedBlocks:
    """Pad a list of per-partition EdgeBlocks to fleet-common shapes
    (at least one block so an all-empty fleet still yields a valid grid)."""
    nb = max(1, max(b.num_blocks for b in per_part))
    be = max(b.edges_per_block for b in per_part)
    P = num_parts
    src = np.zeros((P, nb, be), dtype=np.int32)
    ldst = np.zeros((P, nb, be), dtype=np.int32)
    mask = np.zeros((P, nb, be), dtype=np.float32)
    deg = np.ones((P, nb, bn), dtype=np.float32)
    for p, b in enumerate(per_part):
        src[p, : b.num_blocks, : b.edges_per_block] = b.src
        ldst[p, : b.num_blocks, : b.edges_per_block] = b.local_dst
        mask[p, : b.num_blocks, : b.edges_per_block] = b.mask
        deg[p, : b.num_blocks] = b.deg
    return StackedBlocks(num_blocks=nb, edges_per_block=be,
                         src=src, local_dst=ldst, mask=mask, deg=deg)


def _sub_csr(src: np.ndarray, dst: np.ndarray, mask: np.ndarray,
             num_rows: int, row_base: int = 0):
    """CSR over a destination sub-range rebased to start at row 0 (edges
    must already be dst-major ascending, as build_partitioned_graph emits)."""
    real = mask > 0
    s = src[real].astype(np.int64)
    d = dst[real].astype(np.int64) - row_base
    counts = np.bincount(d, minlength=num_rows) if num_rows else np.zeros(0, np.int64)
    indptr = np.zeros(num_rows + 1, dtype=np.int64)
    np.cumsum(counts[:num_rows], out=indptr[1:])
    return indptr, s


def _stack_vjp_dict(fwd_list, bwd_list, num_parts: int, bn: int) -> dict:
    """Pair per-partition forward + transpose EdgeBlocks into the flat
    ``segment_mean_op`` blocks dict, each side padded fleet-wide."""
    f = _stack_blocks(fwd_list, num_parts, bn)
    b = _stack_blocks(bwd_list, num_parts, bn)
    return {"src": f.src, "dst": f.local_dst, "mask": f.mask, "deg": f.deg,
            "t_src": b.src, "t_dst": b.local_dst, "t_mask": b.mask}


def build_stacked_vjp_blocks(pg: PartitionedGraph, bn: int = BN,
                             bec: int = BEC) -> dict:
    """Stacked paired forward/transpose block structure for the whole-space
    aggregation (``segment_mean_op`` over all ``max_nodes`` local rows):
    the forward is dst-blocked CSR, the transpose is the CSC-ordered mirror
    over the same edges (grad flows dst -> src, covering owned AND halo
    source rows so the halo exchange's VJP can route gradient back to the
    owning partition)."""
    fwds, bwds = [], []
    for p in range(pg.num_parts):
        indptr, indices = _local_csr(pg, p)
        fwds.append(build_edge_blocks(indptr, indices, bn=bn, bec=bec))
        real = pg.edge_mask[p] > 0
        bwds.append(build_transpose_blocks(
            pg.edge_src[p][real], pg.edge_dst[p][real], pg.max_nodes,
            bn=bn, bec=bec))
    return _stack_vjp_dict(fwds, bwds, pg.num_parts, bn)


def build_stacked_split_vjp_blocks(pg: PartitionedGraph, bn: int = BN,
                                   bec: int = BEC) -> tuple[dict, dict]:
    """The overlapped forward's interior/boundary aggregation split
    (DESIGN.md §5) with the transpose mirrors attached: ``(interior,
    boundary)`` blocks dicts for the two ``segment_mean_op`` row-range
    calls.  Each half blocks ONLY its own row range — interior rows
    ``[0, n_int)``, boundary rows rebased to ``[0, n_own - n_int)`` (a
    zero-range partition contributes all-pad blocks that aggregate to
    exact zeros) — while its transpose covers the full ``max_nodes``
    source space, the gather side indexing the REBASED gradient sub-range
    the forward produced."""
    ints_f, ints_b, bnds_f, bnds_b = [], [], [], []
    for p in range(pg.num_parts):
        n_int = int(pg.n_int[p])
        ip, isrc = _sub_csr(pg.int_src[p], pg.int_dst[p], pg.int_mask[p],
                            n_int)
        ints_f.append(build_edge_blocks(ip, isrc, bn=bn, bec=bec))
        real_i = pg.int_mask[p] > 0
        ints_b.append(build_transpose_blocks(
            pg.int_src[p][real_i], pg.int_dst[p][real_i], pg.max_nodes,
            bn=bn, bec=bec))

        n_bnd = int(pg.n_own[p] - pg.n_int[p])
        bp, bsrc = _sub_csr(pg.bnd_src[p], pg.bnd_dst[p], pg.bnd_mask[p],
                            n_bnd, row_base=n_int)
        bnds_f.append(build_edge_blocks(bp, bsrc, bn=bn, bec=bec))
        real_b = pg.bnd_mask[p] > 0
        bnds_b.append(build_transpose_blocks(
            pg.bnd_src[p][real_b], pg.bnd_dst[p][real_b] - n_int,
            pg.max_nodes, bn=bn, bec=bec))
    return (_stack_vjp_dict(ints_f, ints_b, pg.num_parts, bn),
            _stack_vjp_dict(bnds_f, bnds_b, pg.num_parts, bn))


def stack_pytrees(trees):
    """Stack a list of identical-structure pytrees along a new leading axis."""
    import jax
    import jax.numpy as jnp

    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
