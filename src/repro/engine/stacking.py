"""Host-side preprocessing for the SPMD engine: stack every partition's
blocked-CSR aggregation structure (and per-epoch minibatches) into uniform
``(P, ...)`` arrays.

The Pallas ``segment_agg`` kernel needs a static block layout; partitions
have ragged edge counts, so each partition's :class:`EdgeBlocks` is padded to
the fleet-wide maximum ``(num_blocks, edges_per_block)``.  Padding edges
carry ``mask == 0`` and source id 0, so they gather a real row but contribute
nothing to the reduction — the same trick the kernel already uses for
intra-block padding.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..graph.distributed import PartitionedGraph
from ..kernels.segment_agg import BEC, BN, build_edge_blocks

__all__ = ["StackedBlocks", "build_stacked_blocks", "stack_pytrees"]


@dataclass(frozen=True)
class StackedBlocks:
    """Per-partition blocked CSR, padded to common shapes (leading axis P)."""

    num_blocks: int            # nb (common across partitions)
    edges_per_block: int       # BE (fleet-wide max, multiple of BEC)
    src: np.ndarray            # (P, nb, BE) int32 local source ids, pad -> 0
    local_dst: np.ndarray      # (P, nb, BE) int32 in [0, BN)
    mask: np.ndarray           # (P, nb, BE) float32
    deg: np.ndarray            # (P, nb, BN) float32 (>=1 where real)


def _local_csr(pg: PartitionedGraph, p: int) -> tuple[np.ndarray, np.ndarray]:
    """Rebuild partition p's local CSR (dst-major, ascending — the order
    build_partitioned_graph emits) from its padded edge arrays."""
    real = pg.edge_mask[p] > 0
    src = pg.edge_src[p][real].astype(np.int64)
    dst = pg.edge_dst[p][real].astype(np.int64)
    counts = np.bincount(dst, minlength=pg.max_nodes)
    indptr = np.zeros(pg.max_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, src


def build_stacked_blocks(pg: PartitionedGraph, bn: int = BN,
                         bec: int = BEC) -> StackedBlocks:
    per_part = []
    for p in range(pg.num_parts):
        indptr, indices = _local_csr(pg, p)
        per_part.append(build_edge_blocks(indptr, indices, bn=bn, bec=bec))

    nb = max(b.num_blocks for b in per_part)
    be = max(b.edges_per_block for b in per_part)
    P = pg.num_parts
    src = np.zeros((P, nb, be), dtype=np.int32)
    ldst = np.zeros((P, nb, be), dtype=np.int32)
    mask = np.zeros((P, nb, be), dtype=np.float32)
    deg = np.ones((P, nb, bn), dtype=np.float32)
    for p, b in enumerate(per_part):
        src[p, : b.num_blocks, : b.edges_per_block] = b.src
        ldst[p, : b.num_blocks, : b.edges_per_block] = b.local_dst
        mask[p, : b.num_blocks, : b.edges_per_block] = b.mask
        deg[p, : b.num_blocks] = b.deg
    return StackedBlocks(num_blocks=nb, edges_per_block=be,
                         src=src, local_dst=ldst, mask=mask, deg=deg)


def stack_pytrees(trees):
    """Stack a list of identical-structure pytrees along a new leading axis."""
    import jax
    import jax.numpy as jnp

    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
