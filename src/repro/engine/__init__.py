from .spmd import AXIS, EngineConfig, SPMDEngine, stack_epoch_batches
from .sequential import SequentialReference
from .stacking import (StackedBlocks, build_stacked_split_vjp_blocks,
                       build_stacked_vjp_blocks, stack_pytrees)

__all__ = [
    "AXIS", "EngineConfig", "SPMDEngine", "SequentialReference",
    "StackedBlocks", "build_stacked_vjp_blocks",
    "build_stacked_split_vjp_blocks", "stack_pytrees",
    "stack_epoch_batches", "make_engine",
]


def make_engine(model, loss_fn, optimizer, pg, hp=None, config=None):
    """Mode-dispatching factory: sequential -> SequentialReference, anything
    else -> SPMDEngine (which resolves auto/spmd/stacked itself)."""
    from ..core.gp.trainer import GPHyperParams

    hp = hp or GPHyperParams()
    config = config or EngineConfig()
    if config.mode == "sequential":
        return SequentialReference(model, loss_fn, optimizer, pg, hp, config)
    return SPMDEngine(model, loss_fn, optimizer, pg, hp, config)
