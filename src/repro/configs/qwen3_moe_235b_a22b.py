"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B
family; dims as assigned: 94L d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936, MoE 128e top-8]."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    citation="hf:Qwen/Qwen3-30B-A3B (assigned dims: 235B-A22B)",
    d_model=4096, vocab_size=151936,
    num_heads=64, num_kv_heads=4, head_dim=128, d_ff=1536,
    super_block=(SubLayer(mixer="attention", ffn="moe"),), num_repeats=94,
    num_experts=128, top_k=8,
    rope_theta=1_000_000.0, norm="rmsnorm", activation="swiglu",
)
