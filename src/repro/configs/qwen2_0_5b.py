"""qwen2-0.5b [dense] — GQA with QKV bias [arXiv:2407.10671].

24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151936."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    citation="arXiv:2407.10671",
    d_model=896, vocab_size=151936,
    num_heads=14, num_kv_heads=2, head_dim=64, d_ff=4864,
    super_block=(SubLayer(mixer="attention", ffn="mlp"),), num_repeats=24,
    qkv_bias=True, rope_theta=1_000_000.0, norm="rmsnorm", activation="swiglu",
    tie_embeddings=True,
)
