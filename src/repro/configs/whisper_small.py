"""whisper-small [audio] — encoder-decoder, conv frontend STUB
[arXiv:2212.04356].

12 encoder + 12 decoder layers, d_model=768, 12H, d_ff=3072, vocab=51865.
The mel-spectrogram + conv feature extractor is a stub: input_specs()
provides precomputed (B, 1500, 768) frame embeddings.  Sinusoidal positions
stand in for Whisper's learned decoder embeddings (noted in DESIGN.md)."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    citation="arXiv:2212.04356",
    d_model=768, vocab_size=51865,
    num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
    super_block=(SubLayer(mixer="attention", ffn="mlp", cross_attention=True),),
    num_repeats=12,
    encoder_layers=12, encoder_seq=1500,
    qkv_bias=True, rope_theta=None, norm="layernorm", activation="gelu",
    tie_embeddings=True,
)
