"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L, d_model=1024, attention-free, ssm_state=128, vocab=50280 (GPT-NeoX)."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    citation="arXiv:2405.21060",
    d_model=1024, vocab_size=50280,
    super_block=(SubLayer(mixer="mamba2", ffn="none"),), num_repeats=48,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_chunk=128,
    rope_theta=None, norm="rmsnorm",
    tie_embeddings=True,
)
