"""Architecture registry: ``--arch <id>`` resolution + the paper's GraphSAGE.

Each module defines CONFIG with the exact assigned dimensions and cites its
source in the docstring.  ``get_config(arch, variant)`` applies serving
variants (``swa``: rolling-window serving for full-attention archs — the
explicit opt-in that makes long_500k lowerable for them, DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import replace
from importlib import import_module

from ..models.config import ModelConfig
from .shapes import SHAPES, InputShape, decode_cache_width, input_specs

__all__ = ["ARCH_IDS", "get_config", "SHAPES", "InputShape", "input_specs",
           "decode_cache_width"]

_MODULES = {
    "llama3.2-1b": "llama3_2_1b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-0.5b": "qwen2_0_5b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "mamba2-370m": "mamba2_370m",
    "qwen1.5-110b": "qwen1_5_110b",
    "whisper-small": "whisper_small",
    "paligemma-3b": "paligemma_3b",
    "starcoder2-7b": "starcoder2_7b",
}

ARCH_IDS = tuple(_MODULES)

SWA_SERVE_WINDOW = 8192


def get_config(arch: str, variant: str | None = None) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list(_MODULES)}")
    cfg: ModelConfig = import_module(f"repro.configs.{_MODULES[arch]}").CONFIG
    if variant == "swa" and cfg.sliding_window is None:
        cfg = replace(cfg, sliding_window=SWA_SERVE_WINDOW,
                      name=f"{cfg.name}+swa")
    elif variant not in (None, "", "base"):
        raise ValueError(f"unknown variant {variant!r}")
    return cfg
