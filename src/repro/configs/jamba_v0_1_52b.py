"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887].

32L = 4 super-blocks x 8 sublayers (attention at index 0, mamba at 1..7),
MoE (16e top-2) on every other sublayer; d_model=4096, 32H (GQA kv=8),
d_ff=14336, vocab=65536.  NOTE (DESIGN.md §2): Jamba's Mamba-1 layers are
implemented with the framework's Mamba-2/SSD mixer (state 64) — the
TPU-friendly chunked-dual form."""
from repro.models.config import ModelConfig, SubLayer

_SB = tuple(
    SubLayer(mixer="attention" if i == 0 else "mamba2",
             ffn="moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    citation="arXiv:2403.19887",
    d_model=4096, vocab_size=65536,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
    super_block=_SB, num_repeats=4,
    num_experts=16, top_k=2,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_chunk=128,
    rope_theta=None,  # Jamba uses no positional encoding (Mamba provides it)
    norm="rmsnorm", activation="swiglu",
)
