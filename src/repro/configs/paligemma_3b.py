"""paligemma-3b [vlm] — SigLIP vision stub + gemma decoder [arXiv:2407.07726].

18L, d_model=2048, 8H (MQA kv=1, head_dim=256), d_ff=16384, vocab=257216.
The SigLIP ViT + projector are a stub: input_specs() provides (B, 256, 2048)
patch embeddings; the prefix-LM mask (bidirectional prefix, causal suffix)
is implemented in chunked_attention."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    citation="arXiv:2407.07726",
    d_model=2048, vocab_size=257216,
    num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384,
    super_block=(SubLayer(mixer="attention", ffn="mlp"),), num_repeats=18,
    prefix_tokens=256,
    rope_theta=10_000.0, norm="rmsnorm", activation="swiglu",
    tie_embeddings=True,
)
