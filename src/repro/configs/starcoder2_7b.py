"""starcoder2-7b [dense] — GQA, RoPE, native sliding window 4096
[arXiv:2402.19173].

32L, d_model=4608, 36H (GQA kv=4), d_ff=18432, vocab=49152; LayerNorm +
GeLU MLP, QKV bias, sliding_window=4096 (this is what makes long_500k
native for a dense arch: rolling KV cache of 4096 slots)."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    citation="arXiv:2402.19173",
    d_model=4608, vocab_size=49152,
    num_heads=36, num_kv_heads=4, head_dim=128, d_ff=18432,
    super_block=(SubLayer(mixer="attention", ffn="mlp"),), num_repeats=32,
    qkv_bias=True, sliding_window=4096,
    rope_theta=100_000.0, norm="layernorm", activation="gelu",
)
