"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B].

16L, d_model=2048, 32 heads (GQA kv=8), d_ff=8192, vocab=128256; RoPE
theta=500000, SwiGLU, RMSNorm, tied embeddings (as the 1B card ties)."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    citation="hf:meta-llama/Llama-3.2-1B",
    d_model=2048, vocab_size=128256,
    num_heads=32, num_kv_heads=8, head_dim=64, d_ff=8192,
    super_block=(SubLayer(mixer="attention", ffn="mlp"),), num_repeats=16,
    rope_theta=500_000.0, norm="rmsnorm", activation="swiglu",
    tie_embeddings=True,
)
