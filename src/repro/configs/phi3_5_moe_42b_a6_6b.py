"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32H (GQA kv=8), expert d_ff=6400, vocab=32064."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
    d_model=4096, vocab_size=32064,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=6400,
    super_block=(SubLayer(mixer="attention", ffn="moe"),), num_repeats=32,
    num_experts=16, top_k=2,
    rope_theta=10_000.0, norm="layernorm", activation="swiglu",
)
