"""The four assigned input shapes and ShapeDtypeStruct input builders.

Decode shapes lower ``serve_step`` (ONE token, KV cache of seq_len);
``long_500k`` additionally requires a sub-quadratic path (see DESIGN.md
long_500k policy: native for ssm/hybrid/SWA archs, explicit ``swa``
serving variant for the full-attention archs).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import Transformer

__all__ = ["InputShape", "SHAPES", "input_specs", "decode_cache_width"]


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def decode_cache_width(cfg: ModelConfig, shape: InputShape) -> tuple[int, bool]:
    """(cache width, rolling?) for a decode shape under this config.

    Archs with a sliding window keep a mod-W rolling cache of W slots;
    full-attention archs keep the whole context.
    """
    if cfg.sliding_window is not None and cfg.sliding_window < shape.seq_len:
        return cfg.sliding_window, True
    return shape.seq_len, False


def _token_struct(b: int, s: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    For train/prefill: the batch dict.  For decode: (token, caches,
    cache_len) matching ``Transformer.decode_step``.
    """
    b, s = shape.global_batch, shape.seq_len
    act_dt = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        s_text = s - cfg.prefix_tokens
        batch: dict = {"tokens": _token_struct(b, s_text)}
        if shape.kind == "train":
            batch["labels"] = _token_struct(b, s_text)
        if cfg.prefix_tokens:
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.prefix_tokens, cfg.d_model), act_dt)
        if cfg.is_encoder_decoder:
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), act_dt)
        return batch

    # decode: one token against a cache of seq_len context
    model = Transformer(cfg)
    width, rolling = decode_cache_width(cfg, shape)
    caches = jax.eval_shape(
        lambda: model.make_decode_cache(b, width,
                                        enc_seq=cfg.encoder_seq or None))
    return {
        "token": _token_struct(b, 1),
        "caches": caches,
        "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
        "rolling": rolling,
    }
