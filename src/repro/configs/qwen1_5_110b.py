"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-110B card family].

80L, d_model=8192, 64H (GQA kv=8), d_ff=49152, vocab=152064."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    citation="hf:Qwen/Qwen1.5-110B (assignment cites Qwen1.5 family card)",
    d_model=8192, vocab_size=152064,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=49152,
    super_block=(SubLayer(mixer="attention", ffn="mlp"),), num_repeats=80,
    qkv_bias=True, rope_theta=1_000_000.0, norm="rmsnorm", activation="swiglu",
)
