"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; the launch
configs flip it to False on real TPU hardware.  Every wrapper has the same
signature as its `ref.py` oracle so call sites (and tests) can swap them 1:1.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .flash_attention import flash_attention_pallas
from .rmsnorm import rmsnorm_pallas
from .segment_agg import (EdgeBlocks, build_edge_blocks, build_vjp_blocks,
                          segment_agg_pallas, segment_mean_op)

__all__ = [
    "segment_agg", "make_segment_agg", "segment_mean_op", "build_vjp_blocks",
    "make_mean_blocks", "flash_attention", "rmsnorm",
    "build_edge_blocks", "EdgeBlocks",
]


def make_mean_blocks(indptr: np.ndarray, indices: np.ndarray) -> dict:
    """Host-side: paired forward/transpose block structure for
    :func:`segment_mean_op` from a CSR graph (``num_src_rows == num_rows``)."""
    indptr = np.asarray(indptr)
    n = len(indptr) - 1
    dst = np.repeat(np.arange(n), np.diff(indptr))
    return build_vjp_blocks(np.asarray(indices), dst, num_rows=n,
                            num_src_rows=n)


def make_segment_agg(indptr: np.ndarray, indices: np.ndarray, *, mean: bool = True,
                     interpret: bool = True, use_pallas: bool = True):
    """Bind the static CSR block structure once per graph; returns
    ``agg(x) -> (N, D)`` suitable for jit closure.

    The Pallas path routes through :func:`segment_mean_op`, so the returned
    closure is DIFFERENTIABLE: ``jax.grad`` through it stages the transpose
    aggregation kernel instead of falling back to jnp scatter ops.
    """
    n = len(indptr) - 1
    if not use_pallas:
        src = jnp.asarray(indices)
        dst = jnp.asarray(np.repeat(np.arange(n), np.diff(indptr)))
        return lambda x: ref.segment_agg_ref(x, src, dst, n, mean=mean)

    blocks = {k: jnp.asarray(v)
              for k, v in make_mean_blocks(indptr, indices).items()}

    def agg(x: jnp.ndarray) -> jnp.ndarray:
        return segment_mean_op(x, blocks, num_rows=n, mean=mean,
                               interpret=interpret)

    return agg


def segment_agg(x, indptr, indices, *, mean: bool = True, interpret: bool = True):
    """One-shot convenience (rebuilds block structure; prefer make_segment_agg)."""
    return make_segment_agg(np.asarray(indptr), np.asarray(indices), mean=mean,
                            interpret=interpret)(x)


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "interpret",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    q_offset: int = 0, block_q: int = 128, block_k: int = 256,
                    interpret: bool = True):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, weight, *, eps: float = 1e-6, interpret: bool = True):
    return rmsnorm_pallas(x, weight, eps=eps, interpret=interpret)
