"""Pallas TPU kernel: flash attention with GQA, causal mask and sliding
window — the transformer-side compute hot-spot (prefill_32k, long_500k-swa).

Classic online-softmax tiling [Dao et al.], re-thought for the TPU memory
hierarchy: (BQ × Dh) query tiles and (BK × Dh) key/value tiles live in VMEM,
the (BQ × BK) logits tile is produced on the MXU, and the softmax running
statistics (m, l) plus the (BQ × Dh) accumulator are VMEM scratch carried
across the *sequential* innermost grid dimension (TPU grids execute the last
axis in order — the idiomatic replacement for a CUDA persistent-CTA loop).

Grid: (B, Hq, Sq/BQ, Sk/BK); KV tiles for query head h come from KV head
``h // (Hq // Hkv)`` via the BlockSpec index map (GQA without materialising
repeated KV).  Causal and sliding-window structure short-circuits whole
(q-tile, k-tile) cells with ``pl.when`` — skipped tiles cost no FLOPs, which
is exactly how the kernel turns the 500k-context decode into O(window).

VMEM per cell ≈ (BQ + 2·BK)·Dh·4 + BQ·BK·4 ≈ (128+512)·128·4 + 64 KiB ≈ 0.4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

DEFAULT_BQ = 128
DEFAULT_BK = 256
NEG_INF = -1e30

# jax renamed TPUCompilerParams -> CompilerParams in newer releases; resolve
# whichever this jax ships so the kernel builds across the 0.4.x/0.5.x line.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  q_offset: int, bq: int, bk: int, nk: int, kv_len: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qi = pl.program_id(2)
    q_start = qi * bq + q_offset          # absolute position of this q tile
    k_start = ki * bk

    # tile-level structural skip
    live = True
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, Dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, Dh)
        v = v_ref[0, 0].astype(jnp.float32)          # (BK, Dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (BQ, BK)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < kv_len          # tail-padding of the KV sequence
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                        # (BQ, BK)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = o.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,            # (B, Hq, Sq, Dh)
    k: jnp.ndarray,            # (B, Hkv, Sk, Dh)
    v: jnp.ndarray,            # (B, Hkv, Sk, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, "GQA requires Hq to be a multiple of Hkv"
    group = hq // hkv
    scale = float(1.0 / (dh**0.5))

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sq_pad = ((sq + bq - 1) // bq) * bq
    sk_pad = ((sk + bk - 1) // bk) * bk
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        # tail-padded key positions are excluded by the kv_len mask in-kernel
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
    nq, nk = sq_pad // bq, sk_pad // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, nk=nk, kv_len=sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, qi, ki: (b_, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h, qi, ki: (b_, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
