"""Pallas TPU kernel: fused RMSNorm (bandwidth-bound fusion example).

One pass over the row: mean-of-squares reduction and the scale multiply are
fused so each activation row is read from HBM exactly once, instead of
XLA's unfused reduce + broadcast-mul pair.  Rows tile the grid; the full
feature dim sits in VMEM per tile (d_model ≤ 8192 → ≤ 4 MiB for BR=128 fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_pallas"]

DEFAULT_BR = 256


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * scale * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jnp.ndarray,          # (..., D)
    weight: jnp.ndarray,     # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = DEFAULT_BR,
    interpret: bool = True,
) -> jnp.ndarray:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = int(x.size // d)
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    rows_pad = ((rows + br - 1) // br) * br
    if rows_pad != rows:
        x2 = jnp.pad(x2, ((0, rows_pad - rows), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows_pad // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda r: (r, 0)),
            pl.BlockSpec((1, d), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, d), x.dtype),
        interpret=interpret,
    )(x2, weight.reshape(1, d))
    return out[:rows].reshape(orig_shape)
