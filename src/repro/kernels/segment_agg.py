"""Pallas TPU kernel: blocked CSR segment aggregation (the GNN hot-spot).

GraphSAGE's Eq. 1 mean-aggregation is an SpMM: out[v] = Σ_{u∈N(v)} x[u] / |N(v)|.
A CUDA implementation scatters with atomics; TPUs have no scatter-atomics, so
we ADAPT (DESIGN.md §2): destination nodes are grouped into blocks of ``BN``
consecutive rows whose incoming edges (contiguous in CSR!) are padded to a
common ``BE``; the gather ``msgs = x[src]`` stays in XLA (which lowers it to
efficient dynamic-slices), and the kernel performs the reduction as a
**one-hot × message matmul on the MXU**:

    acc(BN, BD) += onehot(local_dst)(BN, BEC) @ msgs(BEC, BD)

i.e. the irregular segment-sum becomes a dense systolic matmul — the
TPU-native rendering of scatter-add.  Feature dim is tiled to ``BD`` lanes
(multiples of 128); edge chunks ``BEC`` feed the MXU contraction dim.

VMEM per grid cell ≈ BE·BD·4 (msgs) + BN·BD·4 (acc) + O(BE) indices
≈ 1024·256·4 + 128·256·4 ≈ 1.2 MiB « 16 MiB VMEM.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["EdgeBlocks", "build_edge_blocks", "segment_agg_pallas",
           "segment_agg_blocks", "segment_agg_rows", "pallas_call_count",
           "reset_pallas_call_count"]

BN = 128    # destination nodes per block
BD = 256    # feature lanes per block (multiple of 128)
BEC = 128   # edge chunk fed to the MXU contraction per step

# Trace-time observability: bumped every time the Pallas kernel is staged
# into a jaxpr.  Lets callers (and tests) assert the kernel is actually on
# the hot path rather than silently swapped for the jnp reference.
_PALLAS_CALLS = 0


def pallas_call_count() -> int:
    return _PALLAS_CALLS


def reset_pallas_call_count() -> None:
    global _PALLAS_CALLS
    _PALLAS_CALLS = 0


@dataclass(frozen=True)
class EdgeBlocks:
    """Static, padded block structure for one CSR graph (host preprocessing)."""

    num_nodes: int
    num_blocks: int
    edges_per_block: int       # BE (multiple of BEC)
    src: np.ndarray            # (num_blocks, BE) int32, pad -> 0 (masked)
    local_dst: np.ndarray      # (num_blocks, BE) int32 in [0, BN), pad -> 0
    mask: np.ndarray           # (num_blocks, BE) float32
    deg: np.ndarray            # (num_blocks, BN) float32 (>=1 where real)


def build_edge_blocks(indptr: np.ndarray, indices: np.ndarray, bn: int = BN,
                      bec: int = BEC) -> EdgeBlocks:
    n = len(indptr) - 1
    nblocks = (n + bn - 1) // bn
    counts = [int(indptr[min((b + 1) * bn, n)] - indptr[b * bn]) for b in range(nblocks)]
    be = max(bec, ((max(counts) + bec - 1) // bec) * bec) if counts else bec

    src = np.zeros((nblocks, be), dtype=np.int32)
    ldst = np.zeros((nblocks, be), dtype=np.int32)
    mask = np.zeros((nblocks, be), dtype=np.float32)
    deg = np.ones((nblocks, bn), dtype=np.float32)
    for b in range(nblocks):
        lo_node, hi_node = b * bn, min((b + 1) * bn, n)
        lo, hi = int(indptr[lo_node]), int(indptr[hi_node])
        k = hi - lo
        src[b, :k] = indices[lo:hi]
        dst_global = np.repeat(
            np.arange(lo_node, hi_node),
            np.diff(indptr[lo_node : hi_node + 1]),
        )
        ldst[b, :k] = dst_global - lo_node
        mask[b, :k] = 1.0
        d = np.diff(indptr[lo_node : hi_node + 1]).astype(np.float32)
        deg[b, : hi_node - lo_node] = np.maximum(d, 1.0)
    return EdgeBlocks(
        num_nodes=n, num_blocks=nblocks, edges_per_block=be,
        src=src, local_dst=ldst, mask=mask, deg=deg,
    )


def _segment_agg_kernel(msgs_ref, ldst_ref, mask_ref, deg_ref, out_ref, *, be: int,
                        bn: int, mean: bool):
    """One (node-block, feature-block) grid cell."""
    acc = jnp.zeros((bn, msgs_ref.shape[-1]), dtype=jnp.float32)
    ldst = ldst_ref[0]          # (BE,)
    mask = mask_ref[0]          # (BE,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bn, BEC), 0)

    def chunk(e, acc):
        sl = pl.dslice(e * BEC, BEC)
        m = msgs_ref[sl, :].astype(jnp.float32)              # (BEC, BD)
        d = jax.lax.dynamic_slice(ldst, (e * BEC,), (BEC,))  # (BEC,)
        w = jax.lax.dynamic_slice(mask, (e * BEC,), (BEC,))
        onehot = jnp.where(rows == d[None, :], w[None, :], 0.0)  # (BN, BEC)
        return acc + jax.lax.dot_general(
            onehot, m, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    acc = jax.lax.fori_loop(0, be // BEC, chunk, acc)
    if mean:
        acc = acc / deg_ref[0][:, None]
    out_ref[...] = acc.astype(out_ref.dtype)


def segment_agg_blocks(
    msgs: jnp.ndarray,        # (num_blocks * BE, D) gathered edge messages
    local_dst: jnp.ndarray,   # (num_blocks, BE) int32 in [0, BN)
    mask: jnp.ndarray,        # (num_blocks, BE) float32
    deg: jnp.ndarray,         # (num_blocks, BN) float32 (>=1 where real)
    *,
    mean: bool = True,
    bd: int = BD,
    interpret: bool = True,
) -> jnp.ndarray:
    """Array-based kernel entry: the block structure arrives as (possibly
    traced) arrays, so the call nests cleanly under ``vmap`` / ``shard_map``
    where each program instance owns a different partition's blocks.  Only
    the SHAPES must agree across instances (the SPMD engine pads them to a
    common (nb, BE)).  Returns (num_blocks * BN, D); caller unpads rows.

    ``interpret=True`` runs the kernel body in Python on CPU (this container);
    on a real TPU pass ``interpret=False``.
    """
    global _PALLAS_CALLS
    _PALLAS_CALLS += 1
    nb, be = local_dst.shape
    bn = deg.shape[-1]
    d = msgs.shape[-1]
    d_pad = ((d + bd - 1) // bd) * bd
    if d_pad != d:
        msgs = jnp.pad(msgs, ((0, 0), (0, d_pad - d)))

    out = pl.pallas_call(
        functools.partial(_segment_agg_kernel, be=be, bn=bn, mean=mean),
        grid=(nb, d_pad // bd),
        in_specs=[
            pl.BlockSpec((be, bd), lambda b, f: (b, f)),       # msgs
            pl.BlockSpec((1, be), lambda b, f: (b, 0)),        # local dst
            pl.BlockSpec((1, be), lambda b, f: (b, 0)),        # mask
            pl.BlockSpec((1, bn), lambda b, f: (b, 0)),        # deg
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda b, f: (b, f)),
        out_shape=jax.ShapeDtypeStruct((nb * bn, d_pad), msgs.dtype),
        interpret=interpret,
    )(
        msgs.reshape(nb * be, d_pad),
        jnp.asarray(local_dst),
        jnp.asarray(mask),
        jnp.asarray(deg),
    )
    return out[:, :d]


def segment_agg_rows(
    msgs: jnp.ndarray,        # (num_blocks * BE, D) gathered edge messages
    local_dst: jnp.ndarray,   # (num_blocks, BE) int32 in [0, BN)
    mask: jnp.ndarray,        # (num_blocks, BE) float32
    deg: jnp.ndarray,         # (num_blocks, BN) float32 (>=1 where real)
    *,
    row_base,                 # int or traced scalar: first output row
    num_rows: int,            # static total output rows
    mean: bool = True,
    bd: int = BD,
    interpret: bool = True,
) -> jnp.ndarray:
    """Row-range (masked) kernel entry: aggregate a REBASED sub-range of the
    node space and place it at ``row_base`` inside a zero ``(num_rows, D)``
    output.

    The block structure covers only the sub-range's rows (e.g. the boundary
    rows ``[n_int, n_own)`` of a partition, rebased to start at 0), so the
    kernel pays for ``ceil(range / BN)`` node blocks instead of the whole
    local space; ``row_base`` may be a traced scalar, which is what lets the
    per-partition boundary offset vary under ``vmap``/``shard_map``.  Rows
    outside ``[row_base, row_base + num_blocks * BN)`` are exactly zero; an
    empty range (all-pad blocks, the zero-boundary partition) yields an
    all-zero output.
    """
    out = segment_agg_blocks(msgs, local_dst, mask, deg, mean=mean, bd=bd,
                             interpret=interpret)
    # place at the (possibly traced) row offset; the target is padded by the
    # block rows so dynamic_update_slice never clamps for row_base <= num_rows
    target = jnp.zeros((num_rows + out.shape[0], out.shape[1]), out.dtype)
    target = jax.lax.dynamic_update_slice(
        target, out, (jnp.asarray(row_base, jnp.int32), jnp.int32(0)))
    return target[:num_rows]


def segment_agg_pallas(
    msgs: jnp.ndarray,        # (num_blocks * BE, D) gathered edge messages
    blocks: EdgeBlocks,
    *,
    mean: bool = True,
    bd: int = BD,
    interpret: bool = True,
) -> jnp.ndarray:
    """Blocked segment sum/mean over a host-built :class:`EdgeBlocks`."""
    return segment_agg_blocks(
        msgs, jnp.asarray(blocks.local_dst), jnp.asarray(blocks.mask),
        jnp.asarray(blocks.deg), mean=mean, bd=bd, interpret=interpret,
    )
