"""Pallas TPU kernel: blocked CSR segment aggregation (the GNN hot-spot).

GraphSAGE's Eq. 1 mean-aggregation is an SpMM: out[v] = Σ_{u∈N(v)} x[u] / |N(v)|.
A CUDA implementation scatters with atomics; TPUs have no scatter-atomics, so
we ADAPT (DESIGN.md §2): destination nodes are grouped into blocks of ``BN``
consecutive rows whose incoming edges (contiguous in CSR!) are padded to a
common ``BE``; the gather ``msgs = x[src]`` stays in XLA (which lowers it to
efficient dynamic-slices), and the kernel performs the reduction as a
**one-hot × message matmul on the MXU**:

    acc(BN, BD) += onehot(local_dst)(BN, BEC) @ msgs(BEC, BD)

i.e. the irregular segment-sum becomes a dense systolic matmul — the
TPU-native rendering of scatter-add.  Feature dim is tiled to ``BD`` lanes
(multiples of 128); edge chunks ``BEC`` feed the MXU contraction dim.

VMEM per grid cell ≈ BE·BD·4 (msgs) + BN·BD·4 (acc) + O(BE) indices
≈ 1024·256·4 + 128·256·4 ≈ 1.2 MiB « 16 MiB VMEM.

The op is DIFFERENTIABLE end-to-end: :func:`segment_mean_op` wraps the
forward in a ``jax.custom_vjp`` whose backward is the transpose aggregation
(grad flows dst → src over the same edges) through the same one-hot × matmul
kernel on a CSC-ordered :class:`EdgeBlocks` mirror (DESIGN.md §6), so
full-graph training keeps both directions of the pass on the MXU.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["EdgeBlocks", "build_edge_blocks", "build_edge_blocks_from_edges",
           "build_transpose_blocks", "build_vjp_blocks", "segment_agg_pallas",
           "segment_agg_blocks", "segment_agg_rows", "segment_agg_bwd_blocks",
           "segment_mean_op", "pallas_call_count", "reset_pallas_call_count"]

BN = 128    # destination nodes per block
BD = 256    # feature lanes per block (multiple of 128)
BEC = 128   # edge chunk fed to the MXU contraction per step

# Trace-time observability: bumped every time the Pallas kernel is staged
# into a jaxpr.  Lets callers (and tests) assert the kernel is actually on
# the hot path rather than silently swapped for the jnp reference.
_PALLAS_CALLS = 0


def pallas_call_count() -> int:
    return _PALLAS_CALLS


def reset_pallas_call_count() -> None:
    global _PALLAS_CALLS
    _PALLAS_CALLS = 0


@dataclass(frozen=True)
class EdgeBlocks:
    """Static, padded block structure for one CSR graph (host preprocessing)."""

    num_nodes: int
    num_blocks: int
    edges_per_block: int       # BE (multiple of BEC)
    src: np.ndarray            # (num_blocks, BE) int32, pad -> 0 (masked)
    local_dst: np.ndarray      # (num_blocks, BE) int32 in [0, BN), pad -> 0
    mask: np.ndarray           # (num_blocks, BE) float32
    deg: np.ndarray            # (num_blocks, BN) float32 (>=1 where real)


def build_edge_blocks(indptr: np.ndarray, indices: np.ndarray, bn: int = BN,
                      bec: int = BEC) -> EdgeBlocks:
    n = len(indptr) - 1
    nblocks = (n + bn - 1) // bn
    counts = [int(indptr[min((b + 1) * bn, n)] - indptr[b * bn]) for b in range(nblocks)]
    be = max(bec, ((max(counts) + bec - 1) // bec) * bec) if counts else bec

    src = np.zeros((nblocks, be), dtype=np.int32)
    ldst = np.zeros((nblocks, be), dtype=np.int32)
    mask = np.zeros((nblocks, be), dtype=np.float32)
    deg = np.ones((nblocks, bn), dtype=np.float32)
    for b in range(nblocks):
        lo_node, hi_node = b * bn, min((b + 1) * bn, n)
        lo, hi = int(indptr[lo_node]), int(indptr[hi_node])
        k = hi - lo
        src[b, :k] = indices[lo:hi]
        dst_global = np.repeat(
            np.arange(lo_node, hi_node),
            np.diff(indptr[lo_node : hi_node + 1]),
        )
        ldst[b, :k] = dst_global - lo_node
        mask[b, :k] = 1.0
        d = np.diff(indptr[lo_node : hi_node + 1]).astype(np.float32)
        deg[b, : hi_node - lo_node] = np.maximum(d, 1.0)
    return EdgeBlocks(
        num_nodes=n, num_blocks=nblocks, edges_per_block=be,
        src=src, local_dst=ldst, mask=mask, deg=deg,
    )


def build_edge_blocks_from_edges(src: np.ndarray, dst: np.ndarray,
                                 num_rows: int, bn: int = BN,
                                 bec: int = BEC) -> EdgeBlocks:
    """:func:`build_edge_blocks` over an explicit edge list (``dst`` need not
    be sorted; a stable dst-sort reproduces the CSR per-row edge order)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    order = np.argsort(dst, kind="stable")
    counts = np.bincount(dst, minlength=num_rows)[:num_rows]
    indptr = np.zeros(num_rows + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return build_edge_blocks(indptr, src[order], bn=bn, bec=bec)


def build_transpose_blocks(src: np.ndarray, dst: np.ndarray,
                           num_src_rows: int, bn: int = BN,
                           bec: int = BEC) -> EdgeBlocks:
    """CSC-ordered mirror of a CSR block structure: blocks for the TRANSPOSE
    aggregation over the same edges (grad flows dst -> src), i.e. edges
    re-grouped by SOURCE with the original destinations as the gather index.
    This is the static structure of the backward kernel of
    :func:`segment_mean_op`."""
    return build_edge_blocks_from_edges(dst, src, num_src_rows, bn=bn, bec=bec)


def _pad_min_one_block(blocks: EdgeBlocks, bn: int) -> EdgeBlocks:
    """Guarantee >= 1 (all-pad) block so empty edge sets still stage a valid
    kernel grid — the same guard engine.stacking applies when stacking."""
    if blocks.num_blocks:
        return blocks
    be = blocks.edges_per_block
    return EdgeBlocks(
        num_nodes=blocks.num_nodes, num_blocks=1, edges_per_block=be,
        src=np.zeros((1, be), np.int32), local_dst=np.zeros((1, be), np.int32),
        mask=np.zeros((1, be), np.float32), deg=np.ones((1, bn), np.float32))


def build_vjp_blocks(src: np.ndarray, dst: np.ndarray, num_rows: int,
                     num_src_rows: int, bn: int = BN,
                     bec: int = BEC) -> dict[str, np.ndarray]:
    """Paired forward (dst-blocked CSR) + backward (src-blocked CSC mirror)
    structures for :func:`segment_mean_op`, as a flat dict of arrays (a
    pytree: stacks along a leading partition axis and nests cleanly under
    ``vmap`` / ``shard_map``).

    ``num_rows`` is the aggregation's output row range (destinations live in
    ``[0, num_rows)``); ``num_src_rows`` is the gathered-from row space the
    gradient must cover (sources live in ``[0, num_src_rows)``).
    """
    fwd = _pad_min_one_block(
        build_edge_blocks_from_edges(src, dst, num_rows, bn=bn, bec=bec), bn)
    bwd = _pad_min_one_block(
        build_transpose_blocks(src, dst, num_src_rows, bn=bn, bec=bec), bn)
    return {"src": fwd.src, "dst": fwd.local_dst, "mask": fwd.mask,
            "deg": fwd.deg, "t_src": bwd.src, "t_dst": bwd.local_dst,
            "t_mask": bwd.mask}


def _segment_agg_kernel(msgs_ref, ldst_ref, mask_ref, deg_ref, out_ref, *, be: int,
                        bn: int, mean: bool):
    """One (node-block, feature-block) grid cell."""
    # accumulate in the input precision for float64 (interpret-mode oracles
    # and the fp64 grad checks need exact arithmetic), float32 otherwise
    acc_dt = jnp.float64 if msgs_ref.dtype == jnp.float64 else jnp.float32
    acc = jnp.zeros((bn, msgs_ref.shape[-1]), dtype=acc_dt)
    ldst = ldst_ref[0]          # (BE,)
    mask = mask_ref[0]          # (BE,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bn, BEC), 0)

    def chunk(e, acc):
        sl = pl.dslice(e * BEC, BEC)
        m = msgs_ref[sl, :].astype(acc_dt)                   # (BEC, BD)
        d = jax.lax.dynamic_slice(ldst, (e * BEC,), (BEC,))  # (BEC,)
        w = jax.lax.dynamic_slice(mask, (e * BEC,), (BEC,)).astype(acc_dt)
        onehot = jnp.where(rows == d[None, :], w[None, :],
                           jnp.zeros((), acc_dt))            # (BN, BEC)
        return acc + jax.lax.dot_general(
            onehot, m, (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dt,
        )

    acc = jax.lax.fori_loop(0, be // BEC, chunk, acc)
    if mean:
        acc = acc / deg_ref[0][:, None].astype(acc_dt)
    out_ref[...] = acc.astype(out_ref.dtype)


def segment_agg_blocks(
    msgs: jnp.ndarray,        # (num_blocks * BE, D) gathered edge messages
    local_dst: jnp.ndarray,   # (num_blocks, BE) int32 in [0, BN)
    mask: jnp.ndarray,        # (num_blocks, BE) float32
    deg: jnp.ndarray,         # (num_blocks, BN) float32 (>=1 where real)
    *,
    mean: bool = True,
    bd: int = BD,
    interpret: bool = True,
) -> jnp.ndarray:
    """Array-based kernel entry: the block structure arrives as (possibly
    traced) arrays, so the call nests cleanly under ``vmap`` / ``shard_map``
    where each program instance owns a different partition's blocks.  Only
    the SHAPES must agree across instances (the SPMD engine pads them to a
    common (nb, BE)).  Returns (num_blocks * BN, D); caller unpads rows.

    ``interpret=True`` runs the kernel body in Python on CPU (this container);
    on a real TPU pass ``interpret=False``.
    """
    global _PALLAS_CALLS
    _PALLAS_CALLS += 1
    nb, be = local_dst.shape
    bn = deg.shape[-1]
    d = msgs.shape[-1]
    d_pad = ((d + bd - 1) // bd) * bd
    if d_pad != d:
        msgs = jnp.pad(msgs, ((0, 0), (0, d_pad - d)))

    out = pl.pallas_call(
        functools.partial(_segment_agg_kernel, be=be, bn=bn, mean=mean),
        grid=(nb, d_pad // bd),
        in_specs=[
            pl.BlockSpec((be, bd), lambda b, f: (b, f)),       # msgs
            pl.BlockSpec((1, be), lambda b, f: (b, 0)),        # local dst
            pl.BlockSpec((1, be), lambda b, f: (b, 0)),        # mask
            pl.BlockSpec((1, bn), lambda b, f: (b, 0)),        # deg
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda b, f: (b, f)),
        out_shape=jax.ShapeDtypeStruct((nb * bn, d_pad), msgs.dtype),
        interpret=interpret,
    )(
        msgs.reshape(nb * be, d_pad),
        jnp.asarray(local_dst),
        jnp.asarray(mask),
        jnp.asarray(deg),
    )
    return out[:, :d]


def segment_agg_rows(
    msgs: jnp.ndarray,        # (num_blocks * BE, D) gathered edge messages
    local_dst: jnp.ndarray,   # (num_blocks, BE) int32 in [0, BN)
    mask: jnp.ndarray,        # (num_blocks, BE) float32
    deg: jnp.ndarray,         # (num_blocks, BN) float32 (>=1 where real)
    *,
    row_base,                 # int or traced scalar: first output row
    num_rows: int,            # static total output rows
    mean: bool = True,
    bd: int = BD,
    interpret: bool = True,
) -> jnp.ndarray:
    """Row-range (masked) kernel entry: aggregate a REBASED sub-range of the
    node space and place it at ``row_base`` inside a zero ``(num_rows, D)``
    output.

    The block structure covers only the sub-range's rows (e.g. the boundary
    rows ``[n_int, n_own)`` of a partition, rebased to start at 0), so the
    kernel pays for ``ceil(range / BN)`` node blocks instead of the whole
    local space; ``row_base`` may be a traced scalar, which is what lets the
    per-partition boundary offset vary under ``vmap``/``shard_map``.  Rows
    outside ``[row_base, row_base + num_blocks * BN)`` are exactly zero; an
    empty range (all-pad blocks, the zero-boundary partition) yields an
    all-zero output.
    """
    out = segment_agg_blocks(msgs, local_dst, mask, deg, mean=mean, bd=bd,
                             interpret=interpret)
    # place at the (possibly traced) row offset; the target is padded by the
    # block rows so dynamic_update_slice never clamps for row_base <= num_rows
    target = jnp.zeros((num_rows + out.shape[0], out.shape[1]), out.dtype)
    target = jax.lax.dynamic_update_slice(
        target, out, (jnp.asarray(row_base, jnp.int32), jnp.int32(0)))
    return target[:num_rows]


def segment_agg_pallas(
    msgs: jnp.ndarray,        # (num_blocks * BE, D) gathered edge messages
    blocks: EdgeBlocks,
    *,
    mean: bool = True,
    bd: int = BD,
    interpret: bool = True,
) -> jnp.ndarray:
    """Blocked segment sum/mean over a host-built :class:`EdgeBlocks`."""
    return segment_agg_blocks(
        msgs, jnp.asarray(blocks.local_dst), jnp.asarray(blocks.mask),
        jnp.asarray(blocks.deg), mean=mean, bd=bd, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# differentiable unified op: forward (CSR-blocked) + backward (CSC-blocked)
# ---------------------------------------------------------------------------
#
# out[r] = (1/deg[r]) * sum_{edges (u, r)} x[u]   (placed at row_base in a
# zero (num_rows, D) output).  The VJP is ITSELF a segment aggregation over
# the same edges with source and destination swapped:
#
#     dL/dx[u] = sum_{edges (u, r)} g[r] / deg[r]
#
# so the backward reuses the one-hot x matmul kernel on the CSC-ordered
# transpose structure (build_transpose_blocks) — both directions of the pass
# stay on the MXU, no scatter-add anywhere.

@dataclass(frozen=True)
class _MeanOpMeta:
    """Static (hashable) config of one segment_mean_op call site."""

    num_rows: int    # output rows
    n_in: int        # rows of x the gradient must cover
    mean: bool
    interpret: bool
    bd: int


def _segment_mean_fwd_impl(meta: _MeanOpMeta, x, src, dst, mask, deg, row_base):
    msgs = x[src.reshape(-1)]                   # XLA gather, per-block layout
    out = segment_agg_blocks(msgs, dst, mask, deg, mean=meta.mean, bd=meta.bd,
                             interpret=meta.interpret)
    # place at the (possibly traced) row offset; the target is padded by the
    # block rows so dynamic_update_slice never clamps for row_base <= num_rows
    target = jnp.zeros((meta.num_rows + out.shape[0], out.shape[1]), out.dtype)
    target = jax.lax.dynamic_update_slice(
        target, out, (jnp.asarray(row_base, jnp.int32), jnp.int32(0)))
    return target[:meta.num_rows]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _segment_mean_core(meta, x, src, dst, mask, deg, t_src, t_dst, t_mask,
                       row_base):
    return _segment_mean_fwd_impl(meta, x, src, dst, mask, deg, row_base)


def segment_agg_bwd_blocks(
    g: jnp.ndarray,           # (num_rows, D) cotangent of the op's output
    blocks: dict,             # the SAME build_vjp_blocks arrays as the fwd
    *,
    n_in: int,                # rows of the x space to produce
    mean: bool = True,
    row_base=0,               # int or traced scalar (matches the forward)
    bd: int = BD,
    interpret: bool = True,
) -> jnp.ndarray:
    """Source-blocked BACKWARD kernel entry: scale the output cotangent by
    the forward 1/deg (mean) and aggregate it dst -> src through the same
    one-hot × matmul kernel over the CSC-ordered transpose blocks.  Returns
    ``(n_in, D) = dL/dx``.

    Implemented as the core op with forward and transpose structures
    SWAPPED (the transpose of the transpose is the forward), so the
    backward pass is itself differentiable — second-order ``check_grads``
    recurses through the same custom VJP instead of hitting the raw
    ``pallas_call``.
    """
    deg = blocks["deg"]
    d_feat = g.shape[-1]
    range_cap = deg.shape[0] * deg.shape[1]     # rows the fwd kernel produced
    # un-place: rows [row_base, row_base + range_cap) of the padded cotangent
    # are the fwd kernel's output rows (rows sliced off by the forward's
    # [:num_rows] read zero cotangent here, exactly mirroring the placement)
    gpad = jnp.concatenate(
        [g, jnp.zeros((range_cap, d_feat), g.dtype)], axis=0)
    gsub = jax.lax.dynamic_slice(
        gpad, (jnp.asarray(row_base, jnp.int32), jnp.int32(0)),
        (range_cap, d_feat))
    if mean:
        gsub = gsub / deg.reshape(-1)[:, None].astype(gsub.dtype)
    meta_t = _MeanOpMeta(num_rows=n_in, n_in=range_cap, mean=False,
                         interpret=interpret, bd=bd)
    t_deg = jnp.ones((blocks["t_dst"].shape[0], deg.shape[-1]), jnp.float32)
    return _segment_mean_core(
        meta_t, gsub, blocks["t_src"], blocks["t_dst"], blocks["t_mask"],
        t_deg, blocks["src"], blocks["dst"], blocks["mask"],
        jnp.int32(0))


def _segment_mean_fwd(meta, x, src, dst, mask, deg, t_src, t_dst, t_mask,
                      row_base):
    # re-enter the custom-vjp op (not the raw impl): higher-order AD
    # differentiates the fwd/bwd RULES, so both must resolve to the custom
    # VJP again instead of exposing the raw pallas_call to jvp/transpose
    out = _segment_mean_core(meta, x, src, dst, mask, deg, t_src, t_dst,
                             t_mask, row_base)
    return out, (src, dst, mask, deg, t_src, t_dst, t_mask, row_base)


def _segment_mean_bwd(meta, res, g):
    src, dst, mask, deg, t_src, t_dst, t_mask, row_base = res
    blocks = {"src": src, "dst": dst, "mask": mask, "deg": deg,
              "t_src": t_src, "t_dst": t_dst, "t_mask": t_mask}
    gx = segment_agg_bwd_blocks(g, blocks, n_in=meta.n_in, mean=meta.mean,
                                row_base=row_base, bd=meta.bd,
                                interpret=meta.interpret)
    # block structure and row offset are static graph data: zero cotangents
    return (gx, None, None, None, None, None, None, None, None)


_segment_mean_core.defvjp(_segment_mean_fwd, _segment_mean_bwd)


def segment_mean_op(
    x: jnp.ndarray,                 # (n_in, D) node features / embeddings
    blocks: dict,                   # build_vjp_blocks arrays (traced ok)
    *,
    num_rows: int,                  # static output rows
    row_base=0,                     # int or traced scalar: first output row
    mean: bool = True,
    interpret: bool = True,
    bd: int = BD,
) -> jnp.ndarray:
    """THE differentiable blocked aggregation op (every forward's Eq. 1).

    Forward: gather ``x`` by the CSR block structure and reduce on the MXU
    (:func:`segment_agg_blocks`), placing the aggregated sub-range at
    ``row_base`` inside a zero ``(num_rows, D)`` output — ``row_base=0`` with
    ``num_rows = n`` is the plain full-space aggregation, a nonzero traced
    ``row_base`` is the overlapped forward's boundary half.  Backward: a
    ``jax.custom_vjp`` that runs the transpose aggregation through the same
    kernel over the CSC-ordered mirror (:func:`segment_agg_bwd_blocks`), so
    ``jax.grad`` stages a SECOND Pallas call instead of falling back to jnp
    scatter ops.  ``blocks`` may be (possibly traced, e.g. per-partition
    stacked) arrays from :func:`build_vjp_blocks`; only shapes must be
    static.
    """
    meta = _MeanOpMeta(num_rows=int(num_rows), n_in=int(x.shape[0]),
                       mean=bool(mean), interpret=bool(interpret), bd=int(bd))
    return _segment_mean_core(
        meta, x, blocks["src"], blocks["dst"], blocks["mask"], blocks["deg"],
        blocks["t_src"], blocks["t_dst"], blocks["t_mask"],
        jnp.asarray(row_base, jnp.int32))
