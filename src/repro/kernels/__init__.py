# Pallas TPU kernels for the compute hot-spots (validated with interpret=True
# on CPU; target is TPU v5e).  ops.py = jit wrappers, ref.py = jnp oracles.
from . import ops, ref

__all__ = ["ops", "ref"]
