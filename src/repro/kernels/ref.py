"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["segment_agg_ref", "segment_agg_rows_ref", "attention_ref",
           "rmsnorm_ref"]


def segment_agg_ref(
    x: jnp.ndarray,           # (N, D) node features
    edge_src: jnp.ndarray,    # (E,)
    edge_dst: jnp.ndarray,    # (E,)
    num_nodes: int,
    mean: bool = True,
) -> jnp.ndarray:
    """out[v] = sum/mean of x[u] over in-edges (u, v).

    The canonical jnp segment-mean (imported by ``ops.make_segment_agg``'s
    fallback and ``graph.sage.apply_full``'s jnp path).  The mean divides in
    the input precision for float64 — casting through float32 would make the
    fp64 oracle lossier than the kernel it checks.
    """
    s = jax.ops.segment_sum(x[edge_src], edge_dst, num_segments=num_nodes)
    if not mean:
        return s.astype(x.dtype)
    acc_dt = jnp.float64 if x.dtype == jnp.float64 else jnp.float32
    deg = jax.ops.segment_sum(
        jnp.ones_like(edge_dst, dtype=acc_dt), edge_dst, num_segments=num_nodes
    )
    return (s.astype(acc_dt) / jnp.maximum(deg, 1.0)[:, None]).astype(x.dtype)


def segment_agg_rows_ref(
    x: jnp.ndarray,           # (N, D) node features
    edge_src: jnp.ndarray,    # (E,) indices into x
    edge_dst: jnp.ndarray,    # (E,) REBASED destinations in [0, range_rows)
    range_rows: int,          # rows covered by the sub-range
    row_base: int,            # first output row of the sub-range
    num_rows: int,            # total output rows
    mean: bool = True,
) -> jnp.ndarray:
    """Oracle for the row-range kernel entry ``segment_agg_rows``: aggregate
    a rebased destination sub-range and place it at ``row_base`` inside a
    zero ``(num_rows, D)`` output."""
    sub = segment_agg_ref(x, edge_src, edge_dst, range_rows, mean=mean)
    out = jnp.zeros((num_rows, x.shape[-1]), x.dtype)
    return jax.lax.dynamic_update_slice(
        out, sub[: max(0, min(range_rows, num_rows - row_base))],
        (row_base, 0))


def attention_ref(
    q: jnp.ndarray,           # (B, Hq, Sq, Dh)
    k: jnp.ndarray,           # (B, Hkv, Sk, Dh)
    v: jnp.ndarray,           # (B, Hkv, Sk, Dh)
    *,
    causal: bool = True,
    window: int | None = None,   # sliding window over keys (None = full)
    q_offset: int = 0,           # absolute position of q[0] (decode: cache len)
) -> jnp.ndarray:
    """Dense-softmax GQA attention oracle (fp32 softmax)."""
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows -> 0
    return jnp.einsum("bhqk,bhkd->bhqd", w, vx.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * weight.astype(jnp.float32)).astype(x.dtype)
