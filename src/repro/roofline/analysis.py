"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory term     = HLO_bytes   / (chips × HBM_bw)
    collective term = coll_bytes  / (chips × link_bw)

``cost_analysis`` supplies FLOPs and bytes-accessed; collective bytes are
parsed from the (post-SPMD-partitioning) HLO text by summing the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  HLO text is per-PARTITION (shapes are already local),
so the parsed bytes are per-chip — matching the per-chip roofline
denominators directly.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

__all__ = ["HW", "collective_bytes_from_hlo", "analyze_compiled",
           "RooflineReport", "model_flops"]


@dataclass(frozen=True)
class HW:
    """TPU v5e-class chip constants (per the assignment)."""

    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\)|\S+))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)\(",
    re.MULTILINE,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum OUTPUT shape bytes per collective kind (post-partitioning HLO:
    shapes are per-device)."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def model_flops(n_active_params: float, tokens: float) -> float:
    """The 6·N·D estimate (N = active params, D = tokens)."""
    return 6.0 * n_active_params * tokens


@dataclass
class RooflineReport:
    name: str
    chips: int
    hlo_flops: float                 # per-chip FLOPs (cost_analysis is per-device)
    hlo_bytes: float                 # per-chip bytes accessed
    coll_bytes: dict[str, int] = field(default_factory=dict)
    model_flops_total: float = 0.0   # 6·N·D over the GLOBAL batch
    peak_memory_per_chip: float = 0.0
    hw: HW = field(default_factory=HW)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): fraction of compiled compute
        that is 'useful' model compute (catches remat/dispatch waste)."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    def row(self) -> dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "coll_bytes_per_chip": sum(self.coll_bytes.values()),
            "coll_breakdown": self.coll_bytes,
            "model_flops": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_memory_per_chip_gb": self.peak_memory_per_chip / 1e9,
        }


def analyze_compiled(name: str, lowered, compiled, *, chips: int,
                     n_active_params: float, tokens: float,
                     hw: HW = HW()) -> RooflineReport:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        hlo_text = compiled.as_text()
    except Exception:
        hlo_text = lowered.as_text()
    coll = collective_bytes_from_hlo(hlo_text)
    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        peak = (getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0))
    return RooflineReport(
        name=name, chips=chips, hlo_flops=flops, hlo_bytes=byts,
        coll_bytes=coll, model_flops_total=model_flops(n_active_params, tokens),
        peak_memory_per_chip=peak, hw=hw,
    )
