"""Epoch-granular run checkpointing with a checksummed manifest.

:class:`RunCheckpointer` persists the FULL pipeline state at epoch
boundaries so a killed run resumes bit-for-bit (DESIGN.md §10):

  · each step is one atomic ``save_pytree`` archive (device/host arrays:
    params, opt states, the stacked halo cache, ...) plus a JSON host-state
    blob (controller, RNG generator states, histories) carried in the same
    sidecar the per-entry CRCs live in;
  · a ``manifest.json`` — written LAST, atomically — lists the retained
    steps with whole-file CRCs, so a crash mid-save never publishes a
    half-written checkpoint and the newest VALID step is discoverable;
  · only the last K steps are retained (older archives pruned after the
    manifest stops referencing them);
  · ``load_latest`` walks the manifest newest→oldest, skipping any step
    whose archive fails its integrity checks — one corrupted file costs
    one epoch of progress, not the run.

The arrays template depends on host state (a phase-1 checkpoint carries
personal params a phase-0 one doesn't), so ``load_latest`` takes a
``make_like(host_state) -> template`` callable.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Callable

from ..train.checkpoint import (CheckpointCorruptError, load_meta,
                                load_pytree, save_pytree)

__all__ = ["RunCheckpointer"]

_MANIFEST = "manifest.json"


def _file_crc(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                return crc
            crc = zlib.crc32(chunk, crc)


class RunCheckpointer:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = max(1, int(keep_last))
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ plumbing
    def _name(self, step: int) -> str:
        return f"ckpt_{step:06d}"

    def _npz(self, step: int) -> str:
        return os.path.join(self.dir, self._name(step) + ".npz")

    def _manifest_path(self) -> str:
        return os.path.join(self.dir, _MANIFEST)

    def _read_manifest(self) -> dict:
        path = self._manifest_path()
        if not os.path.exists(path):
            return {"steps": [], "entries": {}}
        try:
            with open(path) as f:
                return json.load(f)
        except (json.JSONDecodeError, OSError):
            # a torn manifest write loses the INDEX, not the archives:
            # rebuild from whatever complete checkpoints are on disk
            steps = sorted(
                int(n[5:11]) for n in os.listdir(self.dir)
                if n.startswith("ckpt_") and n.endswith(".npz"))
            return {"steps": steps, "entries": {}}

    def _write_manifest(self, man: dict) -> None:
        tmp = self._manifest_path() + f".tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(man, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._manifest_path())

    # -------------------------------------------------------------- public
    def steps(self) -> list[int]:
        """Retained steps, oldest first."""
        return sorted(int(s) for s in self._read_manifest()["steps"])

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, arrays: Any, host_state: dict) -> str:
        """Persist one epoch boundary; prunes beyond ``keep_last``.  The
        manifest is updated only after the archive is fully on disk."""
        step = int(step)
        path = self._npz(step)
        save_pytree(path, arrays, meta={"step": step, "host": host_state})
        man = self._read_manifest()
        steps = sorted(set(int(s) for s in man["steps"]) | {step})
        drop, steps = steps[:-self.keep_last], steps[-self.keep_last:]
        entries = {k: v for k, v in man.get("entries", {}).items()
                   if int(k) in steps}
        entries[str(step)] = {"file": os.path.basename(path),
                              "crc32": _file_crc(path)}
        self._write_manifest({"steps": steps, "entries": entries})
        for s in drop:
            for stale in (self._npz(s), self._npz(s) + ".meta.json"):
                if os.path.exists(stale):
                    os.remove(stale)
        return path

    def peek(self, step: int) -> dict:
        """Host-state blob of ``step`` (no array I/O)."""
        meta = load_meta(self._npz(step))
        if "host" not in meta:
            raise CheckpointCorruptError(
                f"{self._npz(step)}: missing host-state blob")
        return meta["host"]

    def load(self, step: int, like: Any) -> tuple[Any, dict]:
        """(arrays, host_state) of one step, integrity-checked: whole-file
        CRC from the manifest, then per-entry CRCs inside load_pytree."""
        path = self._npz(step)
        if not os.path.exists(path):
            raise CheckpointCorruptError(f"{path}: missing archive")
        ent = self._read_manifest().get("entries", {}).get(str(int(step)))
        if ent and _file_crc(path) != ent["crc32"]:
            raise CheckpointCorruptError(
                f"{path}: whole-file crc32 mismatch vs manifest")
        host = self.peek(step)
        return load_pytree(path, like), host

    def load_latest(self, make_like: Callable[[dict], Any]
                    ) -> tuple[Any, dict, int] | None:
        """Newest valid checkpoint as (arrays, host_state, step), falling
        back step by step past corrupted archives; None if no checkpoints,
        raises if every retained step is corrupt."""
        steps = self.steps()
        if not steps:
            return None
        skipped: list[str] = []
        for step in reversed(steps):
            try:
                host = self.peek(step)
                arrays, host = self.load(step, make_like(host))
                return arrays, host, step
            except CheckpointCorruptError as e:
                skipped.append(str(e))
        raise CheckpointCorruptError(
            "no valid checkpoint among retained steps "
            f"{steps}: {'; '.join(skipped)}")
