"""Deterministic fault injection for training and serving (DESIGN.md §10).

A :class:`FaultPlan` is a pure, reusable schedule: given the same seed and
knobs it always describes the same faults, so every failure mode a test
exercises is reproducible bit-for-bit.  The plan itself holds no mutable
state — ``run_eat_distgnn`` and ``GNNServingEngine.tick`` query it at
their epoch/tick boundaries:

  · **Partition-host crashes** fire at epoch boundaries (after the epoch's
    checkpoint, the only honest crash point an epoch-granular checkpointer
    can replay through) by raising :class:`InjectedCrash`; serving-side
    crashes fail a partition's health at a tick boundary.
  · **Straggler delays** add per-partition seconds to the simulated host
    time of chosen epochs — the synchronous phases feel them through the
    existing max-over-hosts accounting, numerics are untouched.
  · **Dropped halo-refresh payloads** make the engine discard the freshly
    exchanged cache state for one eval forward (the wire ate the payload;
    the stale cache ages on), via ``SPMDEngine.drop_next_halo_refresh``.
  · **Checkpoint corruption** helpers truncate or bit-flip files on disk
    at seed-determined offsets, for exercising the CRC/fallback paths.

``FaultPlan.random`` draws a full schedule from one seed; explicit
constructor arguments script exact scenarios.
"""
from __future__ import annotations

import os
import zlib
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

__all__ = ["InjectedCrash", "FaultPlan", "truncate_file", "flip_bit"]


class InjectedCrash(RuntimeError):
    """A scheduled partition-host crash (training epoch boundary)."""

    def __init__(self, epoch: int):
        super().__init__(f"injected crash after epoch {epoch}")
        self.epoch = epoch


def truncate_file(path: str, keep_fraction: float = 0.5) -> int:
    """Cut ``path`` to the leading fraction of its bytes; returns new size."""
    size = os.path.getsize(path)
    keep = max(0, int(size * keep_fraction))
    with open(path, "rb+") as f:
        f.truncate(keep)
    return keep


def flip_bit(path: str, byte_offset: int, bit: int = 0) -> None:
    """Flip one bit in place (the classic silent-corruption model)."""
    with open(path, "rb+") as f:
        f.seek(byte_offset)
        b = f.read(1)
        f.seek(byte_offset)
        f.write(bytes([b[0] ^ (1 << (bit & 7))]))


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, immutable fault schedule.

    ``crash_epochs``       epoch-boundary counts (epochs completed) at which
                           training raises :class:`InjectedCrash`.
    ``straggler``          {epoch: {partition: delay_seconds}} added to the
                           simulated host time.
    ``drop_refresh_epochs`` epochs whose eval-forward halo refresh payload
                           is dropped in transit (halo cache runs only).
    ``serve_fail``         {tick: (partitions,)} failed at that tick.
    ``serve_recover``      {tick: (partitions,)} recovered at that tick.
    ``seed``               drives the corruption helpers' offsets.
    """

    crash_epochs: frozenset = frozenset()
    straggler: Mapping[int, Mapping[int, float]] = field(default_factory=dict)
    drop_refresh_epochs: frozenset = frozenset()
    serve_fail: Mapping[int, tuple] = field(default_factory=dict)
    serve_recover: Mapping[int, tuple] = field(default_factory=dict)
    seed: int = 0

    # ---------------------------------------------------- training queries
    def crash_at(self, epochs_completed: int) -> bool:
        return epochs_completed in self.crash_epochs

    def straggler_delay(self, epoch: int, num_parts: int) -> np.ndarray:
        out = np.zeros(num_parts)
        for p, d in self.straggler.get(epoch, {}).items():
            if 0 <= int(p) < num_parts:
                out[int(p)] = float(d)
        return out

    def drop_halo_refresh(self, epoch: int) -> bool:
        return epoch in self.drop_refresh_epochs

    # ----------------------------------------------------- serving queries
    def serve_events(self, tick: int) -> list[tuple[str, int]]:
        """[('fail'|'recover', partition), ...] scheduled for this tick."""
        ev = [("fail", int(p)) for p in self.serve_fail.get(tick, ())]
        ev += [("recover", int(p)) for p in self.serve_recover.get(tick, ())]
        return ev

    # ------------------------------------------------- checkpoint sabotage
    def corrupt(self, path: str, mode: str = "bitflip") -> dict:
        """Deterministically damage a checkpoint file: the offset is a pure
        function of (plan seed, file name, file size), so the same plan
        always injects the same corruption."""
        size = os.path.getsize(path)
        h = zlib.crc32(os.path.basename(path).encode()) ^ (self.seed * 2654435761)
        if mode == "truncate":
            keep = truncate_file(path, 0.25 + (h % 1000) / 4000.0)
            return {"mode": "truncate", "kept_bytes": keep, "orig_bytes": size}
        if mode == "bitflip":
            # land inside the archive body, past the local zip header
            off = 64 + (h % max(1, size - 128)) if size > 256 else size // 2
            flip_bit(path, off, h % 8)
            return {"mode": "bitflip", "byte_offset": off, "bit": h % 8}
        raise ValueError(f"unknown corruption mode: {mode}")

    # ------------------------------------------------------------ builders
    @classmethod
    def random(cls, seed: int, *, num_parts: int, max_epochs: int,
               crash_prob: float = 0.2, straggler_prob: float = 0.2,
               drop_refresh_prob: float = 0.2, max_delay_s: float = 2.0,
               serve_ticks: int = 0, serve_fail_prob: float = 0.0,
               down_ticks: int = 3) -> "FaultPlan":
        """Draw a full schedule from one seed (same seed → same plan)."""
        rng = np.random.default_rng([seed, 0xFA17])
        crash = frozenset(
            int(e) for e in range(1, max_epochs)
            if rng.random() < crash_prob)
        straggler = {}
        for e in range(max_epochs):
            if rng.random() < straggler_prob:
                p = int(rng.integers(num_parts))
                straggler[e] = {p: float(rng.uniform(0.1, max_delay_s))}
        drops = frozenset(
            int(e) for e in range(max_epochs)
            if rng.random() < drop_refresh_prob)
        fail, recover = {}, {}
        for t in range(1, serve_ticks + 1):
            if rng.random() < serve_fail_prob:
                p = int(rng.integers(num_parts))
                fail.setdefault(t, ())
                fail[t] = fail[t] + (p,)
                rt = t + down_ticks
                recover.setdefault(rt, ())
                recover[rt] = recover[rt] + (p,)
        return cls(crash_epochs=crash, straggler=straggler,
                   drop_refresh_epochs=drops, serve_fail=fail,
                   serve_recover=recover, seed=seed)
