"""Fault tolerance: deterministic fault injection, epoch-granular
checkpoint/resume, serving degradation support (DESIGN.md §10)."""
from .checkpoint import RunCheckpointer
from .faults import FaultPlan, InjectedCrash, flip_bit, truncate_file

__all__ = ["FaultPlan", "InjectedCrash", "RunCheckpointer", "flip_bit",
           "truncate_file"]
