"""EAT-DistGNN pipeline: EW partitioning → CBS sampling → GP training.

This is the paper's full experimental loop (the engine behind Tables II–V
and Fig. 3), simulated over N logical compute hosts.  Faithfulness notes:

  · Phase-0 is synchronous data-parallel SGD: per host gradients on its own
    batch, averaged each iteration (the all-reduce), identical updates.
  · The personalization trigger is loss-curve flattening (Fig. 3 magenta).
  · Phase-1 stops aggregating; each host descends its local loss + the
    Eq. 4 prox term, with per-host early stopping and per-host best models.
  · CBS mini-epochs resample 25% of the host's training nodes by Eq. 3.
  · Sampling may cross partition boundaries exactly like DistDGL's remote
    neighbour fetch (we account the traffic rather than forbid it).
  · "Distributed" timing on one CPU is reported as the paper measures it:
    per-epoch time = max over hosts (synchronous phases) or per-host
    cumulative time (asynchronous phase-1); communication is additionally
    reported in bytes (gradient + halo traffic), since wall-clock network
    time cannot be measured honestly in a single-process simulation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .core import (GPController, GPHyperParams, GPScheduleConfig,
                   broadcast_to_partitions, make_generalize_step,
                   make_personalize_step, partition_graph)
from .core.sampler import CBSampler
from .graph import BENCHMARKS, CSRGraph, GraphSAGE, NeighborSampler, make_benchmark
from .train.metrics import F1Report, f1_scores
from .train.optim import AdamW, apply_updates

__all__ = ["EATConfig", "EATResult", "run_eat_distgnn"]


@dataclass(frozen=True)
class EATConfig:
    dataset: str = "products-s"
    num_parts: int = 4
    partition_method: str = "ew"          # random | metis | ew | ew_balanced
    use_cbs: bool = True
    use_gp: bool = True
    use_focal: bool = False
    max_epochs: int = 40
    hidden_dim: int = 128
    batch_size: int = 256
    fanouts: tuple[int, int] = (10, 10)
    lr: float = 1e-3
    lambda_prox: float = 0.01
    subset_fraction: float = 0.25
    flatten_tol: float = 0.02
    seed: int = 0
    centralized: bool = False             # 1 host, no partitioning (Table IV)


@dataclass
class EATResult:
    config: EATConfig
    f1: F1Report                       # pooled test predictions
    per_partition_micro: np.ndarray
    partition_entropies: np.ndarray
    partition_time_s: float
    weight_time_s: float
    train_time_s: float                # simulated distributed wall time
    epoch_time_s: float                # mean per-epoch (phase-0)
    epochs_run: int
    personalize_start_epoch: int
    loss_history: list[float] = field(default_factory=list)
    val_history: list[float] = field(default_factory=list)
    comm_grad_bytes: int = 0
    comm_halo_bytes: int = 0

    def summary(self) -> dict:
        return {
            "dataset": self.config.dataset,
            "method": self._label(),
            "parts": self.config.num_parts,
            "micro_f1": round(self.f1.micro * 100, 2),
            "macro_f1": round(self.f1.macro * 100, 2),
            "weighted_f1": round(self.f1.weighted * 100, 2),
            "train_time_s": round(self.train_time_s, 2),
            "epoch_time_s": round(self.epoch_time_s, 3),
            "epochs": self.epochs_run,
            "personalize_start": self.personalize_start_epoch,
            "avg_entropy": round(float(self.partition_entropies.mean()), 4),
            "partition_time_s": round(self.partition_time_s, 2),
            "comm_grad_mb": round(self.comm_grad_bytes / 1e6, 1),
            "comm_halo_mb": round(self.comm_halo_bytes / 1e6, 1),
        }

    def _label(self) -> str:
        c = self.config
        if c.centralized:
            return "Centralized"
        parts = {"random": "RAND", "metis": "METIS", "ew": "EW",
                 "ew_balanced": "EW-BAL"}[c.partition_method]
        mods = [parts]
        if c.use_gp:
            mods.append("GP")
        if c.use_cbs:
            mods.append("CBS")
        return "+".join(mods)


def _param_bytes(params) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(params))


def _eval_full(model, params, graph: CSRGraph, idx: np.ndarray,
               edge_src, edge_dst) -> tuple[np.ndarray, np.ndarray]:
    logits = model.apply_full(params, jnp.asarray(graph.features), edge_src,
                              edge_dst, graph.num_nodes)
    preds = np.asarray(jnp.argmax(logits, axis=-1))
    return preds[idx], graph.labels[idx]


def run_eat_distgnn(cfg: EATConfig, verbose: bool = False) -> EATResult:
    rng = np.random.default_rng([cfg.seed, 0xEA7])
    graph = make_benchmark(BENCHMARKS[cfg.dataset])
    n_parts = 1 if cfg.centralized else cfg.num_parts

    # ---------------- partitioning (host-side preprocessing, timed) -------
    if cfg.centralized:
        parts = np.zeros(graph.num_nodes, dtype=np.int64)
        p_time = w_time = 0.0
        ents = np.array([0.0])
    else:
        pres = partition_graph(graph.indptr, graph.indices, graph.features,
                               graph.labels, n_parts,
                               method=cfg.partition_method, seed=cfg.seed,
                               fanout_k=cfg.fanouts[0])
        parts = pres.parts
        p_time, w_time = pres.partition_time_s, pres.weight_time_s
        ents = pres.stats.entropies
        if verbose:
            print(f"partition[{cfg.partition_method}] {pres.stats.row()}")

    # cross-partition edges = remote fetch volume per epoch (DistDGL analog)
    src_all = graph.indices
    dst_all = np.repeat(np.arange(graph.num_nodes), np.diff(graph.indptr))
    cut_frac = float((parts[src_all] != parts[dst_all]).mean())

    # ---------------- per-host samplers -----------------------------------
    model = GraphSAGE(feature_dim=graph.feature_dim, hidden_dim=cfg.hidden_dim,
                      num_classes=graph.num_classes)
    loss_fn = model.make_loss_fn(loss="focal" if cfg.use_focal else "ce")
    neigh = NeighborSampler(graph, fanouts=cfg.fanouts, seed=cfg.seed)

    host_train = [graph.train_idx[parts[graph.train_idx] == p] for p in range(n_parts)]
    host_val = [graph.val_idx[parts[graph.val_idx] == p] for p in range(n_parts)]
    host_test = [graph.test_idx[parts[graph.test_idx] == p] for p in range(n_parts)]
    samplers = [
        CBSampler(graph.indptr, graph.indices, graph.labels, host_train[p],
                  batch_size=cfg.batch_size,
                  subset_fraction=cfg.subset_fraction if cfg.use_cbs else 1.0,
                  class_balanced=cfg.use_cbs, seed=cfg.seed + p)
        for p in range(n_parts)
    ]

    # ---------------- jitted steps ----------------------------------------
    opt = AdamW(lr=cfg.lr, grad_clip=5.0)
    params = model.init(cfg.seed)
    opt_state = opt.init(params)
    grad_bytes_per_sync = _param_bytes(params)

    @jax.jit
    def grad_step(p, batch):
        return jax.value_and_grad(loss_fn)(p, batch)

    @jax.jit
    def apply_avg(p, o, grads):
        updates, o2 = opt.update(grads, o, p)
        return apply_updates(p, updates), o2

    pstep = jax.jit(make_personalize_step(
        loss_fn, opt, GPHyperParams(lambda_prox=cfg.lambda_prox)))

    edge_src = jnp.asarray(graph.indices)
    edge_dst = jnp.asarray(dst_all)

    def make_batch(nodes: np.ndarray) -> dict:
        # fixed shapes (pad + mask) so batches stack across hosts and the
        # jitted step compiles once — mirrors the static-shape TPU contract
        k = len(nodes)
        if k < cfg.batch_size:
            nodes = np.concatenate(
                [nodes, np.zeros(cfg.batch_size - k, dtype=nodes.dtype)])
        mask = np.zeros(cfg.batch_size, np.float32)
        mask[:k] = 1.0
        blocks = neigh.sample(nodes)
        x_t, x_1, x_2 = blocks.feature_views(graph.features)
        return {"x_t": jnp.asarray(x_t), "x_1": jnp.asarray(x_1),
                "x_2": jnp.asarray(x_2),
                "labels": jnp.asarray(graph.labels[nodes]),
                "mask": jnp.asarray(mask)}

    # ---------------- phase 0: generalization -----------------------------
    ctrl = GPController(
        num_partitions=n_parts,
        config=GPScheduleConfig(max_epochs=cfg.max_epochs,
                                flatten_tol=cfg.flatten_tol),
    )
    sim_time = 0.0
    epoch_times: list[float] = []
    comm_grad = 0
    comm_halo = 0
    best_global = params
    loss_hist: list[float] = []
    val_hist: list[float] = []

    while not ctrl.done and ctrl.phase == 0:
        host_batches = [s.batches() for s in samplers]
        iters = max(len(b) for b in host_batches)
        host_time = np.zeros(n_parts)
        ep_losses = []
        for it in range(iters):
            grads_acc = None
            for p in range(n_parts):
                hb = host_batches[p]
                nodes = hb[it % len(hb)]
                t0 = time.perf_counter()
                batch = make_batch(nodes)
                l, g = grad_step(params, batch)
                jax.block_until_ready(l)
                host_time[p] += time.perf_counter() - t0
                ep_losses.append(float(l))
                grads_acc = g if grads_acc is None else jax.tree.map(
                    lambda a, b: a + b, grads_acc, g)
            grads = jax.tree.map(lambda g_: g_ / n_parts, grads_acc)
            params, opt_state = apply_avg(params, opt_state, grads)
            comm_grad += grad_bytes_per_sync * n_parts
        comm_halo += int(cut_frac * graph.num_edges * graph.feature_dim * 4
                         * cfg.subset_fraction)
        # synchronous epoch: everyone waits for the slowest host
        sim_time += float(host_time.max())
        epoch_times.append(float(host_time.max()))

        scores = []
        for p in range(n_parts):
            pred, lab = _eval_full(model, params, graph, host_val[p],
                                   edge_src, edge_dst)
            scores.append(f1_scores(pred, lab, graph.num_classes).micro)
        mean_loss = float(np.mean(ep_losses))
        mean_val = float(np.mean(scores))
        loss_hist.append(mean_loss)
        val_hist.append(mean_val)
        if ctrl.record_phase0(mean_loss, mean_val):
            best_global = params
        if verbose:
            print(f"[phase-0] epoch {ctrl.epoch:3d} loss {mean_loss:.4f} "
                  f"val-micro {mean_val*100:.2f}")
        if cfg.use_gp and ctrl.should_personalize():
            ctrl.start_personalization()
        elif not cfg.use_gp and ctrl.phase0_stopper.stopped:
            break

    personalize_start = ctrl.personalize_start_epoch

    # ---------------- phase 1: personalization ----------------------------
    if cfg.use_gp and not cfg.centralized:
        global_params = best_global
        pparams = broadcast_to_partitions(global_params, n_parts)
        popt = jax.vmap(opt.init)(pparams)
        best_personal = [jax.tree.map(lambda x: x[p], pparams)
                         for p in range(n_parts)]
        host_elapsed = np.zeros(n_parts)
        while not ctrl.done:
            active_np = ctrl.active_partitions
            active = jnp.asarray(active_np)
            host_batches = [s.batches() for s in samplers]
            iters = max(len(b) for b in host_batches)
            t_host = np.zeros(n_parts)
            losses_ep = np.zeros(n_parts)
            for it in range(iters):
                stacked = [None] * n_parts
                for p in range(n_parts):
                    hb = host_batches[p]
                    nodes = hb[it % len(hb)]
                    t0 = time.perf_counter()
                    stacked[p] = make_batch(nodes)
                    t_host[p] += time.perf_counter() - t0
                batch_p = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
                t0 = time.perf_counter()
                pparams, popt, losses = pstep(pparams, popt, batch_p,
                                              global_params, active)
                jax.block_until_ready(losses)
                # vmapped step: attribute 1/n of device time to each host
                t_host += (time.perf_counter() - t0) / n_parts
                losses_ep = np.asarray(losses)
            host_elapsed += np.where(active_np, t_host, 0.0)
            scores = np.zeros(n_parts)
            for p in range(n_parts):
                pp = jax.tree.map(lambda x: x[p], pparams)
                pred, lab = _eval_full(model, pp, graph, host_val[p],
                                       edge_src, edge_dst)
                scores[p] = f1_scores(pred, lab, graph.num_classes).micro
            is_best = ctrl.record_phase1(scores)
            for p in np.flatnonzero(is_best):
                best_personal[p] = jax.tree.map(lambda x: x[p], pparams)
            loss_hist.append(float(losses_ep.mean()))
            val_hist.append(float(scores.mean()))
            if verbose:
                print(f"[phase-1] epoch {ctrl.epoch:3d} "
                      f"val-micro {scores.mean()*100:.2f} "
                      f"active {int(active_np.sum())}/{n_parts}")
        # async phase: distributed time = slowest host's own cumulative time
        sim_time += float(host_elapsed.max())
        final_models = best_personal
    else:
        final_models = [best_global] * n_parts

    # ---------------- final evaluation -------------------------------------
    all_preds, all_labels, per_micro = [], [], np.zeros(n_parts)
    for p in range(n_parts):
        pred, lab = _eval_full(model, final_models[p], graph, host_test[p],
                               edge_src, edge_dst)
        all_preds.append(pred)
        all_labels.append(lab)
        per_micro[p] = f1_scores(pred, lab, graph.num_classes).micro
    f1 = f1_scores(np.concatenate(all_preds), np.concatenate(all_labels),
                   graph.num_classes)

    return EATResult(
        config=cfg, f1=f1, per_partition_micro=per_micro,
        partition_entropies=ents, partition_time_s=p_time, weight_time_s=w_time,
        train_time_s=sim_time,
        epoch_time_s=float(np.mean(epoch_times)) if epoch_times else 0.0,
        epochs_run=ctrl.epoch, personalize_start_epoch=personalize_start,
        loss_history=loss_hist, val_history=val_hist,
        comm_grad_bytes=comm_grad, comm_halo_bytes=comm_halo,
    )
