"""EAT-DistGNN pipeline: EW partitioning → CBS sampling → GP training.

This is the paper's full experimental loop (the engine behind Tables II–V
and Fig. 3) over N logical compute hosts.  Since PR 1 the per-partition
Python loop is gone: every epoch executes as two fused steps through
``repro.engine.SPMDEngine`` (DESIGN.md §3) — one jitted trace scans all
training iterations with the cross-partition gradient mean, a second runs
the full-graph validation forward with its per-layer halo ``all_to_all``
and the Pallas ``segment_agg`` aggregation.  On a multi-device host the
same per-shard program runs under ``shard_map`` over a partition mesh; on
one CPU it runs under ``vmap`` with identical collective semantics;
``engine_mode="sequential"`` keeps the legible Python-loop reference (the
parity oracle of tests/test_engine_parity.py).

Faithfulness notes:

  · Phase-0 is synchronous data-parallel SGD: per host gradients on its own
    batch, averaged each iteration (the all-reduce), identical updates.
  · The personalization trigger is loss-curve flattening (Fig. 3 magenta).
  · Phase-1 stops aggregating; each host descends its local loss + the
    Eq. 4 prox term, with per-host early stopping and per-host best models.
  · Evaluation (phase-1 validation and the final test) runs through the
    DISTRIBUTED forward: boundary nodes aggregate halo embeddings computed
    under the OWNING partition's personalized model — the semantics a real
    deployment has, and a deliberate change from the pre-engine driver,
    which evaluated each host's model solo over the whole graph.
  · CBS mini-epochs resample 25% of the host's training nodes by Eq. 3.
  · ``full_graph_train=True`` replaces phase-0's sampled minibatches with
    full-batch ``value_and_grad`` straight through the distributed forward
    (halo exchange + the differentiable blocked aggregation op, DESIGN.md
    §6); with ``centralized=True`` this is the Table IV baseline trained at
    full-graph scale on the kernel path.
  · ``async_personalize=True`` makes phase-1 genuinely asynchronous: each
    partition gets its own iteration budget from GPController (masked
    variable-length scan), and the mini-epoch draw itself moves on-device
    (core/sampler/cbs_device.py) so no host NumPy runs on that path;
    DESIGN.md §4 defines what "epoch" means when budgets differ.
  · ``async_generalize=True`` moves phase-0's epoch draw on-device too
    (the same DeviceEpochSampler: CBS-weighted mini-epochs, or a uniform
    shuffle of the local train set without CBS) and fuses the train scan
    WITH the validation eval forward into one compiled call, so a
    generalization epoch is one host→device round-trip — no host NumPy
    draw and no ``_EpochPrefetcher`` on that path (DESIGN.md §7).
    ``full_graph_train`` supersedes it (full-graph phase-0 has no sampling).
  · Host-side sampling (where it remains) is double-buffered: epoch t+1's
    draw overlaps epoch t's fused device step.  The prefetcher is created
    lazily, on the first epoch that actually samples on the host.
  · Sampling may cross partition boundaries exactly like DistDGL's remote
    neighbour fetch; comm_halo_bytes accounts BOTH that sampled remote-fetch
    volume (cut_fraction-scaled, per training epoch) and the eval forward's
    per-layer halo all_to_all volume (PartitionedGraph.halo_bytes_per_layer).
  · "Distributed" timing on one CPU is reported as the paper measures it:
    per-epoch time = max over hosts of (host-side sampling time + an equal
    1/N share of the fused TRAIN scan), synchronous phases waiting for the
    slowest host; phase-1 accumulates per-host time only while that host is
    active.  Validation-forward time is excluded, as in the original
    per-batch driver, so epoch-time ablations compare training work.  Communication is additionally reported in bytes (gradient +
    halo traffic), since wall-clock network time cannot be measured honestly
    in a single-process simulation.  XLA compilation is excluded (the engine
    AOT-compiles each epoch shape before the timed call).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .core import (GPController, GPHyperParams, GPScheduleConfig,
                   broadcast_to_partitions, partition_graph)
from .core.gp.trainer import grad_sync_wire_bytes
from .core.sampler import (CBSampler, build_device_epoch_sampler,
                           host_draw_count)
from .engine import (EngineConfig, make_engine, stack_epoch_batches,
                     stack_pytrees)
from .graph import (BENCHMARKS, GraphSAGE, NeighborSampler,
                    build_partitioned_graph, make_benchmark)
from .robustness import FaultPlan, InjectedCrash, RunCheckpointer
from .train.metrics import F1Report, f1_scores
from .train.optim import AdamW

__all__ = ["EATConfig", "EATResult", "run_eat_distgnn"]


@dataclass(frozen=True)
class EATConfig:
    dataset: str = "products-s"
    num_parts: int = 4
    partition_method: str = "ew"          # random | metis | ew | ew_balanced
    use_cbs: bool = True
    use_gp: bool = True
    use_focal: bool = False
    max_epochs: int = 40
    hidden_dim: int = 128
    batch_size: int = 256
    fanouts: tuple[int, int] = (10, 10)
    lr: float = 1e-3
    lambda_prox: float = 0.01
    subset_fraction: float = 0.25
    flatten_tol: float = 0.02
    # hard phase split: fraction of max_epochs spent generalizing (the
    # paper's "parameter controls the proportion"); None = loss-driven
    # trigger, except async runs default to 0.4 so personalization — the
    # phase async exists for — is reached even under tiny epoch budgets
    phase0_fraction: float | None = None
    seed: int = 0
    centralized: bool = False             # 1 host, no partitioning (Table IV)
    engine_mode: str = "auto"             # auto | spmd | stacked | sequential
    use_pallas_agg: bool = True           # Pallas segment_agg on the eval path
    # boundary/interior split forward: overlap each layer's halo exchange
    # with interior aggregation + the self-term matmul (DESIGN.md §5)
    overlap_halo: bool = False
    ring_chunks: int = 0                  # chunked ppermute ring (0 = all_to_all)
    # historical-embedding halo cache (DESIGN.md §8): eval forwards aggregate
    # against the last-received boundary embeddings; only every
    # halo_refresh_every-th forward pays the full exchange, and halo_cv
    # refreshes a rotating slot chunk in between (VR-GCN control variate)
    halo_cache: bool = False
    halo_refresh_every: int = 4
    halo_cv: bool = False
    # compressed communication (DESIGN.md §11): quantized halo exchange on
    # the eval forwards (error-compensated; composes with the halo cache and
    # either exchange schedule) and the phase-0 gradient all-reduce spelling
    halo_compress: str = "none"           # none | fp16 | int8
    grad_compress: str = "none"           # none | bucketed | topk
    grad_topk_frac: float = 0.01          # fraction of entries top-k ships
    grad_bucket_kb: int = 512             # bucketed psum slice size
    interpret: bool = True                # Pallas interpret mode (False on TPU)
    # phase-0 trains FULL-GRAPH instead of sampled minibatches: one (or
    # ``full_graph_iters``) full-batch value_and_grad step(s) per epoch
    # straight through the distributed forward — halo exchange and the
    # differentiable blocked aggregation op (custom VJP; DESIGN.md §6).
    # With ``centralized=True`` this is the paper's Table IV baseline
    # trained at full-graph scale on the MXU path.
    full_graph_train: bool = False
    full_graph_iters: int = 1             # full-batch steps per phase-0 epoch
    # phase-1 runs fully on device: per-partition iteration budgets + the CBS
    # mini-epoch draw / fanout sampling / feature gather on the epoch trace
    # (no host NumPy on the mini-epoch path; DESIGN.md §4)
    async_personalize: bool = False
    # phase-0 runs fully on device too: the epoch draw (CBS mini-epoch, or a
    # uniform train-set shuffle without CBS) plus the train scan plus the
    # fused validation eval, all in ONE device program per epoch — no host
    # prefetcher on this path (DESIGN.md §7; superseded by full_graph_train)
    async_generalize: bool = False
    # overlap host-side sampling of epoch t+1 with the device step of epoch t
    double_buffer: bool = True
    # fault tolerance (DESIGN.md §10): checkpoint_dir arms epoch-granular
    # checkpointing through RunCheckpointer (atomic archives + checksummed
    # manifest, last keep_checkpoints retained); resume=True restores the
    # newest valid checkpoint and continues such that final params and val
    # micro-F1 are bit-for-bit the uninterrupted run's
    checkpoint_dir: str | None = None
    checkpoint_every: int = 1
    keep_checkpoints: int = 3
    resume: bool = False
    # two-tier feature store (DESIGN.md §12): keep the top hot_frac of each
    # partition's feature rows (by hot_policy score) resident on device and
    # stage the cold remainder from host numpy per compiled call; the device
    # sampler's gather table splits the same way.  feat_groups > 0 streams
    # the eval over G-partition groups (stacked mode only) so a feature
    # matrix bigger than the stacked plane still evaluates; feat_budget_mb
    # makes the engine refuse to build when peak device feature bytes
    # exceed the budget (<= 0 disables)
    feat_store: bool = False
    hot_frac: float = 0.5
    hot_policy: str = "degree"            # degree | freq
    feat_groups: int = 0
    feat_budget_mb: float = 0.0
    # float dtype of the feature/mask path ("float32" | "float64"); float64
    # needs jax_enable_x64 and is what the fp64 resume-parity oracles run
    dtype: str = "float32"


@dataclass
class EATResult:
    config: EATConfig
    f1: F1Report                       # pooled test predictions
    per_partition_micro: np.ndarray
    partition_entropies: np.ndarray
    partition_time_s: float
    weight_time_s: float
    train_time_s: float                # simulated distributed wall time
    epoch_time_s: float                # mean per-epoch (phase-0), eval excluded
                                       # where eval is a separate call
    epochs_run: int
    personalize_start_epoch: int
    loss_history: list[float] = field(default_factory=list)
    val_history: list[float] = field(default_factory=list)
    comm_grad_bytes: int = 0
    comm_halo_bytes: int = 0
    # per-phase communication volume (bytes moved, not just seconds):
    # gradient all-reduce traffic is phase-0 only; halo/remote-fetch
    # traffic is attributed to the phase whose epochs incurred it
    comm_halo_bytes_phase0: int = 0
    comm_halo_bytes_phase1: int = 0
    halo_bytes_per_layer: int = 0      # eval-forward exchange payload/layer
    # eval-forward exchange volume actually paid (sum and per-epoch trace):
    # equals 2 * halo_bytes_per_layer per epoch without the cache, only the
    # refreshed-row payload per epoch with --halo-cache
    comm_halo_exchange_bytes: int = 0
    halo_exchange_history: list[int] = field(default_factory=list)
    engine_mode: str = "stacked"
    phase1_time_s: float = 0.0         # slowest host's cumulative phase-1 time
    phase1_epochs: int = 0
    host_draws_phase1: int = 0         # host NumPy mini-epoch draws in phase-1
                                       # (0 under async_personalize)
    host_draws_phase0: int = 0         # host NumPy epoch draws in phase-0
                                       # (0 under async_generalize)
    # per-epoch TRAIN iteration counts in phase-0 — the deterministic
    # work-based witness that CBS mini-epochs shorten the epoch (the
    # wall-clock claim's machine-load-independent proxy)
    phase0_iter_history: list[int] = field(default_factory=list)
    # TOTAL host→device payload across all phase-0 epochs: stacked batch
    # arrays on the host-sampled path, just the (P, 2) PRNG keys per epoch
    # on the async path (divide by epochs for the per-epoch payload) —
    # plus, under the feature store, the cold rows staged for phase-0's
    # compiled calls (train gathers and the per-epoch validation eval)
    host_to_device_bytes_phase0: int = 0
    # phase-1's cold-row staging traffic (async epoch gathers, per-epoch
    # val evals AND the final test eval); 0 without the feature store
    host_to_device_bytes_phase1: int = 0
    # device-resident feature bytes (engine plane/hot tier + attached
    # sampler table) — the footprint the feature store shrinks
    resident_feature_bytes: int = 0
    # total cold-row host->device staging bytes (both phases)
    cold_h2d_bytes: int = 0
    # mean phase-0 epoch period INCLUDING the validation eval's 1/N share —
    # the apples-to-apples number against the fused async epoch, whose one
    # device call is inseparable from its eval (epoch_time_s excludes eval
    # wherever eval is a separately-compiled call)
    epoch_time_with_eval_s: float = 0.0
    # the stacked per-partition params the final test eval ran with — the
    # bit-for-bit witness the kill-and-resume parity tests compare
    final_params: Any = None
    # epoch the run resumed from (-1 = fresh start)
    resumed_from_epoch: int = -1
    # total injected straggler delay (max over hosts per epoch, summed)
    straggler_delay_s: float = 0.0

    def summary(self) -> dict:
        return {
            "dataset": self.config.dataset,
            "method": self._label(),
            "parts": self.config.num_parts,
            "engine": self.engine_mode,
            "micro_f1": round(self.f1.micro * 100, 2),
            "macro_f1": round(self.f1.macro * 100, 2),
            "weighted_f1": round(self.f1.weighted * 100, 2),
            "train_time_s": round(self.train_time_s, 2),
            "epoch_time_s": round(self.epoch_time_s, 3),
            "epoch_time_with_eval_s": round(self.epoch_time_with_eval_s, 4),
            "epochs": self.epochs_run,
            "personalize_start": self.personalize_start_epoch,
            "avg_entropy": round(float(self.partition_entropies.mean()), 4),
            "partition_time_s": round(self.partition_time_s, 2),
            "comm_grad_mb": round(self.comm_grad_bytes / 1e6, 1),
            "comm_halo_mb": round(self.comm_halo_bytes / 1e6, 1),
            "comm_halo_phase0_mb": round(self.comm_halo_bytes_phase0 / 1e6, 1),
            "comm_halo_phase1_mb": round(self.comm_halo_bytes_phase1 / 1e6, 1),
            "halo_bytes_per_layer": self.halo_bytes_per_layer,
            "halo_cache": self.config.halo_cache,
            "halo_refresh_every": self.config.halo_refresh_every,
            "halo_cv": self.config.halo_cv,
            "halo_compress": self.config.halo_compress,
            "grad_compress": self.config.grad_compress,
            "comm_halo_exchange_mb": round(
                self.comm_halo_exchange_bytes / 1e6, 3),
            "phase1_time_s": round(self.phase1_time_s, 3),
            "phase1_epochs": self.phase1_epochs,
            "async_personalize": self.config.async_personalize,
            "async_generalize": self.config.async_generalize,
            "overlap_halo": self.config.overlap_halo,
            "full_graph_train": self.config.full_graph_train,
            "phase0_iters_per_epoch": (
                round(float(np.mean(self.phase0_iter_history)), 2)
                if self.phase0_iter_history else 0.0),
            "host_to_device_mb_phase0": round(
                self.host_to_device_bytes_phase0 / 1e6, 3),
            "host_to_device_mb_phase1": round(
                self.host_to_device_bytes_phase1 / 1e6, 3),
            "feat_store": self.config.feat_store,
            "hot_frac": self.config.hot_frac,
            "resident_feature_mb": round(
                self.resident_feature_bytes / 1e6, 3),
            "cold_h2d_mb": round(self.cold_h2d_bytes / 1e6, 3),
            "resumed_from_epoch": self.resumed_from_epoch,
            "straggler_delay_s": round(self.straggler_delay_s, 3),
        }

    def _label(self) -> str:
        c = self.config
        if c.centralized:
            return "Centralized"
        parts = {"random": "RAND", "metis": "METIS", "ew": "EW",
                 "ew_balanced": "EW-BAL"}[c.partition_method]
        mods = [parts]
        if c.use_gp:
            mods.append("GP")
        if c.use_cbs:
            mods.append("CBS")
        return "+".join(mods)


class _EpochPrefetcher:
    """Double-buffered host sampling: draw epoch t+1's batches in a background
    thread while the device executes epoch t's fused step.

    One worker thread at a time, so the samplers' NumPy RNG streams advance
    in exactly the sequential order — results are identical to the
    unbuffered pipeline, only the wall-clock overlaps.

    ``snapshot`` (optional) is called on the MAIN thread immediately before
    each speculative draw starts, so ``last_snapshot`` always holds a
    race-free capture of the sampler RNG states with every draw through the
    last handed-out epoch consumed — the stream position an epoch-boundary
    checkpoint must store for a resumed run to re-draw the next epoch
    identically (DESIGN.md §10).
    """

    def __init__(self, draw, snapshot=None):
        self._draw = draw
        self._snapshot = snapshot
        self._pending = None
        self.last_snapshot = None

    def _spawn(self) -> None:
        import threading

        if self._snapshot is not None:
            self.last_snapshot = self._snapshot()
        box = {}

        def work():
            try:
                box["out"] = self._draw()
            except BaseException as e:   # surfaces in next(), not swallowed
                box["err"] = e

        th = threading.Thread(target=work, daemon=True)
        th.start()
        self._pending = (th, box)

    def next(self):
        """Epoch t's batches (waits if still sampling), then immediately
        kicks off epoch t+1's draw so it overlaps the caller's device step."""
        if self._pending is None:
            self._spawn()
        th, box = self._pending
        th.join()
        if "err" in box:
            raise box["err"]
        self._spawn()
        return box["out"]

    def settle(self) -> None:
        """Wait for any in-flight draw WITHOUT discarding it — quiesces the
        worker so host_draw_count() snapshots are race-free."""
        if self._pending is not None:
            self._pending[0].join()

    def close(self) -> None:
        """Join and discard any in-flight draw (phase transition / shutdown)."""
        if self._pending is not None:
            self._pending[0].join()
            self._pending = None


def run_eat_distgnn(cfg: EATConfig, verbose: bool = False,
                    fault_plan: FaultPlan | None = None) -> EATResult:
    if cfg.halo_cache and cfg.full_graph_train:
        raise ValueError(
            "halo_cache is an eval-forward optimisation; full_graph_train "
            "differentiates through the live halo exchange and cannot train "
            "against stale cached embeddings")
    if cfg.feat_store and cfg.full_graph_train:
        raise ValueError(
            "full_graph_train differentiates through the resident feature "
            "stack; the feature store's staged cold tier has no training "
            "spelling — run full-graph training all-resident")
    if cfg.feat_groups and cfg.async_generalize:
        raise ValueError(
            "feat_groups streams the eval host-side, which cannot live "
            "inside the fused async phase-0 program — run the host-batch "
            "phase-0 path (async_generalize=False) when streaming")
    fdt = np.dtype(cfg.dtype)
    graph = make_benchmark(BENCHMARKS[cfg.dataset])
    n_parts = 1 if cfg.centralized else cfg.num_parts

    # ---------------- partitioning (host-side preprocessing, timed) -------
    if cfg.centralized:
        parts = np.zeros(graph.num_nodes, dtype=np.int64)
        p_time = w_time = 0.0
        ents = np.array([0.0])
    else:
        pres = partition_graph(graph.indptr, graph.indices, graph.features,
                               graph.labels, n_parts,
                               method=cfg.partition_method, seed=cfg.seed,
                               fanout_k=cfg.fanouts[0])
        parts = pres.parts
        p_time, w_time = pres.partition_time_s, pres.weight_time_s
        ents = pres.stats.entropies
        if verbose:
            print(f"partition[{cfg.partition_method}] {pres.stats.row()}")

    # ---------------- stacked shards + engine ------------------------------
    pg = build_partitioned_graph(graph, parts, n_parts)
    model = GraphSAGE(feature_dim=graph.feature_dim, hidden_dim=cfg.hidden_dim,
                      num_classes=graph.num_classes)
    loss_fn = model.make_loss_fn(loss="focal" if cfg.use_focal else "ce")
    opt = AdamW(lr=cfg.lr, grad_clip=5.0)
    engine = make_engine(
        model, loss_fn, opt, pg,
        hp=GPHyperParams(lambda_prox=cfg.lambda_prox),
        config=EngineConfig(mode=cfg.engine_mode,
                            use_pallas_agg=cfg.use_pallas_agg,
                            interpret=cfg.interpret,
                            dtype=fdt,
                            overlap_halo=cfg.overlap_halo,
                            ring_chunks=cfg.ring_chunks,
                            fg_loss="focal" if cfg.use_focal else "ce",
                            halo_cache=cfg.halo_cache,
                            halo_refresh_every=cfg.halo_refresh_every,
                            halo_cv=cfg.halo_cv,
                            halo_compress=cfg.halo_compress,
                            grad_compress=cfg.grad_compress,
                            grad_topk_frac=cfg.grad_topk_frac,
                            grad_bucket_kb=cfg.grad_bucket_kb,
                            feat_store=cfg.feat_store,
                            hot_frac=cfg.hot_frac,
                            hot_policy=cfg.hot_policy,
                            feat_groups=cfg.feat_groups,
                            feat_budget_mb=cfg.feat_budget_mb))
    if verbose:
        print(f"engine[{engine.mode}] {pg.summary()}")

    # ---------------- per-host samplers -----------------------------------
    neigh = NeighborSampler(graph, fanouts=cfg.fanouts, seed=cfg.seed)
    host_train = [graph.train_idx[parts[graph.train_idx] == p]
                  for p in range(n_parts)]
    samplers = [
        CBSampler(graph.indptr, graph.indices, graph.labels, host_train[p],
                  batch_size=cfg.batch_size,
                  subset_fraction=cfg.subset_fraction if cfg.use_cbs else 1.0,
                  class_balanced=cfg.use_cbs, seed=cfg.seed + p)
        for p in range(n_parts)
    ]

    params = model.init(cfg.seed)
    opt_state = opt.init(params)
    # per-sync gradient wire volume, truthful to the sync SPELLING: the
    # plain all_gather ships P*(P-1) full copies, the bucketed ring 2*(P-1),
    # top-k only the (value, index) pairs each partition keeps
    p_leaves = jax.tree_util.tree_leaves(params)
    grad_bytes_per_sync = grad_sync_wire_bytes(
        cfg.grad_compress, n_parts, sum(l.size for l in p_leaves),
        itemsize=p_leaves[0].dtype.itemsize, topk_frac=cfg.grad_topk_frac)
    # cross-partition edges = remote fetch volume per epoch (DistDGL analog)
    src_all = graph.indices
    dst_all = np.repeat(np.arange(graph.num_nodes), np.diff(graph.indptr))
    cut_frac = float((parts[src_all] != parts[dst_all]).mean())
    # effective per-epoch visit fraction: CBS mini-epochs touch subset_fraction
    # of the train nodes, the plain sampler touches all of them
    eff_fraction = cfg.subset_fraction if cfg.use_cbs else 1.0
    fetch_bytes_per_epoch = int(cut_frac * graph.num_edges * graph.feature_dim
                                * fdt.itemsize * eff_fraction)
    def eval_exchange_bytes() -> int:
        # the exchange volume THIS epoch's eval forward actually paid: only
        # the refreshed-row payload under the historical halo cache (the
        # engine reports it after each cached forward), the full per-layer
        # WIRE payload (dtype- and compression-truthful) otherwise
        if cfg.halo_cache:
            return int(engine.last_halo_exchange_bytes)
        return model.num_layers * int(getattr(
            engine, "halo_wire_bytes_per_layer", pg.halo_bytes_per_layer))

    batch_feats = np.asarray(graph.features, fdt)

    def make_batch(nodes: np.ndarray) -> dict:
        # fixed shapes (pad + mask) so batches stack across hosts and the
        # jitted step compiles once — mirrors the static-shape TPU contract
        k = len(nodes)
        if k < cfg.batch_size:
            nodes = np.concatenate(
                [nodes, np.zeros(cfg.batch_size - k, dtype=nodes.dtype)])
        mask = np.zeros(cfg.batch_size, fdt)
        mask[:k] = 1.0
        blocks = neigh.sample(nodes)
        x_t, x_1, x_2 = blocks.feature_views(batch_feats)
        return {"x_t": jnp.asarray(x_t), "x_1": jnp.asarray(x_1),
                "x_2": jnp.asarray(x_2),
                "labels": jnp.asarray(graph.labels[nodes]),
                "mask": jnp.asarray(mask)}

    # ---------------- phase 0: generalization -----------------------------
    p0frac = cfg.phase0_fraction
    if p0frac is None and cfg.async_personalize:
        p0frac = 0.4
    sched = GPScheduleConfig(
        max_epochs=cfg.max_epochs,
        flatten_tol=cfg.flatten_tol,
        phase0_fraction=p0frac,
        # a hard split must fit the epoch budget (e.g. --epochs 3)
        min_phase0_epochs=(min(3, max(1, cfg.max_epochs // 3))
                           if p0frac is not None else 3))
    ctrl = GPController(num_partitions=n_parts, config=sched)
    sim_time = 0.0
    epoch_times: list[float] = []
    epoch_times_with_eval: list[float] = []
    comm_grad = 0
    comm_halo_p0 = 0
    comm_halo_p1 = 0
    halo_exchange_hist: list[int] = []   # per-epoch eval-exchange payload
    best_global = params
    loss_hist: list[float] = []
    val_hist: list[float] = []

    # host sampler RNG discipline for checkpointing: `rng_snapshot` always
    # holds the generator states with every draw through the last
    # handed-out epoch consumed — captured on the main thread BEFORE any
    # speculative prefetch draw, so the double-buffered path checkpoints
    # the same stream position the unbuffered path would (DESIGN.md §10)
    def capture_rng() -> dict:
        return {"cbs": [s._rng.bit_generator.state for s in samplers],
                "neigh": neigh._rng.bit_generator.state}

    def restore_rng(snap: dict) -> None:
        for s, st in zip(samplers, snap["cbs"]):
            s._rng.bit_generator.state = st
        neigh._rng.bit_generator.state = snap["neigh"]

    rng_snapshot = capture_rng()

    # the prefetcher exists only where host sampling does: it is created
    # lazily by the first epoch that draws on the host, so fully-async runs
    # never construct it (the phase-0 host-isolation contract)
    prefetch = None

    def next_epoch_batches():
        nonlocal prefetch, rng_snapshot
        if cfg.double_buffer:
            if prefetch is None:
                prefetch = _EpochPrefetcher(
                    lambda: stack_epoch_batches(samplers, make_batch, n_parts),
                    snapshot=capture_rng)
            out = prefetch.next()
            rng_snapshot = prefetch.last_snapshot
            return out
        out = stack_epoch_batches(samplers, make_batch, n_parts)
        rng_snapshot = capture_rng()
        return out

    # ONE device sampler serves both async phases (Eq. 3 / uniform logp +
    # fanout structure + features); staged lazily by the first phase that
    # needs it, so it never pins a replicated feature copy it won't use
    async_phase0 = cfg.async_generalize and not cfg.full_graph_train
    dev_sampler = None

    def stage_device_sampler():
        nonlocal dev_sampler
        if dev_sampler is None:
            dev_sampler = build_device_epoch_sampler(
                graph, host_train, n_parts, batch_size=cfg.batch_size,
                subset_fraction=cfg.subset_fraction if cfg.use_cbs else 1.0,
                class_balanced=cfg.use_cbs, fanouts=cfg.fanouts,
                feat_store=cfg.feat_store, hot_frac=cfg.hot_frac,
                hot_policy=cfg.hot_policy)
        return dev_sampler

    if async_phase0:
        engine.set_device_sampler(stage_device_sampler())
        p0_base_keys = jax.random.split(
            jax.random.PRNGKey(cfg.seed ^ 0x6E02), n_parts)

    def epoch_host_times(t_host, t_dev):
        # synchronous epoch: everyone waits for the slowest host; the fused
        # device step is attributed in equal 1/N shares.  Double-buffered,
        # the next epoch's sampling overlaps this epoch's device step, so
        # the steady-state epoch period is the max of the two, not the sum.
        if cfg.double_buffer:
            return np.maximum(t_host, t_dev / n_parts)
        return t_host + t_dev / n_parts

    # full-graph epochs exchange halos in BOTH directions of each train
    # step (the backward's transpose aggregation routes gradient through
    # the same send/recv lists), plus the per-epoch validation forward's
    # per-layer exchange — which the sampled path's accounting also counts
    # — and fetch no sampled neighbours
    # (training exchanges stay uncompressed — only the eval forward's
    # exchange is quantized, so only its term uses the wire-byte rate)
    fg_halo_bytes_per_epoch = (2 * model.num_layers * pg.halo_bytes_per_layer
                               * cfg.full_graph_iters
                               + model.num_layers * int(getattr(
                                   engine, "halo_wire_bytes_per_layer",
                                   pg.halo_bytes_per_layer)))

    host_to_device_p0 = 0
    host_to_device_p1 = 0
    p0_iter_hist: list[int] = []
    straggler_total = 0.0

    # cold-row staging is counted inside the engine (where the numpy buffer
    # is handed to a compiled call); the pipeline reads per-epoch DELTAS to
    # attribute the traffic to the phase that paid it
    cold_mark = int(getattr(engine, "cold_h2d_bytes", 0))

    def cold_delta() -> int:
        nonlocal cold_mark
        now = int(getattr(engine, "cold_h2d_bytes", 0))
        d, cold_mark = now - cold_mark, now
        return d

    # ---------------- checkpoint/resume (DESIGN.md §10) --------------------
    ckpt = (RunCheckpointer(cfg.checkpoint_dir,
                            keep_last=cfg.keep_checkpoints)
            if cfg.checkpoint_dir else None)
    fingerprint = {"dataset": cfg.dataset, "num_parts": n_parts,
                   "method": cfg.partition_method, "seed": cfg.seed,
                   "dtype": cfg.dtype, "engine": engine.mode,
                   "halo_cache": cfg.halo_cache,
                   "halo_compress": cfg.halo_compress,
                   "grad_compress": cfg.grad_compress,
                   "feat_store": cfg.feat_store,
                   "hot_frac": cfg.hot_frac if cfg.feat_store else 0.0,
                   "hot_policy": cfg.hot_policy if cfg.feat_store else ""}

    def halo_ckpt_state():
        if cfg.halo_cache and hasattr(engine, "halo_cache_state"):
            return engine.halo_cache_state()
        return None

    def comm_res_state():
        # error-feedback residuals are part of the resumable state: dropping
        # them on resume would re-inject the already-compensated error
        if hasattr(engine, "comm_residual_state"):
            return engine.comm_residual_state()
        return None

    def make_like(host: dict) -> dict:
        # reject a foreign checkpoint BEFORE any array I/O: a different
        # seed/partitioning would otherwise surface as a shape mismatch
        fp = host.get("fingerprint", {})
        if fp != fingerprint:
            raise ValueError(
                f"checkpoint fingerprint {fp} does not match this run "
                f"{fingerprint} — refusing to resume")
        # the arrays template is phase-dependent: personal params exist
        # only once the phase-1 loop has run at least one epoch
        like = {"params": params, "opt": opt_state, "best_global": params}
        if host.get("has_phase1"):
            pp = broadcast_to_partitions(params, n_parts)
            like.update(global_params=params, pparams=pp,
                        popt=jax.vmap(opt.init)(pp), best_personal=pp)
        st = halo_ckpt_state()
        if st is not None:
            like["halo"] = st[0]
        if host.get("has_halo_res"):
            like["halo_res"] = engine._halo_residual
        if host.get("has_grad_res"):
            like["grad_res"] = engine._grad_residual(params)
        return like

    restore_phase1 = None
    resumed_from = -1
    if ckpt is not None and cfg.resume:
        loaded = ckpt.load_latest(make_like)
        if loaded is not None:
            arrays, host, resumed_from = loaded
            params, opt_state = arrays["params"], arrays["opt"]
            best_global = arrays["best_global"]
            ctrl.load_state_dict(host["controller"])
            rng_snapshot = host["rng"]
            restore_rng(rng_snapshot)
            loss_hist = [float(x) for x in host["loss_hist"]]
            val_hist = [float(x) for x in host["val_hist"]]
            sim_time = float(host["sim_time"])
            epoch_times = [float(x) for x in host["epoch_times"]]
            epoch_times_with_eval = [float(x)
                                     for x in host["epoch_times_with_eval"]]
            comm_grad, comm_halo_p0, comm_halo_p1 = (
                int(x) for x in host["comm"])
            halo_exchange_hist = [int(x) for x in host["halo_exchange_hist"]]
            p0_iter_hist = [int(x) for x in host["p0_iter_hist"]]
            host_to_device_p0 = int(host["host_to_device_p0"])
            host_to_device_p1 = int(host.get("host_to_device_p1", 0))
            straggler_total = float(host.get("straggler_s", 0.0))
            if "halo" in arrays:
                engine.restore_halo_cache_state(arrays["halo"],
                                                host["halo_age"])
            if "halo_res" in arrays or "grad_res" in arrays:
                engine.restore_comm_residual_state(
                    (arrays.get("halo_res"), arrays.get("grad_res")))
            if host.get("has_phase1"):
                restore_phase1 = (arrays, host)
            if verbose:
                print(f"[resume] epoch {resumed_from} phase {ctrl.phase} "
                      f"from {cfg.checkpoint_dir}")

    phase1_state: dict = {}   # live phase-1 state, for checkpoint capture

    def save_checkpoint() -> None:
        arrays = {"params": params, "opt": opt_state,
                  "best_global": best_global}
        host = {
            "has_phase1": bool(phase1_state),
            "controller": ctrl.state_dict(),
            "rng": rng_snapshot,
            "loss_hist": loss_hist, "val_hist": val_hist,
            "sim_time": sim_time,
            "epoch_times": epoch_times,
            "epoch_times_with_eval": epoch_times_with_eval,
            "comm": [int(comm_grad), int(comm_halo_p0), int(comm_halo_p1)],
            "halo_exchange_hist": [int(x) for x in halo_exchange_hist],
            "p0_iter_hist": [int(x) for x in p0_iter_hist],
            "host_to_device_p0": int(host_to_device_p0),
            "host_to_device_p1": int(host_to_device_p1),
            "straggler_s": straggler_total,
            "fingerprint": fingerprint,
        }
        st = halo_ckpt_state()
        if st is not None:
            arrays["halo"] = jax.tree.map(np.asarray, st[0])
            host["halo_age"] = int(st[1])
        cs = comm_res_state()
        if cs is not None:
            h_res, g_res = cs
            if h_res is not None:
                arrays["halo_res"] = jax.tree.map(np.asarray, h_res)
            if g_res is not None:
                arrays["grad_res"] = np.asarray(g_res)
            host["has_halo_res"] = h_res is not None
            host["has_grad_res"] = g_res is not None
        if phase1_state:
            arrays.update(
                global_params=phase1_state["global_params"],
                pparams=phase1_state["pparams"],
                popt=phase1_state["popt"],
                best_personal=stack_pytrees(phase1_state["best_personal"]))
            host["host_elapsed"] = [float(x)
                                    for x in phase1_state["host_elapsed"]]
            host["phase1_epochs"] = int(phase1_state["phase1_epochs"])
        ckpt.save(ctrl.epoch, arrays, host)

    def epoch_boundary() -> None:
        """End of one epoch (ctrl already advanced): persist the boundary,
        then let any injected crash fire AFTER the state is durable — the
        only crash point an epoch-granular checkpointer can replay."""
        if ckpt is not None and ctrl.epoch % max(1, cfg.checkpoint_every) == 0:
            save_checkpoint()
        if fault_plan is not None and fault_plan.crash_at(ctrl.epoch):
            raise InjectedCrash(ctrl.epoch)

    def epoch_faults() -> np.ndarray | None:
        """Start of one epoch (index ctrl.epoch): arm the dropped-refresh
        fault, return this epoch's straggler delays (None = none)."""
        if fault_plan is None:
            return None
        if (cfg.halo_cache and fault_plan.drop_halo_refresh(ctrl.epoch)
                and hasattr(engine, "drop_next_halo_refresh")):
            engine.drop_next_halo_refresh()
        d = fault_plan.straggler_delay(ctrl.epoch, n_parts)
        return d if d.any() else None

    draws_at_p0_start = host_draw_count()
    # the no-GP early stop lives in the loop CONDITION (not a body break) so
    # a run resumed from its stopping boundary also exits before training
    while (not ctrl.done and ctrl.phase == 0
           and not (not cfg.use_gp and ctrl.phase0_stopper.stopped)):
        delay = epoch_faults()
        if cfg.full_graph_train:
            params, opt_state, losses, val_micro, t_dev = (
                engine.phase0_fullgraph_epoch(params, opt_state,
                                              iters=cfg.full_graph_iters))
            iters = np.asarray(losses).shape[0]
            t_host = np.zeros(n_parts)      # no host sampling on this path
            comm_halo_p0 += fg_halo_bytes_per_epoch
            halo_exchange_hist.append(eval_exchange_bytes())
        elif async_phase0:
            # one device program per epoch: draw + train scan + fused eval.
            # The only host→device payload is the per-partition PRNG keys.
            keys = jax.vmap(jax.random.fold_in, (0, None))(
                p0_base_keys, ctrl.epoch)
            params, opt_state, losses, val_micro, t_dev = (
                engine.phase0_epoch_async(params, opt_state, keys))
            iters = np.asarray(losses).shape[0]
            t_host = np.zeros(n_parts)      # no host sampling on this path
            host_to_device_p0 += np.asarray(keys).nbytes
            ex = eval_exchange_bytes()
            halo_exchange_hist.append(ex)
            comm_halo_p0 += ex + fetch_bytes_per_epoch
        else:
            batches, t_host, iters = next_epoch_batches()
            host_to_device_p0 += sum(
                l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(batches))
            params, opt_state, losses, val_micro, t_dev = engine.phase0_epoch(
                params, opt_state, batches)
            ex = eval_exchange_bytes()
            halo_exchange_hist.append(ex)
            comm_halo_p0 += ex + fetch_bytes_per_epoch
        host_to_device_p0 += cold_delta()
        comm_grad += grad_bytes_per_sync * iters
        p0_iter_hist.append(int(iters))
        host_time = epoch_host_times(t_host, t_dev)
        if delay is not None:
            # injected straggler: the synchronous epoch waits for it
            host_time = host_time + delay
            straggler_total += float(delay.max())
        sim_time += float(host_time.max())
        epoch_times.append(float(host_time.max()))
        # eval-inclusive epoch period: a separately-compiled eval (host and
        # full-graph paths) adds its 1/N share; the fused async epoch's
        # t_dev already contains it (last_eval_seconds is 0 there)
        epoch_times_with_eval.append(
            float(host_time.max())
            + getattr(engine, "last_eval_seconds", 0.0) / n_parts)

        mean_loss = float(np.asarray(losses).mean())
        mean_val = float(np.asarray(val_micro).mean())
        loss_hist.append(mean_loss)
        val_hist.append(mean_val)
        if ctrl.record_phase0(mean_loss, mean_val):
            best_global = params
        if verbose:
            print(f"[phase-0] epoch {ctrl.epoch:3d} loss {mean_loss:.4f} "
                  f"val-micro {mean_val*100:.2f}")
        if cfg.use_gp and ctrl.should_personalize():
            ctrl.start_personalization()
        epoch_boundary()

    if prefetch is not None:
        prefetch.settle()       # quiesce the worker: race-free snapshot
    # sync note: with the prefetcher the tally includes the speculative
    # next-epoch draw that overlapped the last phase-0 device step
    host_draws_p0 = host_draw_count() - draws_at_p0_start

    personalize_start = ctrl.personalize_start_epoch

    # ---------------- phase 1: personalization ----------------------------
    phase1_time = 0.0
    phase1_epochs = 0
    host_draws_p1 = 0
    if cfg.use_gp and not cfg.centralized:
        if restore_phase1 is not None:
            # resumed mid-personalization: restore the phase-1 state the
            # checkpoint carried instead of re-deriving it from best_global
            arrays, rhost = restore_phase1
            global_params = arrays["global_params"]
            pparams, popt = arrays["pparams"], arrays["popt"]
            best_personal = [
                jax.tree.map(lambda x, p=p: x[p], arrays["best_personal"])
                for p in range(n_parts)]
            host_elapsed = np.asarray(rhost["host_elapsed"], float)
            phase1_epochs = int(rhost["phase1_epochs"])
        else:
            global_params = best_global
            pparams = broadcast_to_partitions(global_params, n_parts)
            popt = jax.vmap(opt.init)(pparams)
            best_personal = [jax.tree.map(lambda x: x[p], pparams)
                             for p in range(n_parts)]
            host_elapsed = np.zeros(n_parts)

        if cfg.async_personalize:
            # from here on the mini-epoch path is one device program: join
            # and discard any in-flight host draw, then attach the device
            # sampler staged before phase-0 (ONE sampler serves both phases;
            # already attached when phase-0 ran async)
            if prefetch is not None:
                prefetch.close()
            if not async_phase0:
                engine.set_device_sampler(stage_device_sampler())
            base_keys = jax.random.split(
                jax.random.PRNGKey(cfg.seed ^ 0xCB5D), n_parts)
        elif prefetch is not None:
            prefetch.settle()       # quiesce the worker: race-free snapshot
        # sync note: the count includes the final speculative (discarded)
        # prefetch epoch — those draws still run on the host during phase-1
        draws_at_p1_start = host_draw_count()

        while not ctrl.done:
            active_np = ctrl.active_partitions
            delay = epoch_faults()
            if delay is not None:
                host_elapsed += np.where(active_np, delay, 0.0)
                straggler_total += float(delay.max())
            if cfg.async_personalize:
                budgets = ctrl.phase1_budgets(dev_sampler.natural_iters)
                keys = jax.vmap(jax.random.fold_in, (0, None))(
                    base_keys, ctrl.epoch)
                pparams, popt, losses, val_micro, t_dev = (
                    engine.phase1_epoch_async(pparams, popt, keys,
                                              jnp.asarray(budgets),
                                              global_params))
                # each host pays for its own budgeted share of the fused
                # step; converged hosts (budget 0) pay nothing
                host_elapsed += t_dev * budgets / max(1, int(budgets.sum()))
            else:
                batches, t_host, iters = next_epoch_batches()
                budgets = ctrl.phase1_budgets(iters)
                pparams, popt, losses, val_micro, t_dev = engine.phase1_epoch(
                    pparams, popt, batches, global_params,
                    jnp.asarray(budgets))
                host_elapsed += np.where(
                    active_np, epoch_host_times(t_host, t_dev), 0.0)
            ex = eval_exchange_bytes()
            halo_exchange_hist.append(ex)
            comm_halo_p1 += ex + fetch_bytes_per_epoch
            host_to_device_p1 += cold_delta()
            scores = np.asarray(val_micro)
            is_best = ctrl.record_phase1(scores)
            phase1_epochs += 1
            for p in np.flatnonzero(is_best):
                best_personal[p] = jax.tree.map(lambda x: x[p], pparams)
            loss_hist.append(float(np.asarray(losses)[-1].mean()))
            val_hist.append(float(scores.mean()))
            if verbose:
                print(f"[phase-1] epoch {ctrl.epoch:3d} "
                      f"val-micro {scores.mean()*100:.2f} "
                      f"active {int(active_np.sum())}/{n_parts} "
                      f"budgets {np.asarray(budgets).tolist()}")
            phase1_state.update(
                global_params=global_params, pparams=pparams, popt=popt,
                best_personal=best_personal, host_elapsed=host_elapsed,
                phase1_epochs=phase1_epochs)
            epoch_boundary()
        # async phase: distributed time = slowest host's own cumulative time
        if prefetch is not None:
            prefetch.close()        # settle in-flight draws before counting
        host_draws_p1 = host_draw_count() - draws_at_p1_start
        phase1_time = float(host_elapsed.max())
        sim_time += phase1_time
        final_stacked = stack_pytrees(best_personal)
    else:
        final_stacked = broadcast_to_partitions(best_global, n_parts)
        if prefetch is not None:
            prefetch.close()

    # ---------------- final evaluation -------------------------------------
    _, preds = engine.evaluate(final_stacked, "test",
                               per_partition_params=True)
    host_to_device_p1 += cold_delta()    # the test eval's cold staging
    preds = np.asarray(preds)
    test_mask = np.asarray(pg.test_mask)
    labels = np.asarray(pg.labels)
    all_preds, all_labels, per_micro = [], [], np.zeros(n_parts)
    for p in range(n_parts):
        m = test_mask[p]
        pred, lab = preds[p][m], labels[p][m]
        all_preds.append(pred)
        all_labels.append(lab)
        per_micro[p] = f1_scores(pred, lab, graph.num_classes).micro
    f1 = f1_scores(np.concatenate(all_preds), np.concatenate(all_labels),
                   graph.num_classes)

    return EATResult(
        config=cfg, f1=f1, per_partition_micro=per_micro,
        partition_entropies=ents, partition_time_s=p_time, weight_time_s=w_time,
        train_time_s=sim_time,
        epoch_time_s=float(np.mean(epoch_times)) if epoch_times else 0.0,
        epoch_time_with_eval_s=(float(np.mean(epoch_times_with_eval))
                                if epoch_times_with_eval else 0.0),
        epochs_run=ctrl.epoch, personalize_start_epoch=personalize_start,
        loss_history=loss_hist, val_history=val_hist,
        comm_grad_bytes=comm_grad,
        comm_halo_bytes=comm_halo_p0 + comm_halo_p1,
        comm_halo_bytes_phase0=comm_halo_p0,
        comm_halo_bytes_phase1=comm_halo_p1,
        halo_bytes_per_layer=pg.halo_bytes_per_layer,
        comm_halo_exchange_bytes=sum(halo_exchange_hist),
        halo_exchange_history=halo_exchange_hist,
        engine_mode=engine.mode,
        phase1_time_s=phase1_time, phase1_epochs=phase1_epochs,
        host_draws_phase1=host_draws_p1,
        host_draws_phase0=host_draws_p0,
        phase0_iter_history=p0_iter_hist,
        host_to_device_bytes_phase0=host_to_device_p0,
        host_to_device_bytes_phase1=host_to_device_p1,
        resident_feature_bytes=int(getattr(engine,
                                           "resident_feature_bytes", 0)),
        cold_h2d_bytes=int(getattr(engine, "cold_h2d_bytes", 0)),
        final_params=final_stacked,
        resumed_from_epoch=resumed_from,
        straggler_delay_s=straggler_total,
    )
