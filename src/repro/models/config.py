"""Unified model configuration covering all ten assigned architectures.

A model is a stack of repeated *super-blocks*; each super-block is a list of
sub-layer descriptors (attention / mamba2 / mlp / moe).  Uniform models have a
one-layer super-block repeated L times; Jamba has an 8-sublayer super-block
(1 attention : 7 mamba, MoE on alternate sublayers) repeated 4 times.  This
keeps every architecture expressible as `lax.scan` over stacked params.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal, Sequence

__all__ = ["SubLayer", "ModelConfig"]

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
Mixer = Literal["attention", "mamba2"]
Ffn = Literal["mlp", "moe", "none"]


@dataclass(frozen=True)
class SubLayer:
    """One (mixer, ffn) pair inside a super-block."""

    mixer: Mixer = "attention"
    ffn: Ffn = "mlp"
    cross_attention: bool = False   # whisper decoder: cross-attn after self


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    citation: str

    # dimensions
    d_model: int
    vocab_size: int
    num_heads: int = 0            # query heads (0 for attention-free)
    num_kv_heads: int = 0
    head_dim: int = 0             # 0 -> d_model // num_heads
    d_ff: int = 0                 # dense MLP hidden (per expert for MoE)

    # block structure
    super_block: tuple[SubLayer, ...] = (SubLayer(),)
    num_repeats: int = 1          # super-block repeats; layers = repeats*len(sb)

    # attention details
    qkv_bias: bool = False
    rope_theta: float | None = 10_000.0   # None -> sinusoidal absolute pos
    sliding_window: int | None = None     # native SWA (starcoder2)
    attn_logit_softcap: float | None = None

    # norm / activation
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    activation: Literal["swiglu", "gelu"] = "swiglu"

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # Mamba2 / SSD
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # encoder (whisper) / multimodal prefix (paligemma)
    encoder_layers: int = 0
    encoder_seq: int = 0          # e.g. 1500 audio frames
    prefix_tokens: int = 0        # e.g. 256 image patches (prefix-LM mask)

    # training details
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True            # activation checkpointing over super-blocks
    max_position: int = 1 << 20
    # measurement mode: fully unroll every scan so XLA cost_analysis counts
    # true FLOPs (while bodies are otherwise counted once, not × trip count);
    # used by the dry-run's R=1/R=2 extrapolation compiles, never for runtime
    scan_unroll: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def num_layers(self) -> int:
        return self.num_repeats * len(self.super_block)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """True if some sub-quadratic path exists natively (SSM/hybrid/SWA)."""
        if any(sl.mixer == "mamba2" for sl in self.super_block):
            return True
        return self.sliding_window is not None

    def reduced(self, *, d_model: int = 256, repeats: int | None = None,
                experts: int = 4, d_ff: int | None = None,
                vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant: <=2 effective layers, small dims, <=4 experts."""
        scale = d_model / self.d_model
        nh = max(1, min(self.num_heads, 4))
        nkv = max(1, min(self.num_kv_heads, nh)) if self.num_kv_heads else 0
        if nkv:
            nh = (nh // nkv) * nkv or nkv
        return replace(
            self,
            d_model=d_model,
            vocab_size=vocab,
            num_heads=nh if self.num_heads else 0,
            num_kv_heads=nkv,
            head_dim=(d_model // nh) if self.num_heads else 0,
            d_ff=d_ff if d_ff is not None else max(64, int(self.d_ff * scale)) if self.d_ff else 0,
            num_repeats=repeats if repeats is not None else (2 if len(self.super_block) == 1 else 1),
            num_experts=min(self.num_experts, experts) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=32 if self.ssm_state else self.ssm_chunk,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 64) if self.encoder_seq else 0,
            prefix_tokens=min(self.prefix_tokens, 16) if self.prefix_tokens else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            remat=False,
            dtype="float32",
        )
