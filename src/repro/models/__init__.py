from .config import ModelConfig, SubLayer
from .sharding import NO_SHARDING, ShardingPolicy
from .transformer import Transformer, chunked_ce_loss

__all__ = ["ModelConfig", "SubLayer", "ShardingPolicy", "NO_SHARDING",
           "Transformer", "chunked_ce_loss"]
