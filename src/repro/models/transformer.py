"""Architecture assembly: super-block scan, caches, train/prefill/decode.

One `Transformer` class covers all ten assigned architectures:
  · layers are grouped into repeated super-blocks whose parameters are
    STACKED along a leading repeat axis and driven by `lax.scan` — 94-layer
    models lower to a single block HLO (compile-time sanity);
  · optional activation checkpointing (`jax.checkpoint`) around the scan body;
  · decode carries a per-sublayer cache pytree with the same stacked layout;
  · encoder–decoder (whisper) and prefix-LM (paligemma) wrap the same core.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .config import ModelConfig, SubLayer
from .sharding import NO_SHARDING, ShardingPolicy

__all__ = ["Transformer", "chunked_ce_loss"]

Params = dict[str, Any]


def chunked_ce_loss(h: jnp.ndarray, w_head: jnp.ndarray, labels: jnp.ndarray,
                    chunk: int = 4096, unroll: bool = False) -> jnp.ndarray:
    """Cross-entropy over vocab without materialising (T, V) logits.

    Scans over token chunks; each chunk's logits (chunk, V) live only inside
    one scan iteration (V can be 257k — the full logits would be GBs), and
    the body is REMATTED so the backward pass recomputes each chunk's logits
    instead of stashing them (without this the saved logp of every chunk
    costs ~40 GB/chip at train_4k).  Labels < 0 are masked out.
    """
    t, d = h.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)
    hc = h.reshape(-1, chunk, d)
    lc = labels.reshape(-1, chunk)

    @jax.checkpoint
    def body(carry, inp):
        hx, lx = inp
        logits = hx.astype(jnp.float32) @ w_head.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        safe = jnp.maximum(lx, 0)
        nll = -jnp.take_along_axis(logp, safe[:, None], axis=1)[:, 0]
        wgt = (lx >= 0).astype(jnp.float32)
        return (carry[0] + (nll * wgt).sum(), carry[1] + wgt.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hc, lc),
                                 unroll=hc.shape[0] if unroll else 1)
    return tot / jnp.maximum(cnt, 1.0)


@dataclass(frozen=True)
class Transformer:
    cfg: ModelConfig
    policy: ShardingPolicy = NO_SHARDING

    # ================================================================ init
    def init(self, seed: int = 0) -> Params:
        cfg = self.cfg
        rng = L.KeyGen(seed)
        dt = jnp.dtype(cfg.dtype)
        d = cfg.d_model
        p: Params = {
            "embed": (0.02 * jax.random.normal(rng(), (cfg.vocab_size, d), jnp.float32)).astype(dt),
            "final_norm": L.norm_init(cfg),
            "blocks": self._init_blocks(rng),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = L._dense_init(rng, d, cfg.vocab_size, dt)
        if cfg.is_encoder_decoder:
            p["encoder"] = {
                "blocks": self._init_enc_blocks(rng),
                "final_norm": L.norm_init(cfg),
            }
        return p

    def _sublayer_init(self, sl: SubLayer, rng) -> Params:
        cfg = self.cfg
        sp: Params = {"norm_mix": L.norm_init(cfg)}
        if sl.mixer == "attention":
            sp["attn"] = L.attention_init(cfg, rng)
        else:
            sp["mamba"] = L.mamba2_init(cfg, rng)
        if sl.cross_attention:
            sp["norm_cross"] = L.norm_init(cfg)
            sp["cross"] = L.attention_init(cfg, rng, cross=True)
        if sl.ffn == "mlp":
            sp["norm_ffn"] = L.norm_init(cfg)
            sp["mlp"] = L.mlp_init(cfg, rng)
        elif sl.ffn == "moe":
            sp["norm_ffn"] = L.norm_init(cfg)
            sp["moe"] = L.moe_init(cfg, rng)
        return sp

    def _init_blocks(self, rng) -> Params:
        cfg = self.cfg
        per_repeat = []
        for _ in range(cfg.num_repeats):
            per_repeat.append({
                f"sub{i}": self._sublayer_init(sl, rng)
                for i, sl in enumerate(cfg.super_block)
            })
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_repeat)

    def _init_enc_blocks(self, rng) -> Params:
        cfg = self.cfg
        sl = SubLayer(mixer="attention", ffn="mlp")
        reps = [
            {"sub0": self._sublayer_init(sl, rng)} for _ in range(cfg.encoder_layers)
        ]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *reps)

    # ======================================================== block bodies
    def _run_sublayer(self, i: int, sl: SubLayer, sp: Params, x, *, mode: str,
                      cache=None, cache_len=None, enc_out=None, window=None,
                      rolling=False, prefix_len=0, cache_size=None):
        """Returns (x, new_cache, aux)."""
        cfg, policy = self.cfg, self.policy
        aux = jnp.zeros((), jnp.float32)
        new_cache: Params = {}
        h = L.norm_apply(sp["norm_mix"], x, cfg)
        if sl.mixer == "attention":
            if mode == "train":
                mix = L.attention_apply(sp["attn"], h, cfg, policy, causal=True,
                                        window=window, prefix_len=prefix_len)
                new_cache["attn"] = None
            elif mode == "prefill":
                mix, c = L.attention_prefill(sp["attn"], h, cfg, policy,
                                             window=window, prefix_len=prefix_len,
                                             cache_size=cache_size)
                new_cache["attn"] = c
            else:  # decode
                mix, c = L.attention_decode(sp["attn"], h, cache["attn"], cache_len,
                                            cfg, policy, window=window, rolling=rolling)
                new_cache["attn"] = c
        else:  # mamba2
            if mode in ("train", "prefill"):
                mix, c = L.mamba2_apply(sp["mamba"], h, cfg, policy)
                new_cache["mamba"] = c if mode == "prefill" else None
            else:
                mix, c = L.mamba2_decode(sp["mamba"], h, cache["mamba"], cfg)
                new_cache["mamba"] = c
        x = x + mix
        x = policy.residual(x) if policy.enabled else x

        if sl.cross_attention:
            h = L.norm_apply(sp["norm_cross"], x, cfg)
            if mode == "decode":
                cx, _ = L.attention_decode(sp["cross"], h, None, cache_len, cfg,
                                           policy, enc_cache=cache["cross"])
                new_cache["cross"] = cache["cross"]
            else:
                cx = L.attention_apply(sp["cross"], h, cfg, policy, causal=False,
                                       enc_out=enc_out)
                if mode == "prefill":
                    # stash encoder K/V for decode
                    kq = enc_out @ sp["cross"]["wk"] + sp["cross"].get("b_k", 0.0)
                    vq = enc_out @ sp["cross"]["wv"] + sp["cross"].get("b_v", 0.0)
                    b, se, _ = enc_out.shape
                    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
                    new_cache["cross"] = {
                        "k": kq.reshape(b, se, hkv, dh).transpose(0, 2, 1, 3),
                        "v": vq.reshape(b, se, hkv, dh).transpose(0, 2, 1, 3),
                    }
            x = x + cx
            x = policy.residual(x) if policy.enabled else x

        if sl.ffn != "none":
            h = L.norm_apply(sp["norm_ffn"], x, cfg)
            if sl.ffn == "moe":
                y, aux = L.moe_apply(sp["moe"], h, cfg, self.policy)
            else:
                y = L.mlp_apply(sp["mlp"], h, cfg)
            x = x + y
            x = policy.residual(x) if policy.enabled else x
        return x, new_cache, aux

    def _scan_blocks(self, blocks: Params, x, *, mode: str, caches=None,
                     cache_len=None, enc_out=None, rolling=False,
                     prefix_len=0, cache_size=None):
        cfg = self.cfg
        window = cfg.sliding_window

        def body(carry, xs):
            xc = carry
            blk_params = xs[0]
            blk_cache = xs[1] if caches is not None else None
            new_caches = {}
            aux_total = jnp.zeros((), jnp.float32)
            for i, sl in enumerate(cfg.super_block):
                sub_cache = None if blk_cache is None else blk_cache.get(f"sub{i}")
                xc, nc, aux = self._run_sublayer(
                    i, sl, blk_params[f"sub{i}"], xc, mode=mode, cache=sub_cache,
                    cache_len=cache_len, enc_out=enc_out, window=window,
                    rolling=rolling, prefix_len=prefix_len, cache_size=cache_size)
                new_caches[f"sub{i}"] = nc
                aux_total = aux_total + aux
            return xc, (new_caches, aux_total)

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body, prevent_cse=False)
        xs = (blocks,) if caches is None else (blocks, caches)
        x, (new_caches, auxes) = jax.lax.scan(
            body, x, xs, unroll=cfg.num_repeats if cfg.scan_unroll else 1)
        return x, new_caches, auxes.sum()

    # ============================================================= encoder
    def encode(self, params: Params, enc_embeds: jnp.ndarray) -> jnp.ndarray:
        """Whisper encoder over precomputed (stub) frame embeddings."""
        cfg = self.cfg
        se = enc_embeds.shape[1]
        x = enc_embeds + L.sinusoidal_positions(se, cfg.d_model)[None].astype(enc_embeds.dtype)

        def body(carry, blk):
            h = L.norm_apply(blk["sub0"]["norm_mix"], carry, cfg)
            mix = L.attention_apply(blk["sub0"]["attn"], h, cfg, self.policy,
                                    causal=False)
            xc = carry + mix
            h = L.norm_apply(blk["sub0"]["norm_ffn"], xc, cfg)
            xc = xc + L.mlp_apply(blk["sub0"]["mlp"], h, cfg)
            return xc, None

        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"],
                            unroll=cfg.encoder_layers if cfg.scan_unroll else 1)
        return L.norm_apply(params["encoder"]["final_norm"], x, cfg)

    # ============================================================== embed
    def _embed_tokens(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        x = params["embed"][tokens]
        if self.cfg.rope_theta is None and not self.cfg.is_encoder_decoder:
            x = x + L.sinusoidal_positions(tokens.shape[1], self.cfg.d_model)[None].astype(x.dtype)
        return x

    def _head(self, params: Params) -> jnp.ndarray:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    # ================================================================ train
    def train_loss(self, params: Params, batch: dict[str, jnp.ndarray]) -> jnp.ndarray:
        """batch: tokens (B,S), labels (B,S); optional patch_embeds /
        enc_embeds for vlm / audio.  Returns scalar loss."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        prefix_len = 0
        if cfg.prefix_tokens:
            x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
            prefix_len = cfg.prefix_tokens
        if cfg.is_encoder_decoder:
            x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
            enc_out = self.encode(params, batch["enc_embeds"])
        else:
            enc_out = None
        x = self.policy.residual(x) if self.policy.enabled else x
        x, _, aux = self._scan_blocks(params["blocks"], x, mode="train",
                                      enc_out=enc_out, prefix_len=prefix_len)
        x = L.norm_apply(params["final_norm"], x, cfg)

        labels = batch["labels"]
        if cfg.prefix_tokens:  # no loss on image prefix
            pads = jnp.full((labels.shape[0], cfg.prefix_tokens), -1, labels.dtype)
            labels = jnp.concatenate([pads, labels], axis=1)
        b, s, d = x.shape
        # measurement mode: one full-size chunk => the scan has a single
        # iteration, so cost_analysis counts the CE exactly with a tiny HLO
        chunk = b * s if cfg.scan_unroll else 4096
        loss = chunked_ce_loss(x.reshape(b * s, d), self._head(params),
                               labels.reshape(-1), chunk=chunk)
        return loss + aux

    # ============================================================== prefill
    def init_cache_len(self) -> jnp.ndarray:
        return jnp.zeros((), jnp.int32)

    def prefill(self, params: Params, batch: dict[str, jnp.ndarray], *,
                cache_size: int | None = None):
        """Run the full prompt; returns (last_logits, caches, cache_len)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        prefix_len = 0
        if cfg.prefix_tokens:
            x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
            prefix_len = cfg.prefix_tokens
        if cfg.is_encoder_decoder:
            x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
            enc_out = self.encode(params, batch["enc_embeds"])
        else:
            enc_out = None
        x, caches, _ = self._scan_blocks(
            params["blocks"], x, mode="prefill", enc_out=enc_out,
            prefix_len=prefix_len, cache_size=cache_size)
        x = L.norm_apply(params["final_norm"], x, cfg)
        logits = x[:, -1].astype(jnp.float32) @ self._head(params).astype(jnp.float32)
        cache_len = jnp.asarray(x.shape[1], jnp.int32)
        return logits, caches, cache_len

    # =============================================================== decode
    def decode_step(self, params: Params, token: jnp.ndarray, caches, cache_len,
                    *, rolling: bool = False, extra: dict | None = None):
        """One-token step.  token: (B, 1) int32.  Returns (logits, caches)."""
        cfg = self.cfg
        x = params["embed"][token]
        if cfg.rope_theta is None:
            # sinusoidal absolute position for the current index
            half = cfg.d_model // 2
            i = jnp.arange(half, dtype=jnp.float32)
            ang = cache_len.astype(jnp.float32) / jnp.power(10000.0, 2 * i / cfg.d_model)
            pos = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
            x = x + pos[None, None].astype(x.dtype)
        x, new_caches, _ = self._scan_blocks(
            params["blocks"], x, mode="decode", caches=caches,
            cache_len=cache_len, rolling=rolling)
        x = L.norm_apply(params["final_norm"], x, cfg)
        logits = x[:, -1].astype(jnp.float32) @ self._head(params).astype(jnp.float32)
        return logits, new_caches

    # ======================================================== cache structs
    def make_decode_cache(self, batch: int, cache_width: int,
                          enc_seq: int | None = None) -> Any:
        """Zero-initialised cache pytree matching _scan_blocks layout."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim

        def one_sub(sl: SubLayer) -> Params:
            c: Params = {}
            if sl.mixer == "attention":
                c["attn"] = {
                    "k": jnp.zeros((cfg.num_repeats, batch, hkv, cache_width, dh), dt),
                    "v": jnp.zeros((cfg.num_repeats, batch, hkv, cache_width, dh), dt),
                }
            else:
                conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_state
                c["mamba"] = {
                    "conv": jnp.zeros((cfg.num_repeats, batch, cfg.ssm_conv - 1, conv_dim), dt),
                    "ssm": jnp.zeros((cfg.num_repeats, batch, cfg.ssm_heads,
                                      cfg.ssm_state, cfg.ssm_headdim), jnp.float32),
                }
            if sl.cross_attention:
                se = enc_seq or cfg.encoder_seq
                c["cross"] = {
                    "k": jnp.zeros((cfg.num_repeats, batch, hkv, se, dh), dt),
                    "v": jnp.zeros((cfg.num_repeats, batch, hkv, se, dh), dt),
                }
            return c

        return {f"sub{i}": one_sub(sl) for i, sl in enumerate(cfg.super_block)}

    # ============================================================== params N
    def param_count(self, params: Params) -> int:
        return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
