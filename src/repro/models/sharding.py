"""Sharding policy: how the model zoo maps onto the production mesh.

Baseline scheme (recorded as such in EXPERIMENTS.md §Perf):
  · params: Megatron 2D — heads / ffn-hidden / experts / vocab over "model";
    everything batch-like over ("pod","data").
  · residual stream (B, S, d): batch over data axes, **sequence over
    "model"** between blocks (Megatron sequence parallelism) so the saved
    scan carry under remat is 1/|model| per chip — without it the 80–94
    layer archs cannot fit activations in 16 GB HBM.
  · attention/mlp internals: heads (resp. ffn hidden) over "model",
    sequence gathered. GSPMD inserts the all-gather / reduce-scatter pair.

`spec_for_param` assigns PartitionSpecs by parameter name + shape rules, so
every architecture in the zoo shares one sharding rulebook.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["ShardingPolicy", "NO_SHARDING"]


@dataclass(frozen=True)
class ShardingPolicy:
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str | None = "model"
    seq_shard_residual: bool = True
    constrain_attn: bool = True   # head-shard constraint on attention acts
    enabled: bool = True
    # mesh axis sizes: required for divisibility-aware activation constraints
    axis_sizes: Any = None   # dict[str, int] | None

    # ---- activation specs -------------------------------------------------
    def residual_spec(self) -> P:
        if self.seq_shard_residual and self.model_axis:
            return P(self.data_axes, self.model_axis, None)
        return P(self.data_axes, None, None)

    def attn_act_spec(self) -> P:
        # (B, H, S, Dh): heads over model
        return P(self.data_axes, self.model_axis, None, None)

    def batch_spec(self, ndim: int) -> P:
        return P(self.data_axes, *([None] * (ndim - 1)))

    def _sanitize(self, spec: P, shape: tuple[int, ...]) -> P:
        if self.axis_sizes is None:
            return spec
        parts = []
        for d in range(len(shape)):
            entry = spec[d] if d < len(spec) else None
            if entry is None:
                parts.append(None)
                continue
            axes = list(entry) if isinstance(entry, tuple) else [entry]
            while axes:
                total = 1
                for a in axes:
                    total *= self.axis_sizes[a]
                if shape[d] % total == 0:
                    break
                axes.pop()
            parts.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
        return P(*parts)

    def constrain(self, x, spec: P):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, self._sanitize(spec, x.shape))

    def residual(self, x):
        return self.constrain(x, self.residual_spec())

    # ---- parameter specs ---------------------------------------------------
    def spec_for_param(self, name: str, shape: tuple[int, ...]) -> P:
        """Name/shape rule-based parameter sharding.

        Leading stacked-layer axes (from scan) are never sharded; rules match
        on the trailing dims.  ``name`` is the flattened pytree path.
        """
        m = self.model_axis
        if not self.enabled or m is None:
            return P()
        n = name.lower()
        nd = len(shape)

        def last2(a, b):  # spec with trailing two dims (a, b), rest None
            return P(*([None] * (nd - 2)), a, b)

        def last1(a):
            return P(*([None] * (nd - 1)), a)

        if nd == 0:
            return P()
        if "embed" in n and nd >= 2:          # (V, d) token embedding
            return last2(m, None)
        if "lm_head" in n and nd >= 2:        # (d, V)
            return last2(None, m)
        if any(k in n for k in ("wq", "wk", "wv")) and nd >= 2:
            return last2(None, m)             # (d, H*Dh) -> heads sharded
        if "wo" in n and nd >= 2:
            return last2(m, None)             # (H*Dh, d)
        if any(k in n for k in ("w_gate", "w_up", "w_in")) and nd >= 2:
            return last2(None, m)             # (d, ff)
        if any(k in n for k in ("w_down", "w_out")) and nd >= 2:
            return last2(m, None)             # (ff, d)
        if "expert" in n and nd >= 3:
            # stacked experts (..., E, d, ff)/(..., E, ff, d): expert-parallel
            return P(*([None] * (nd - 3)), m, None, None)
        if "router" in n and nd >= 2:
            return P()                        # tiny, replicate
        if any(k in n for k in ("b_q", "b_k", "b_v")) and nd >= 1:
            return last1(m)
        if "in_proj" in n and nd >= 2:        # mamba2 (d, 2*di+2*G*N+H)
            return last2(None, m)
        if "out_proj" in n and nd >= 2:       # mamba2 (di, d)
            return last2(m, None)
        if any(k in n for k in ("conv", "a_log", "dt_bias", "d_skip", "ssm_norm")):
            # small per-channel params along d_inner -> model-sharded last dim
            return last1(m) if shape[-1] % 2 == 0 else P()
        return P()  # norms, biases, scalars: replicated

    def param_specs(self, params: Any) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        specs = []
        for path, leaf in flat:
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            specs.append(self.spec_for_param(name, leaf.shape))
        return jax.tree_util.tree_unflatten(treedef, specs)


NO_SHARDING = ShardingPolicy(enabled=False, model_axis=None, data_axes=())
