"""Shared layer implementations for the architecture zoo.

Everything is functional: ``*_init(cfg, rng) -> params`` (plain dicts of
jnp arrays) and ``*_apply(params, x, ...) -> y``.  Attention is implemented
as *statically* chunked online-softmax (flash-style in pure JAX) so that
32k prefill and 500k decode lower with bounded intermediate buffers and
without wasted FLOPs on causally-dead tiles — the Pallas flash_attention
kernel is the TPU runtime twin of this lowering-friendly form.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .sharding import NO_SHARDING, ShardingPolicy

__all__ = [
    "norm_init", "norm_apply", "apply_rope", "sinusoidal_positions",
    "chunked_attention", "rolling_window_attention",
    "attention_init", "attention_apply", "attention_prefill", "attention_decode",
    "mlp_init", "mlp_apply", "moe_init", "moe_apply",
    "mamba2_init", "mamba2_apply", "mamba2_decode",
]

Params = dict[str, Any]
DEFAULT_CHUNK_Q = 512
DEFAULT_CHUNK_K = 1024
NEG_INF = -1e30


class KeyGen:
    """Deterministic jax.random key stream.  Using jax (not numpy) randomness
    keeps ``jax.eval_shape(model.init)`` fully abstract — a 110B-param init
    costs zero bytes in the dry-run."""

    def __init__(self, seed: int):
        self._key = jax.random.key(seed)
        self._n = 0

    def __call__(self):
        self._n += 1
        return jax.random.fold_in(self._key, self._n)


def _uniform(kg: KeyGen, shape, scale, dtype):
    return jax.random.uniform(kg(), shape, jnp.float32, -scale, scale).astype(dtype)


def _dense_init(kg: KeyGen, d_in, d_out, dtype, shape=None):
    scale = math.sqrt(6.0 / (d_in + d_out))
    return _uniform(kg, shape or (d_in, d_out), scale, dtype)


# ---------------------------------------------------------------------------
# norms & positions
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: int | None = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        out = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps) * p["scale"]
    return out.astype(x.dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, H, S, Dh), positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freq[None, :]   # (S, half)
        ang = ang[None, None]
    else:
        ang = positions[:, None, :, None].astype(jnp.float32) * freq[None, None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


# ---------------------------------------------------------------------------
# chunked flash-style attention (pure JAX, static tile skipping)
# ---------------------------------------------------------------------------

def _mask_block(q_pos, k_pos, *, causal, window, prefix_len, kv_len):
    mask = k_pos < kv_len
    if causal:
        visible = k_pos <= q_pos
        if prefix_len:
            visible = jnp.logical_or(visible, k_pos < prefix_len)
        mask = jnp.logical_and(mask, visible)
    if window is not None:
        live = k_pos > q_pos - window
        if prefix_len:
            live = jnp.logical_or(live, k_pos < prefix_len)
        mask = jnp.logical_and(mask, live)
    return mask


def chunked_attention(
    q: jnp.ndarray,            # (B, Hq, Sq, Dh)
    k: jnp.ndarray,            # (B, Hkv, Sk, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int = 0,
    q_offset: int = 0,
    chunk_q: int = DEFAULT_CHUNK_Q,
    chunk_k: int = DEFAULT_CHUNK_K,
) -> jnp.ndarray:
    """Online-softmax attention over static (q-tile × k-tile) loops.

    Tiles that are entirely dead under the causal/window structure are
    skipped at TRACE time, so the lowered HLO carries no masked-out FLOPs —
    the compiled cost_analysis reflects the true sub-quadratic work.
    """
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)

    # adaptive tiles: bound the unrolled tile count (compile size) at ~16x16
    # while keeping each tile's logits block modest
    chunk_q = max(chunk_q, -(-sq // 16))
    chunk_k = max(chunk_k, -(-sk // 16))
    cq = min(chunk_q, sq)
    ck = min(chunk_k, sk)
    sq_pad = -(-sq // cq) * cq
    sk_pad = -(-sk // ck) * ck
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0)))

    qg = q.reshape(b, hkv, g, sq_pad, dh)
    out_chunks = []
    for qi in range(sq_pad // cq):
        q_lo = qi * cq + q_offset           # absolute start of this q tile
        q_hi = q_lo + cq - 1
        qc = qg[:, :, :, qi * cq : (qi + 1) * cq].astype(jnp.float32)
        m = jnp.full((b, hkv, g, cq, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hkv, g, cq, 1), jnp.float32)
        acc = jnp.zeros((b, hkv, g, cq, dh), jnp.float32)
        for ki in range(sk_pad // ck):
            k_lo, k_hi = ki * ck, ki * ck + ck - 1
            # static structural skips (trace-time): drop a tile only when it
            # is ENTIRELY dead — i.e. no column is rescued by the prefix
            in_prefix = k_lo < prefix_len
            if causal and k_lo > q_hi and not in_prefix:
                continue           # fully in the future
            if window is not None and k_hi <= q_lo - window and not in_prefix:
                continue           # fully beyond the sliding window
            kc = k[:, :, k_lo : k_lo + ck].astype(jnp.float32)
            vc = v[:, :, k_lo : k_lo + ck].astype(jnp.float32)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc) * scale
            q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
            k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
            mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                               prefix_len=prefix_len, kv_len=sk)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            p = jnp.where(mask[None, None, None], p, 0.0)
            l = l * alpha + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vc)
            m = m_new
        out_chunks.append(acc / jnp.where(l == 0.0, 1.0, l))
    out = jnp.concatenate(out_chunks, axis=3)[:, :, :, :sq]
    return out.reshape(b, hq, sq, dh).astype(q.dtype)


def rolling_window_attention(
    q: jnp.ndarray,            # (B, Hq, 1, Dh) single decode token
    k_cache: jnp.ndarray,      # (B, Hkv, W, Dh) mod-W rolling cache
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,    # scalar: tokens written so far incl. current
    window: int,
) -> jnp.ndarray:
    """Decode attention over a mod-W rolling KV cache without rolling copies.

    Slot j holds absolute position p_j = (len-1) - ((len-1 - j) mod W);
    validity is p_j >= 0, causality/window are then automatic.
    """
    b, hq, _, dh = q.shape
    hkv, w = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    last = cache_len - 1
    j = jnp.arange(w)
    p_j = last - jnp.mod(last - j, w)
    valid = p_j >= 0
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, hkv, g, 1, dh).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_cache.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention sub-layer
# ---------------------------------------------------------------------------

def attention_init(cfg: ModelConfig, rng: "KeyGen", *,
                   cross: bool = False) -> Params:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    p: Params = {
        "wq": _dense_init(rng, d, h * dh, dt),
        "wk": _dense_init(rng, d, hkv * dh, dt),
        "wv": _dense_init(rng, d, hkv * dh, dt),
        "wo": _dense_init(rng, h * dh, d, dt),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h * dh,), dt)
        p["b_k"] = jnp.zeros((hkv * dh,), dt)
        p["b_v"] = jnp.zeros((hkv * dh,), dt)
    return p


def _project_qkv(p: Params, x: jnp.ndarray, cfg: ModelConfig, kv_input=None):
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    kv_x = x if kv_input is None else kv_input
    skv = kv_x.shape[1]
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, skv, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, skv, hkv, dh).transpose(0, 2, 1, 3)
    return q, k, v


def attention_apply(
    p: Params, x: jnp.ndarray, cfg: ModelConfig,
    policy: ShardingPolicy = NO_SHARDING, *,
    causal: bool = True, window: int | None = None, prefix_len: int = 0,
    positions: jnp.ndarray | None = None, enc_out: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Full-sequence attention (training / encoder / cross)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, kv_input=enc_out)
    if cfg.rope_theta is not None and enc_out is None:
        pos = positions if positions is not None else jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if policy.enabled and getattr(policy, "constrain_attn", True):
        q = policy.constrain(q, policy.attn_act_spec())
    out = chunked_attention(q, k, v, causal=causal and enc_out is None,
                            window=window, prefix_len=prefix_len)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ p["wo"]


def attention_prefill(
    p: Params, x: jnp.ndarray, cfg: ModelConfig,
    policy: ShardingPolicy = NO_SHARDING, *,
    window: int | None = None, prefix_len: int = 0, cache_size: int | None = None,
):
    """Prefill: run attention AND return the populated KV cache.

    With a rolling (windowed) cache, only the last ``cache_size`` keys are
    retained, stored mod-W so decode can continue seamlessly.
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if cfg.rope_theta is not None:
        pos = jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=True, window=window, prefix_len=prefix_len)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)

    if cache_size is not None and cache_size < s:
        w = cache_size
        # place key at position p into slot p % w: for the final window the
        # slots are a permutation of the last w positions
        last = s - 1
        j = jnp.arange(w)
        src = last - jnp.mod(last - j, w)          # position living in slot j
        k_c, v_c = k[:, :, src], v[:, :, src]
    else:
        size = cache_size or s
        pad = size - s
        k_c = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return out @ p["wo"], {"k": k_c, "v": v_c}


def attention_decode(
    p: Params, x: jnp.ndarray, cache: Params, cache_len: jnp.ndarray,
    cfg: ModelConfig, policy: ShardingPolicy = NO_SHARDING, *,
    window: int | None = None, rolling: bool = False,
    enc_cache: Params | None = None,
):
    """One-token decode.  ``cache_len`` = tokens already in the cache.

    ``rolling=True`` uses the mod-W rolling buffer (W = cache width);
    otherwise writes at absolute position ``cache_len``.  ``enc_cache``
    switches to cross-attention against precomputed encoder K/V.
    """
    b = x.shape[0]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    if enc_cache is not None:
        q = (x @ p["wq"] + (p.get("b_q", 0.0))).reshape(b, 1, h, dh).transpose(0, 2, 1, 3)
        out = chunked_attention(q, enc_cache["k"], enc_cache["v"], causal=False)
        out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        return out @ p["wo"], cache

    q, k_new, v_new = _project_qkv(p, x, cfg)
    if cfg.rope_theta is not None:
        pos = jnp.full((1,), 0, jnp.int32) + cache_len
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)

    width = cache["k"].shape[2]
    slot = jnp.mod(cache_len, width) if rolling else cache_len
    k_c = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                       (0, 0, slot, 0))
    v_c = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                       (0, 0, slot, 0))
    if rolling:
        out = rolling_window_attention(q, k_c, v_c, cache_len + 1, width)
    else:
        kv_len_mask_len = width  # masked via positions below
        j = jnp.arange(width)
        valid = j <= cache_len
        if window is not None:
            valid = jnp.logical_and(valid, j > cache_len - window)
        g = h // hkv
        qg = q.reshape(b, hkv, g, 1, dh).astype(jnp.float32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_c.astype(jnp.float32))
        s = s / math.sqrt(dh)
        s = jnp.where(valid[None, None, None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", pr, v_c.astype(jnp.float32))
        out = out.reshape(b, h, 1, dh).astype(x.dtype)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return out @ p["wo"], {"k": k_c, "v": v_c}


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, rng: "KeyGen", d_ff: int | None = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    if cfg.activation == "swiglu":
        return {
            "w_gate": _dense_init(rng, d, ff, dt),
            "w_up": _dense_init(rng, d, ff, dt),
            "w_down": _dense_init(rng, ff, d, dt),
        }
    return {
        "w_in": _dense_init(rng, d, ff, dt),
        "b_in": jnp.zeros((ff,), dt),
        "w_out": _dense_init(rng, ff, d, dt),
        "b_out": jnp.zeros((d,), dt),
    }


def mlp_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.activation == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_in"] + p["b_in"]) @ p["w_out"] + p["b_out"]


# ---------------------------------------------------------------------------
# Mixture of Experts (scatter-based capacity dispatch, expert-parallel)
# ---------------------------------------------------------------------------

def moe_init(cfg: ModelConfig, rng: "KeyGen") -> Params:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.dtype)
    return {
        "router": _dense_init(rng, d, e, jnp.float32),
        "expert_gate": _dense_init(rng, d, ff, dt, shape=(e, d, ff)),
        "expert_up": _dense_init(rng, d, ff, dt, shape=(e, d, ff)),
        "expert_down": _dense_init(rng, ff, d, dt, shape=(e, ff, d)),
    }


def moe_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              policy: ShardingPolicy = NO_SHARDING):
    """Top-k routing with capacity-bounded scatter dispatch.

    Returns (y, aux_loss).  Dispatch avoids the (T, E, C) one-hot combine
    tensor of GShard: slots come from a cumsum over the (T·K, E) assignment
    matrix and tokens are scatter-added into the (E, C, d) buffer — the
    standard TPU-friendly formulation (experts shard over "model").
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                  # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    capacity = max(8, int(math.ceil(t * k * cfg.capacity_factor / e)))
    flat_e = top_i.reshape(-1)                              # (T*K,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)     # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = slot < capacity
    slot = jnp.where(keep, slot, 0)

    tok = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    buf = buf.at[flat_e, slot].add(
        xf[tok] * keep[:, None].astype(x.dtype), mode="drop",
    )

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["expert_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["expert_up"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["expert_down"])

    y_tok = out_buf[flat_e, slot] * keep[:, None].astype(x.dtype)   # (T*K, d)
    y = (y_tok.reshape(t, k, d) * top_w[..., None].astype(x.dtype)).sum(axis=1)

    # Switch-style load-balance auxiliary
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32), axis=0
    )
    frac_probs = probs.mean(axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_weight
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state-space duality, arXiv:2405.21060)
# ---------------------------------------------------------------------------

def mamba2_init(cfg: ModelConfig, rng: "KeyGen") -> Params:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = di + 2 * n
    dt = jnp.dtype(cfg.dtype)
    d_in_proj = 2 * di + 2 * n + h                     # z, x, B, C, dt
    return {
        "in_proj": _dense_init(rng, d, d_in_proj, dt),
        "conv_w": _uniform(rng, (cfg.ssm_conv, conv_dim), 1.0 / math.sqrt(cfg.ssm_conv), dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jax.random.uniform(rng(), (h,), jnp.float32, 1.0, 16.0)),
        "dt_bias": jnp.log(jnp.expm1(jax.random.uniform(rng(), (h,), jnp.float32, 1e-3, 0.1))),
        "d_skip": jnp.ones((h,), jnp.float32),
        "ssm_norm": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(rng, di, d, dt),
    }


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                           state: jnp.ndarray | None = None):
    """x: (B, S, C); w: (K, C).  Returns (y, new_state) with state = last K-1
    inputs (for decode continuation)."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return y + b, xp[:, -(k - 1) :, :] if k > 1 else None


def _ssd_chunked(xh, dt, a, bmat, cmat, chunk, init_state=None, unroll=False):
    """SSD chunked scan.  xh: (B,S,H,P), dt: (B,S,H), a: (H,),
    bmat/cmat: (B,S,N).  Returns (y: (B,S,H,P), final_state: (B,H,N,P)).

    One `lax.scan` over chunks carrying the (B,H,N,P) state; per-chunk
    buffers (the L×L decay matrix included) never exceed one chunk — this is
    what lets a 500k-token sequence lower with bounded memory.
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    L = chunk
    nc = s // L
    assert s % L == 0, f"seq {s} not divisible by ssd chunk {L}"
    # scan-major layout: (nc, b, L, ...)
    xc = xh.reshape(b, nc, L, h, p).swapaxes(0, 1)
    dtc = dt.reshape(b, nc, L, h).swapaxes(0, 1)
    bc = bmat.reshape(b, nc, L, n).swapaxes(0, 1)
    cc = cmat.reshape(b, nc, L, n).swapaxes(0, 1)
    tri = jnp.tril(jnp.ones((L, L), bool))

    s0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(prev, inp):
        xk, dtk, bk, ck = inp                      # (b, L, ...)
        da = dtk * a[None, None, :]                # (b, L, h)
        da_cum = jnp.cumsum(da, axis=1)
        da_sum = da_cum[:, -1]                     # (b, h)
        # intra-chunk (quadratic, attention-like).  The upper triangle is
        # masked out, but its raw diff is POSITIVE and can overflow exp() to
        # inf; a single where(mask, exp(diff), 0) then yields 0*inf = NaN in
        # the backward pass.  Double-where: zero diff first so the unselected
        # branch stays finite for autodiff.
        diff = da_cum[:, :, None, :] - da_cum[:, None, :, :]     # (b, i, j, h)
        lmask = tri[None, :, :, None]
        lmat = jnp.where(lmask, jnp.exp(jnp.where(lmask, diff, 0.0)), 0.0)
        scores = jnp.einsum("bin,bjn->bij", ck, bk)
        y_diag = jnp.einsum("bij,bijh,bjh,bjhp->bihp", scores, lmat, dtk, xk)
        # contribution of the carried state
        y_off = jnp.einsum("bin,bih,bhnp->bihp", ck, jnp.exp(da_cum), prev)
        # chunk-final state
        decay_states = jnp.exp(da_sum[:, None, :] - da_cum)      # (b, L, h)
        states = jnp.einsum("bjh,bjh,bjn,bjhp->bhnp", decay_states, dtk, bk, xk)
        new = jnp.exp(da_sum)[:, :, None, None] * prev + states
        return new, y_diag + y_off

    final_state, ys = jax.lax.scan(step, s0, (xc, dtc, bc, cc),
                                   unroll=nc if unroll else 1)
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    return y, final_state


def _mamba2_split(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * n]
    dt_raw = zxbcdt[..., di + di + 2 * n :]
    return z, xbc, dt_raw


def mamba2_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                 policy: ShardingPolicy = NO_SHARDING,
                 state: Params | None = None):
    """Full-sequence SSD forward.  Returns (y, cache) with cache carrying the
    conv tail and the final SSM state (for decode continuation)."""
    b, s, _ = x.shape
    di, n, h, pdim = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xbc, dt_raw = _mamba2_split(p, x, cfg)
    conv_state = None if state is None else state.get("conv")
    xbc, conv_tail = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xi = xbc[..., :di].reshape(b, s, h, pdim)
    bmat = xbc[..., di : di + n]
    cmat = xbc[..., di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, final_state = _ssd_chunked(
        xi.astype(jnp.float32), dt, a, bmat.astype(jnp.float32),
        cmat.astype(jnp.float32), cfg.ssm_chunk,
        None if state is None else state.get("ssm"),
        unroll=cfg.scan_unroll,
    )
    y = y + xi.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt((y * y).mean(-1, keepdims=True) + 1e-6) * p["ssm_norm"]
    out = y.astype(x.dtype) @ p["out_proj"]
    cache = {"conv": conv_tail, "ssm": final_state}
    return out, cache


def mamba2_decode(p: Params, x: jnp.ndarray, cache: Params, cfg: ModelConfig):
    """Single-token SSD recurrence: O(1) in context length."""
    b = x.shape[0]
    di, n, h, pdim = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xbc, dt_raw = _mamba2_split(p, x, cfg)          # x: (B, 1, d)
    xbc, conv_tail = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"], cache["conv"])
    xbc = jax.nn.silu(xbc)
    xi = xbc[..., :di].reshape(b, h, pdim)
    bmat = xbc[:, 0, di : di + n]
    cmat = xbc[:, 0, di + n :]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)                                # (B,H)
    ssm = cache["ssm"]                                  # (B,H,N,P)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, bmat.astype(jnp.float32),
                     xi.astype(jnp.float32))
    ssm = da[:, :, None, None] * ssm + upd
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), ssm)
    y = y + xi.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt((y * y).mean(-1, keepdims=True) + 1e-6) * p["ssm_norm"]
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, {"conv": conv_tail, "ssm": ssm}
